"""Quickstart: the FIKIT scheduling idea in 60 lines.

Two services share one device: a high-priority interactive service with
inter-kernel gaps, and a low-priority batch service. We profile both
(measurement phase), then compare default sharing vs FIKIT scheduling.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

# High-priority service A: 20 kernels of 2 ms, 5 ms host gap after each
# (tokenize/sample) — a low-GPU-saturation interactive inference.
A = TaskSpec(TaskKey("svcA"), priority=0,
             kernels=[TraceKernel(KernelID("A/layer"), 0.002, 0.005)] * 20)

# Low-priority service B: 60 kernels of 3 ms, almost no gaps, async client
# with 16 launches in flight — a device-bound batch job.
B = TaskSpec(TaskKey("svcB"), priority=5,
             kernels=[TraceKernel(KernelID("B/layer"), 0.003, 0.0002)] * 60,
             max_inflight=16)

# ---- measurement phase (paper Fig 3/6): T solo runs -> SK/SG statistics
profiled = profile_tasks([A, B], T=20, jitter=0.05)
profA = profiled.get(A.key)
print("profiled SK[A/layer] = %.3f ms, SG[A/layer] = %.3f ms"
      % (1e3 * list(profA.SK.values())[0], 1e3 * list(profA.SG.values())[0]))

# ---- sharing phase: run both concurrently under each scheduling mode
print(f"\nsolo JCTs: A={A.solo_jct*1e3:.1f} ms  B={B.solo_jct*1e3:.1f} ms\n")
print(f"{'mode':<10} {'JCT_A':>9} {'JCT_B':>9} {'fills':>6} {'util':>6}")
for mode in (Mode.EXCLUSIVE, Mode.SHARING, Mode.FIKIT, Mode.PREEMPT):
    rep = SimScheduler([A, B], mode, profiled, jitter=0.05, seed=1).run()
    print(f"{mode.value:<10} {rep.jct(0)*1e3:8.1f}m {rep.jct(1)*1e3:8.1f}m "
          f"{rep.fills:6d} {rep.utilization():6.2f}")

print("""
Reading the table:
- SHARING inflates A's JCT (B's async launches flood the FIFO device queue).
- EXCLUSIVE protects A but starves B.
- FIKIT keeps A at ~solo JCT *and* advances B inside A's gaps
  (fills > 0, highest device utilization) — the paper's headline result.
- PREEMPT (kernel-boundary preemptive sharing) also protects A, but B only
  runs when A is absent: no gap fills, lower utilization than FIKIT.
""")
