"""End-to-end training driver: train a reduced llama4-scout (MoE) for a few
hundred steps on CPU through the SAME train_step the production dry-run
lowers at full scale (AdamW, remat, synthetic pipeline, checkpointing).

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama4-scout-17b-a16e")
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, batch=8, seq=64,
                   ckpt_path="/tmp/repro_tiny_ckpt.msgpack")
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"mean loss first-10={first:.4f} last-10={last:.4f} "
          f"({'improved' if last < first else 'no improvement'})")
