"""End-to-end serving driver: two REAL reduced-scale models (qwen3 + mamba2)
share the device through the wall-clock FIKIT engine — real jitted JAX
segments, real threads, real measured JCTs.

Lifecycle per the paper: onboard (measurement phase, exclusive, per-kernel
timing) -> concurrent sharing phase under FIKIT vs default sharing. The
final run spreads the same workload over TWO device executors through the
placement layer (device election + idle-device work stealing). On a
single-accelerator host the two executor threads share the hardware, so
this demonstrates the scheduling path; see SimScheduler(devices=K) /
benchmarks/bench_placement.py for scaling measurements.

    PYTHONPATH=src python examples/serve_priority.py
"""
from repro.launch.serve import serve_pair

for mode in ("sharing", "fikit"):
    print(f"--- mode={mode} ---")
    out = serve_pair("qwen3-4b", "mamba2-2.7b", mode=mode, requests=6,
                     measure_runs=4)
    print()

print("--- mode=fikit devices=2 (placement layer) ---")
out = serve_pair("qwen3-4b", "mamba2-2.7b", mode="fikit", requests=6,
                 measure_runs=4, devices=2)
print()

# Intra-device queue disciplines (repro.core.queues.QUEUE_DISCIPLINES):
# "sjf" orders each priority level shortest-predicted-first; "edf" orders
# by the per-request deadline tag — here every low-priority invocation
# carries a 250 ms budget, and deadline_misses counts blown budgets.
print("--- mode=fikit discipline=sjf ---")
out = serve_pair("qwen3-4b", "mamba2-2.7b", mode="fikit", requests=6,
                 measure_runs=4, discipline="sjf")
print()

print("--- mode=fikit discipline=edf deadline=0.25 ---")
out = serve_pair("qwen3-4b", "mamba2-2.7b", mode="fikit", requests=6,
                 measure_runs=4, discipline="edf", deadline=0.25)
print()
