"""Lower + compile ONE (arch x shape) on the production mesh and print its
memory/cost/roofline numbers — the per-combo view of deliverable (e)/(g).

    PYTHONPATH=src python examples/dryrun_one.py --arch mamba2-2.7b \
        --shape decode_32k [--multi-pod]
"""
import sys

from repro.launch import dryrun

if __name__ == "__main__":
    sys.exit(dryrun.main())
