"""Pallas TPU kernels for the serving stack's compute hot spots.

FIKIT itself is pure scheduling infrastructure (no device-side compute
contribution); these kernels are the perf-critical layers of the models the
scheduler serves. Each kernel ships as a trio:

    <name>/kernel.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
    <name>/ops.py     — jit'd public wrapper (interpret=True on CPU)
    <name>/ref.py     — pure-jnp oracle used by the allclose tests
"""
from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.rglru_scan.ops import rglru_scan  # noqa: F401
