"""Flash attention Pallas TPU kernel: blockwise prefill attention with
online softmax; causal / sliding-window / chunked (iRoPE) masks; GQA.

Tiling: grid = (B, H, num_q_blocks, num_kv_blocks), kv innermost — TPU grid
iterations run sequentially on a core, so the running max / denominator /
accumulator live in VMEM scratch across kv steps. Block shapes are
(block_q, head_dim) / (block_k, head_dim), 128-aligned for the MXU; the
softmax statistics are carried at fp32 in (block_q, 128) scratch (values
replicated across lanes).

Positions are derived from program ids (prefill positions are always
0..S-1), so masks cost no memory traffic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1.0e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  chunk: Optional[int], block_q: int, block_k: int,
                  num_k: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)                     # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale         # [bq, bk]

    qpos = i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    if chunk is not None:
        mask &= (qpos // chunk) == (kpos // chunk)
    logits = jnp.where(mask, logits, NEG)

    m_prev = m_scr[:, :1]                                   # [bq, 1]
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(logits, axis=-1, keepdims=True)         # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                         # rescale old
    p = jnp.exp(logits - m_new)                             # [bq, bk]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == num_k - 1)
    def _out():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=None, chunk=None,
                           scale=None, block_q=128, block_k=128,
                           interpret=False):
    """q: [B, H, Sq, D]; k/v: [B, Kh, Sk, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        chunk=chunk, block_q=block_q, block_k=block_k, num_k=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=_scratch(block_q, D),
        interpret=interpret,
    )(q, k, v)


def _scratch(block_q: int, D: int):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
        pltpu.VMEM((block_q, LANES), jnp.float32),   # running denom
        pltpu.VMEM((block_q, D), jnp.float32),       # output accumulator
    ]
