"""Public jit'd wrapper for the flash attention kernel.

On CPU (this container) the kernel body executes under interpret=True; on a
real TPU pass interpret=False (the default resolves by backend).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref  # noqa: F401


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "chunk", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, chunk=None,
                    scale=None, block_q=128, block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, chunk=chunk, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
