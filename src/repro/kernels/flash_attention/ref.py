"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1.0e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, chunk=None,
                        scale=None):
    """q: [B, H, Sq, D]; k/v: [B, Kh, Sk, D] -> [B, H, Sq, D]."""
    B, H, Sq, D = q.shape
    Kh, Sk = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    if chunk is not None:
        mask &= (qpos // chunk) == (kpos // chunk)
    logits = jnp.where(mask, logits, NEG)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
