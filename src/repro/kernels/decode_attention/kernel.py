"""Decode (single-token) GQA attention Pallas TPU kernel.

One new query token per sequence attends over a long (possibly ring-buffer)
KV cache. Grid = (B, Kh, num_kv_blocks): each step loads one
(block_k, head_dim) cache tile into VMEM plus that tile's position row
(ring caches store positions per slot), masks invalid/out-of-window slots,
and maintains online-softmax statistics for the G query heads that share
the kv head. The memory term dominates decode (every cache byte is read
once) — exactly what the roofline for decode_32k/long_500k shows.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30
LANES = 128


def _decode_kernel(pos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float,
                   window: Optional[int], chunk: Optional[int],
                   block_k: int, num_k: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # [G, d]
    k = k_ref[0, 0].astype(jnp.float32)                    # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [G, bk]

    pos = pos_ref[0]                                       # scalar in SMEM
    kp = kpos_ref[...]                                     # [bk] slot pos
    valid = (kp >= 0) & (kp <= pos)
    if window is not None:
        valid &= pos - kp < window
    if chunk is not None:
        valid &= (pos // chunk) == (kp // chunk)
    logits = jnp.where(valid[None, :], logits, NEG)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == num_k - 1)
    def _out():
        l = l_scr[:, :1]
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, kpos, pos, *, window=None, chunk=None,
                            scale=None, block_k=256, interpret=False):
    """q: [B, H, D] one token; k/v: [B, Kh, C, D]; kpos: [C] slot positions
    (-1 = empty); pos: scalar int32 current position. -> [B, H, D]."""
    B, H, D = q.shape
    Kh, C = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, C)
    assert C % block_k == 0
    nk = C // block_k

    qg = q.reshape(B, Kh, G, D)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               chunk=chunk, block_k=block_k, num_k=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Kh, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),             # pos scalar
            pl.BlockSpec((block_k,), lambda b, h, j: (j,)),    # kpos tile
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kh, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, kpos, qg, k, v)
    return out.reshape(B, H, D)
