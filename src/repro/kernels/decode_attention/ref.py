"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1.0e30


def decode_attention_ref(q, k, v, kpos, pos, *, window=None, chunk=None,
                         scale=None):
    """q: [B, H, D]; k/v: [B, Kh, C, D]; kpos: [C]; pos scalar."""
    B, H, D = q.shape
    Kh, C = k.shape[1], k.shape[2]
    G = H // Kh
    scale = scale if scale is not None else D ** -0.5
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = jnp.asarray(pos, jnp.int32)
    valid = (kpos >= 0) & (kpos <= pos)
    if window is not None:
        valid &= pos - kpos < window
    if chunk is not None:
        valid &= (pos // chunk) == (kpos // chunk)
    logits = jnp.where(valid[None, None, :], logits, NEG)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhk,bhkd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
