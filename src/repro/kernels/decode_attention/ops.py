"""Public jit'd wrapper for the decode attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref  # noqa: F401


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "window", "chunk", "scale", "block_k", "interpret"))
def decode_attention(q, k, v, kpos, pos, *, window=None, chunk=None,
                     scale=None, block_k=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return decode_attention_kernel(
        q, k, v, kpos, pos, window=window, chunk=chunk, scale=scale,
        block_k=block_k, interpret=interpret)
