"""Public jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_kernel
from repro.kernels.rglru_scan.ref import rglru_scan_ref  # noqa: F401


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "block_b", "block_w", "block_s", "interpret"))
def rglru_scan(a, b, h0, *, block_b=8, block_w=128, block_s=128,
               interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return rglru_scan_kernel(a, b, h0, block_b=block_b, block_w=block_w,
                             block_s=block_s, interpret=interpret)
