"""Pure-jnp oracle for the RG-LRU scan kernel (associative scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a/b: [B,S,W]; h0: [B,W]."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return hs.astype(a.dtype)
