"""RG-LRU linear recurrence Pallas TPU kernel: h_t = a_t * h_{t-1} + b_t.

The recurrence is diagonal (elementwise in the width dim), so the natural
TPU decomposition is: grid = (batch tiles, width tiles, seq blocks) with the
seq dimension innermost (sequential on-core) carrying the running state in
VMEM scratch. Within a seq block the recurrence runs as an in-VMEM
fori_loop over rows — every step is a fused multiply-add on a
(block_b, block_w) vector tile, which is VPU-shaped work; the HBM traffic
is exactly one read of a/b and one write of h (memory-bound by design,
matching the roofline's memory term for recurrent layers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, block_s: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    def body(t, h):
        a_t = a_ref[:, t, :].astype(jnp.float32)
        b_t = b_ref[:, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, body, h_scr[...])
    h_scr[...] = h


def rglru_scan_kernel(a, b, h0, *, block_b=8, block_w=128, block_s=128,
                      interpret=False):
    """a/b: [B, S, W]; h0: [B, W]. Returns h: [B, S, W] (all prefixes)."""
    B, S, W = a.shape
    block_b = min(block_b, B)
    block_w = min(block_w, W)
    block_s = min(block_s, S)
    assert B % block_b == 0 and W % block_w == 0 and S % block_s == 0
    grid = (B // block_b, W // block_w, S // block_s)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_s, block_w),
                         lambda i, j, s: (i, s, j)),
            pl.BlockSpec((block_b, block_s, block_w),
                         lambda i, j, s: (i, s, j)),
            pl.BlockSpec((block_b, block_w), lambda i, j, s: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_s, block_w),
                               lambda i, j, s: (i, s, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
