"""Mesh context: lets model code (e.g. the MoE shard_map block) know which
mesh the surrounding pjit is using without threading it through every call.

The launch layer sets the context; model code queries it. With no mesh set
(unit tests, reduced smoke models) the single-device code path is used.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

_state = threading.local()


def set_mesh(mesh) -> None:
    _state.mesh = mesh


def get_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def batch_axes() -> Optional[Tuple[str, ...]]:
    """Mesh axes over which the global batch is sharded."""
    mesh = get_mesh()
    if mesh is None:
        return None
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names) or None


def model_axis_size() -> int:
    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def constrain(x, *spec):
    """with_sharding_constraint against the context mesh; no-op without a
    mesh. ``spec`` entries: "batch" -> the batch axes (dropped when the dim
    is not divisible), "model" -> the model axis (dropped when not
    divisible), None -> unconstrained.

    Model code uses this inside scan bodies where GSPMD's propagation
    otherwise loses shardings and replicates large intermediates.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = get_mesh()
    if mesh is None:
        return x
    bax = batch_axes()
    out = []
    for dim, s in zip(x.shape, spec):
        if s == "batch":
            n = 1
            for a in (bax or ()):
                n *= mesh.shape[a]
            out.append(bax if (bax and dim % n == 0) else None)
        elif s == "model":
            ok = "model" in mesh.axis_names and \
                dim % mesh.shape["model"] == 0
            out.append("model" if ok else None)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*out)))
