"""PartitionSpec rules for params, optimizer state, activations and caches.

Layout policy (single pod mesh (16,16) axes ("data","model"); multi-pod
(2,16,16) axes ("pod","data","model")):

- 2-D weight sharding: feature-in ("fan-in") dims on ``data`` (FSDP/ZeRO-3),
  feature-out / heads / experts / vocab dims on ``model`` (tensor/expert
  parallel). Replicated across ``pod`` (pods are pure data parallel).
- Optimizer moments: identical specs to their params (fp32).
- Activations: batch on ("pod","data"), heads / hidden-parallel dims on
  ``model``. Batch=1 shapes (long_500k) replicate batch and let the data
  axis idle (recorded in the roofline notes).
- KV caches: kv-head dim on ``model`` when divisible, else the cache
  sequence dim goes on ``model`` (ring-buffer writes lower fine under
  GSPMD either way).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.config import HYBRID, ModelConfig


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def bspec(mesh, batch: int, *rest) -> P:
    """Batch-leading spec; replicates batch when not divisible."""
    ax = batch_axes(mesh)
    if batch % max(_n_batch_shards(mesh), 1) != 0:
        return P(None, *rest)
    return P(ax, *rest)


# ---------------------------------------------------------------------------
# Param specs: name-based rules applied leaf-wise (stacked layer dims get a
# leading None automatically by rank matching).
# ---------------------------------------------------------------------------
_D, _M = "data", "model"

# trailing-dims spec per param name (applied to the last len(spec) dims)
_RULES = {
    # embeddings / head
    "embed": (_M, _D),
    "lm_head": (_D, _M),
    "enc_in": (_D, None),
    # attention
    "wq": (_D, _M, None),
    "wk": (_D, _M, None),
    "wv": (_D, _M, None),
    "wo": (_M, None, _D),
    "q_norm": (None,),
    "k_norm": (None,),
    # MLA
    "w_dq": (_D, None),
    "q_norm_lora": (None,),
    "w_dkv": (_D, None),
    "kv_norm": (None,),
    "w_uk": (_M, None, None),
    "w_uv": (_M, None, None),
    # mlp
    "w_gate": (_D, _M),
    "w_up": (_D, _M),
    "w_down": (_M, _D),
    # moe (must match the shard_map in_specs in repro.models.moe)
    "router": (None, None),
    "w1": (_M, _D, None),
    "w3": (_M, _D, None),
    "w2": (_M, None, _D),
    "sh_gate": (None, _M),
    "sh_up": (None, _M),
    "sh_down": (_M, None),
    # mamba2
    "w_z": (_D, _M),
    "w_x": (_D, _M),
    "w_B": (_D, None),
    "w_C": (_D, None),
    "w_dt": (_D, _M),
    "conv_x": (None, _M),
    "conv_B": (None, None),
    "conv_C": (None, None),
    "A_log": (_M,),
    "dt_bias": (_M,),
    "D_skip": (_M,),
    "out_norm": (_M,),
    "w_out": (_M, _D),
    # rg-lru
    "w_y": (_D, _M),
    "conv": (None, _M),
    "w_r": (None, _M),
    "w_i": (None, _M),
    "lam": (_M,),
    # norms
    "ln": (None,),
    "ln1": (None,),
    "ln2": (None,),
    "lnx": (None,),
    "final_norm": (None,),
    "enc_norm": (None,),
}


def _spec_for(name: str, shape, mesh) -> P:
    ndim = len(shape)
    rule = _RULES.get(name)
    if rule is None:
        rule = (None,) * ndim
    # pad leading stacked-layer dims with None
    lead = ndim - len(rule)
    full = (None,) * lead + tuple(rule)
    # drop axes absent from the mesh, and axes whose dim is not divisible
    # by the axis size (e.g. kv_heads=8 on a 16-way model axis -> replicate)
    out = []
    for dim, ax in zip(shape, full):
        if ax is None or ax not in mesh.axis_names \
                or dim % mesh.shape[ax] != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def param_specs(params, mesh):
    """Pytree of PartitionSpec matching ``params`` (arrays or SDS)."""
    def assign(path, leaf):
        name = _leaf_name(path)
        return _spec_for(name, tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(assign, params)


def opt_specs(opt_state, params_spec, zero_axis: str = None, params=None,
              mesh=None):
    """AdamW moments share their param's spec; step is replicated.

    zero_axis: additionally shard each moment's first unsharded divisible
    dim over this axis (ZeRO-style optimizer-state sharding, e.g. across
    pods) — beyond-paper optimization H1."""
    from repro.optim.adamw import AdamWState
    if zero_axis is None:
        return AdamWState(step=P(), mu=params_spec, nu=params_spec)
    size = mesh.shape[zero_axis]

    def widen(spec, leaf):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (ax, dim) in enumerate(zip(entries, leaf.shape)):
            if ax is None and dim % size == 0:
                entries[i] = zero_axis
                break
        return P(*entries)

    mspec = jax.tree.map(widen, params_spec, params,
                         is_leaf=lambda x: isinstance(x, P))
    return AdamWState(step=P(), mu=mspec, nu=mspec)


# ---------------------------------------------------------------------------
# Activation / cache specs
# ---------------------------------------------------------------------------
def token_spec(mesh, batch: int) -> P:
    return bspec(mesh, batch, None)


def embeds_spec(mesh, batch: int) -> P:
    return bspec(mesh, batch, None, None)


def logits_spec(mesh, batch: int, vocab: int = 0) -> P:
    m = _M if _M in mesh.axis_names else None
    if m is not None and vocab and vocab % mesh.shape[_M] != 0:
        m = None              # e.g. seamless vocab 256206 on a 16-way axis
    return bspec(mesh, batch, None, m)


def _kv_dims(cfg: ModelConfig, mesh) -> Tuple[Optional[str], Optional[str]]:
    """(seq_dim_axis, kv_head_axis) for a KV cache."""
    msize = mesh.shape.get(_M, 1)
    if _M not in mesh.axis_names:
        return None, None
    if cfg.num_kv_heads and cfg.num_kv_heads % msize == 0:
        return None, _M
    return _M, None


def cache_specs(cfg: ModelConfig, caches, mesh, batch: int):
    """Specs for the stacked decode caches returned by init_decode_caches."""
    from repro.models.attention import KVCache, MLACache
    from repro.models.mamba2 import SSMCache
    from repro.models.encdec import DecCache
    bax = batch_axes(mesh) if batch % max(_n_batch_shards(mesh), 1) == 0 \
        else None
    seq_ax, kvh_ax = _kv_dims(cfg, mesh)
    m = _M if _M in mesh.axis_names else None

    def kv_spec(stacked: bool):
        lead = (None,) if stacked else ()
        return KVCache(
            k=P(*lead, bax, seq_ax, kvh_ax, None),
            v=P(*lead, bax, seq_ax, kvh_ax, None),
            pos=P(*lead, None),
        )

    def one(cache):
        if isinstance(cache, KVCache):
            stacked = cache.k.ndim == 5
            return kv_spec(stacked)
        if isinstance(cache, MLACache):
            stacked = cache.c.ndim == 4
            lead = (None,) if stacked else ()
            return MLACache(c=P(*lead, bax, m, None),
                            kr=P(*lead, bax, m, None),
                            pos=P(*lead, None))
        if isinstance(cache, SSMCache):
            stacked = cache.state.ndim == 5
            lead = (None,) if stacked else ()
            return SSMCache(state=P(*lead, bax, m, None, None),
                            conv_x=P(*lead, bax, None, m),
                            conv_B=P(*lead, bax, None, None),
                            conv_C=P(*lead, bax, None, None))
        if isinstance(cache, DecCache):
            stacked = cache.cross_k.ndim == 5
            lead = (None,) if stacked else ()
            return DecCache(self_kv=kv_spec(stacked),
                            cross_k=P(*lead, bax, None, kvh_ax, None),
                            cross_v=P(*lead, bax, None, kvh_ax, None))
        raise TypeError(type(cache))

    if cfg.family == HYBRID:
        from repro.models.rglru import RecCache
        out = []
        for cache in caches:
            if isinstance(cache, RecCache):
                out.append(RecCache(h=P(bax, m),
                                    conv=P(bax, None, m)))
            else:
                out.append(one(cache))
        return out
    return one(caches)
