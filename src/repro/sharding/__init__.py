from repro.sharding.context import (  # noqa: F401
    batch_axes, get_mesh, mesh_context, model_axis_size, set_mesh,
)
