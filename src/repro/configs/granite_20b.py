"""granite-20b [dense] — llama-arch, code [arXiv:2405.04324].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.config import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    family=DENSE,
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
))
