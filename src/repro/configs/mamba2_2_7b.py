"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560 (attn-free) vocab=50280, ssm_state=128, expand=2,
headdim=64 (80 SSD heads).
"""
from repro.config import SSM, ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family=SSM,
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
))
