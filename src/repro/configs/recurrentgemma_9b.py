"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, local window 2048,
block pattern (rec, rec, attn) -> runs long_500k.
"""
from repro.config import HYBRID, ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family=HYBRID,
    source="arXiv:2402.19427",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_window=2048,
    ssm_conv=4,
    tie_embeddings=True,
))
