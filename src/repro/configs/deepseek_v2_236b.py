"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H d_ff=1536 (per expert) vocab=102400.
"""
from repro.config import MOE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family=MOE,
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
))
