"""llama4-scout-17b-a16e [moe] — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 +
shared expert; iRoPE-style chunked local attention (8192) on 3 of every 4
layers (every 4th layer is full/NoPE) -> runs long_500k.
"""
from repro.config import MOE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family=MOE,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_shared_experts=1,
    top_k=1,
    moe_d_ff=8192,
    attention_chunk=8192,
    chunk_pattern=4,
))
