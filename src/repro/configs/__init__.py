"""Assigned architecture configs. Importing this package populates the
registry in repro.config."""
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    granite_20b,
    h2o_danube_3_4b,
    llama4_scout_17b_a16e,
    llava_next_mistral_7b,
    mamba2_2_7b,
    qwen3_4b,
    recurrentgemma_9b,
    seamless_m4t_medium,
    stablelm_1_6b,
)

ARCH_IDS = [
    "stablelm-1.6b",
    "granite-20b",
    "llama4-scout-17b-a16e",
    "mamba2-2.7b",
    "qwen3-4b",
    "llava-next-mistral-7b",
    "deepseek-v2-236b",
    "recurrentgemma-9b",
    "seamless-m4t-medium",
    "h2o-danube-3-4b",
]
