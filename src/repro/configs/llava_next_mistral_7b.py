"""llava-next-mistral-7b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
SWA 4096 -> runs long_500k. Vision frontend is a stub: input_specs()
supplies anyres patch embeddings (2880 patches) prepended to text.
"""
from repro.config import VLM, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family=VLM,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    num_patches=2880,
))
