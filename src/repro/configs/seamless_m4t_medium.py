"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

12L (12 enc + 12 dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
Audio frontend (mel + conv codec) is a stub: input_specs() supplies frame
embeddings for the encoder.
"""
from repro.config import ENCDEC, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family=ENCDEC,
    source="arXiv:2308.11596",
    num_layers=12,
    num_encoder_layers=12,
    num_decoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    encoder_frames=1024,
))
