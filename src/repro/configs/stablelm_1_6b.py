"""stablelm-2-1_6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32, i.e. MHA) d_ff=5632 vocab=100352,
partial rotary (25%).
"""
from repro.config import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b",
    family=DENSE,
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rotary_pct=0.25,
))
