"""AdamW + global-norm clipping + linear-warmup cosine schedule, pure JAX.

Moments are fp32 regardless of param dtype; the update is applied in fp32
and cast back (mixed-precision training convention). Works on arbitrary
pytrees, including ShapeDtypeStruct trees (for the dry-run: ``adamw_init``
maps shapes to shapes so the optimizer state can be lowered without
allocation).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def _zeros_like(p, dtype):
    if isinstance(p, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct(p.shape, dtype)
    return jnp.zeros(p.shape, dtype)


def adamw_init(params, moments_dtype=jnp.float32) -> AdamWState:
    """moments_dtype=bfloat16 halves optimizer-state HBM — the lever used
    in EXPERIMENTS.md H1 to fit deepseek-v2-236b training on v5e."""
    import functools
    step = (jax.ShapeDtypeStruct((), jnp.int32)
            if any(isinstance(l, jax.ShapeDtypeStruct)
                   for l in jax.tree.leaves(params))
            else jnp.zeros((), jnp.int32))
    zl = functools.partial(_zeros_like, dtype=jnp.dtype(moments_dtype))
    return AdamWState(
        step=step,
        mu=jax.tree.map(zl, params),
        nu=jax.tree.map(zl, params),
    )


def schedule(step, base_lr: float, warmup: int = 100,
             total: int = 10_000, min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01, clip_norm: float = 1.0,
                 warmup: int = 100, total_steps: int = 10_000):
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
    lr_t = schedule(step, lr, warmup, total_steps)

    def upd(g, m, v, p):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gn,
                                                   "lr": lr_t}
