from repro.serving.engine import InferenceService, ServingSystem  # noqa: F401
