from repro.serving.engine import InferenceService, ServingSystem  # noqa: F401
from repro.serving.admission import (  # noqa: F401
    AdmissionPlane, AdmissionTicket, QoSClass, DEFAULT_CLASSES)
