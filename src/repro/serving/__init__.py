"""Serving package: engine-hosted services, admission plane, workers.

Imports are lazy (PEP 562): ``repro.serving.engine`` pulls in JAX and
the model zoo, which an engine-worker subprocess (``python -m
repro.serving.workers``) never needs — resolving names on first access
keeps worker start-up to the pure-python scheduler core.
"""
_LAZY = {
    "InferenceService": ("repro.serving.engine", "InferenceService"),
    "ServingSystem": ("repro.serving.engine", "ServingSystem"),
    "AdmissionPlane": ("repro.serving.admission", "AdmissionPlane"),
    "AdmissionTicket": ("repro.serving.admission", "AdmissionTicket"),
    "QoSClass": ("repro.serving.admission", "QoSClass"),
    "DEFAULT_CLASSES": ("repro.serving.admission", "DEFAULT_CLASSES"),
    "EngineWorker": ("repro.serving.workers", "EngineWorker"),
    "WorkerConfig": ("repro.serving.workers", "WorkerConfig"),
    "WorkerSupervisor": ("repro.serving.workers", "WorkerSupervisor"),
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value          # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
