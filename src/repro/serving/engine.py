"""Priority serving engine: hosts multiple model services on a node of
``devices=K`` serial device executors (default one) under the FIKIT
scheduler (the paper's cloud-serving deployment).

Lifecycle per the paper (Fig 3):
1. A new service is profiled: T exclusive measured runs -> SK/SG stats
   loaded into the scheduler (measurement phase).
2. All later invocations run in the sharing phase: kernel-ID identification
   only, priority queues + gap filling decide placement.

Any scheduling ``Mode`` can host the system: FIKIT (the paper), SHARING
(default GPU), EXCLUSIVE (serialized), or PREEMPT — kernel-boundary
preemptive sharing, where a lower-priority service's dispatches park in
the priority queues whenever any strictly-higher-priority invocation is
active (no gap filling). All modes share one decision core,
``repro.core.policy.FikitPolicy``; ``devices=K`` spreads invocations over
K device executors through ``repro.core.placement.PlacementLayer`` (device
election per invocation + idle-device work stealing), with one profile
store shared by all devices — a service is profiled once, scheduled
anywhere.
"""
from __future__ import annotations

import logging
import threading
import time
from typing import Dict, List, Optional

import jax

from repro.config import ModelConfig
from repro.core import jobstore as _js
from repro.core.client import HookClient, new_instance
from repro.core.executor import JobCancelled, WallClockEngine
from repro.core.jobstore import coerce_store
from repro.core.profiler import ProfiledData, Profiler
from repro.core.scheduler import Mode
from repro.core.task import TaskKey
from repro.models import api
from repro.models.segmentation import SegmentedService
from repro.serving.admission import AdmissionPlane, coerce_admission

logger = logging.getLogger(__name__)


class InferenceService:
    """One hosted model + its priority + its profile state."""

    def __init__(self, cfg: ModelConfig, priority: int, batch: int = 1,
                 seq: int = 32, host_gap: float = 0.0, tail_gap: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.priority = priority
        self.key = TaskKey(cfg.name, (batch, seq))
        params = api.build_params(cfg, jax.random.key(seed))
        self.svc = SegmentedService(cfg, params, batch, seq,
                                    host_gap=host_gap, tail_gap=tail_gap)
        self.profiled = False

    def client(self, engine: WallClockEngine, identify: bool = True):
        return HookClient(engine, self.key, self.priority,
                          self.svc.segments, identify=identify)


class ServingSystem:
    """Owns the engine + profile store; runs measurement then sharing.

    ``discipline`` elects the device per invocation (placement);
    ``queue_discipline`` orders parked requests within each device's
    priority levels ("fifo" default / "sjf" / "edf"). Invocations may
    carry a relative ``deadline`` budget (seconds): it is tagged onto
    every kernel request (consulted by edf levels) and drives the
    ``deadline_misses``/``deadlines_tagged`` serving stats."""

    def __init__(self, mode: Mode = Mode.FIKIT, measure_runs: int = 5,
                 devices: int = 1, discipline: str = "least_loaded",
                 queue_discipline: str = "fifo", online_measure=False,
                 interference=None, jobstore=None, admission=None):
        """``online_measure`` (False / True / ``repro.core.online.
        OnlineConfig``) enables live SK/SG refinement during the sharing
        phase: every dispatched segment's device-time bracket feeds
        EMA-smoothed profile updates (committed in epochs), never-profiled
        services get cold-start provisional durations instead of being
        invisible to gap filling, and ``online_stats`` reports
        observation/commit/drift counters. Off (default) is the paper's
        strictly-offline two-phase behavior.

        ``interference`` (None / True / mapping /
        ``repro.core.interference.InterferenceModel``) enables
        interference-aware gap filling in the hosted engine; off (None,
        default) keeps scheduling bit-identical to interference-off.

        ``jobstore`` (None / path / ``repro.core.jobstore.JobStore``)
        attaches the durable ops plane: every invocation gets a job row,
        every finished kernel a write-ahead completion record (committed
        by the device thread BEFORE the boundary's scheduling
        side-effects), terminal states and profile snapshots persist,
        and a poller thread consumes operator control verbs written into
        the store by the ``repro.launch.serve`` CLI. The store only
        observes — scheduling decisions are identical with or without
        one. Wall-clock recovery is invocation-level: ``recover()``
        re-runs each incomplete invocation from its service definition
        (payloads are live callables, not replayable records), unlike
        the simulator's kernel-exact ``SimScheduler.recover``.

        ``admission`` (None / True / ``QoSClass`` sequence / dict of
        ``repro.serving.admission.AdmissionPlane`` kwargs) attaches the
        async admission plane: per-tenant QoS classes mapped onto FIKIT
        priorities, bounded queues with backpressure, SLO-aware
        shedding, and continuous batching, served by one dispatcher
        thread over the non-blocking submit path (``submit_async``).
        None (default) leaves the direct ``invoke`` path — and the
        engine's decision traces — exactly as before."""
        self.profiles = ProfiledData()
        self.mode = mode
        self.measure_runs = measure_runs
        self.devices = devices
        self.discipline = discipline
        self.queue_discipline = queue_discipline
        self.online_measure = online_measure
        self.interference = interference
        self.engine: Optional[WallClockEngine] = None
        self.deadline_misses = 0
        self.deadlines_tagged = 0
        self.cancelled_invocations = 0
        self._stats_lock = threading.Lock()
        self._final_online_stats: Optional[dict] = None
        self._stopped = False
        # ops plane: durable store + instance<->job maps + control poller
        self.jobstore = coerce_store(jobstore)
        self._job_of_inst: Dict[int, int] = {}
        self._inst_of_job: Dict[int, int] = {}
        self._snap_commits = 0
        self._poll_stop: Optional[threading.Event] = None
        self._poller: Optional[threading.Thread] = None
        self._poll_join_timeout = 5.0
        self.rejected_controls = 0     # unapplicable operator verbs consumed
        self.poller_deaths = 0         # unexpected poller-killing errors
        # admission plane (built per start(); None = direct-invoke only)
        self._admission_spec = coerce_admission(admission)
        self.admission: Optional[AdmissionPlane] = None

    def start(self) -> "ServingSystem":
        """Build + start a fresh engine. Clears any final-stats snapshot a
        previous start/stop cycle cached, so ``online_stats`` reflects THIS
        engine, not a stale restart leftover. With a jobstore attached,
        also reloads the latest profile snapshot (online-learned SK/SG
        survive a restart) and starts the control poller."""
        self._final_online_stats = None
        self._stopped = False
        if self.jobstore is not None:
            snap = self.jobstore.load_profiles()
            if snap is not None:
                # merge the checkpointed (possibly online-refined) SK/SG
                # into the live profile store the engine will serve from
                for prof in snap._by_key.values():
                    self.profiles.load(prof)
        self.engine = WallClockEngine(
            self.mode, self.profiles, devices=self.devices,
            discipline=self.discipline,
            queue_discipline=self.queue_discipline,
            online=self.online_measure or None,
            interference=self.interference,
            on_kernel_complete=(self._on_kernel_complete
                                if self.jobstore is not None
                                else None)).start()
        if self.jobstore is not None:
            self._poll_stop = threading.Event()
            self._poller = threading.Thread(target=self._poll_controls,
                                            args=(self._poll_stop,),
                                            daemon=True,
                                            name="fikit-ops-poller")
            self._poller.start()
        if self._admission_spec is not None:
            self.admission = AdmissionPlane(self,
                                            **self._admission_spec).start()
        return self

    def stop(self) -> None:
        """Stop the engine (idempotent; a no-op before ``start()``). With
        a jobstore attached, also stops the control poller and writes a
        final profile snapshot + WAL checkpoint — UNLESS the poller
        failed to join in time: a wedged verb handler could still be
        writing ``snapshot_profiles`` against the store mid-checkpoint,
        so the final snapshot is skipped with a warning instead of
        racing it."""
        if self._stopped or self.engine is None:
            self._stopped = True
            return
        self._stopped = True
        if self.admission is not None:
            # drain the plane first: queued work resolves (REQUEUED) and
            # in-flight groups finish while the device threads still run
            self.admission.drain(timeout=5)
            self.admission.stop()
        poller_wedged = False
        if self._poll_stop is not None:
            self._poll_stop.set()
            self._poller.join(timeout=self._poll_join_timeout)
            poller_wedged = self._poller.is_alive()
            self._poll_stop = None
            self._poller = None
        self.engine.stop()
        if self.engine.online is not None and self.engine.online.config.enabled:
            self._final_online_stats = self.engine.online.stats()  # post-flush
        if self.jobstore is not None:
            if poller_wedged:
                logger.warning(
                    "ops poller did not exit within %.1fs — skipping the "
                    "final profile snapshot/checkpoint so a wedged verb "
                    "handler cannot race the store shutdown",
                    self._poll_join_timeout)
            else:
                self.jobstore.snapshot_profiles(self.profiles)
                self.jobstore.checkpoint()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    @property
    def online_stats(self) -> Optional[dict]:
        """Online measurement counters: live while serving, the final
        (post-flush) snapshot after the context manager exits, None when
        ``online_measure`` is off."""
        if self._final_online_stats is not None:
            return self._final_online_stats
        if self.engine is not None:
            return self.engine.online_stats()
        return None

    # ------------------------------------------------------------ lifecycle
    def onboard(self, service: InferenceService) -> List[float]:
        """Measurement phase: T exclusive measured runs (paper: T in
        [10, 1000]); returns the measured-phase JCTs."""
        service.svc.warmup()
        prof = Profiler(service.key)
        jcts = []
        meas_engine = WallClockEngine(Mode.EXCLUSIVE).start()
        try:
            cl = HookClient(meas_engine, service.key, service.priority,
                            service.svc.segments)
            for _ in range(self.measure_runs):
                state = service.svc.make_input()
                _, jct = cl.measure_run(state, prof)
                jcts.append(jct)
        finally:
            meas_engine.stop()
        self.profiles.load(prof.statistics())
        service.profiled = True
        return jcts

    def invoke(self, service: InferenceService, n: int = 1,
               interval: float = 0.0,
               deadline: Optional[float] = None) -> List[float]:
        """n sharing-phase invocations; returns JCTs of the invocations
        that COMPLETED (one cancelled mid-flight by an ops-plane verb is
        counted in ``self.cancelled_invocations`` instead of hanging or
        raising out of the batch). ``deadline`` is a per-invocation
        completion budget in seconds; when given, every kernel request
        is deadline-tagged (edf levels order by it) and invocations
        finishing past the budget count into ``self.deadline_misses``."""
        if self.engine is None:
            raise RuntimeError(
                "ServingSystem.invoke() before start() — the engine does "
                "not exist yet; use the context manager or call start()")
        if self._stopped:
            raise RuntimeError(
                "ServingSystem.invoke() after stop() — the engine's "
                "device threads have exited; call start() again first")
        cl = service.client(self.engine)
        jcts = []
        for _ in range(n):
            jct = self._invoke_one(cl, service, deadline=deadline)
            if jct is not None:
                jcts.append(jct)
            if interval > 0:
                time.sleep(interval)
        return jcts

    def _invoke_one(self, cl: HookClient, service: InferenceService,
                    deadline: Optional[float] = None,
                    job_id: Optional[int] = None) -> Optional[float]:
        """One sharing-phase invocation under an (optional) durable job
        record. Returns the JCT, or None when the invocation was
        cancelled by an ops-plane verb."""
        inst = new_instance()
        if self.jobstore is not None:
            job_id = self.jobstore.record_submit(
                job_id, service.key, service.priority,
                n_kernels=len(service.svc.segments),
                deadline=deadline, state=_js.RUNNING)
            with self._stats_lock:
                self._job_of_inst[inst] = job_id
                self._inst_of_job[job_id] = inst
        state = service.svc.make_input()
        try:
            _, jct = cl.run(state, deadline=deadline, instance=inst)
        except JobCancelled:
            with self._stats_lock:
                self.cancelled_invocations += 1
            return None
        finally:
            if self.jobstore is not None:
                with self._stats_lock:
                    self._job_of_inst.pop(inst, None)
                    self._inst_of_job.pop(job_id, None)
        if self.jobstore is not None:
            self.jobstore.record_state(job_id, _js.DONE)
        if deadline is not None:
            with self._stats_lock:
                self.deadlines_tagged += 1
                if jct > deadline:
                    self.deadline_misses += 1
        return jct

    # ------------------------------------------------------ async admission
    def _invoke_async(self, service: InferenceService, on_done,
                      deadline: Optional[float] = None,
                      job_id: Optional[int] = None) -> int:
        """Non-blocking ``_invoke_one``: submits through
        ``HookClient.run_async`` and returns the instance id at once.
        ``on_done(jct, error)`` fires from a device thread when the
        invocation retires — ``(jct, None)`` on success, ``(None, None)``
        when an ops-plane cancel hit it (counted like the sync path),
        ``(None, error)`` when a payload failed. Shares the jobstore and
        deadline-stat bookkeeping with the blocking path."""
        if self.engine is None:
            raise RuntimeError("ServingSystem._invoke_async() before "
                               "start() — the engine does not exist yet")
        if self._stopped:
            raise RuntimeError("ServingSystem._invoke_async() after stop()")
        inst = new_instance()
        if self.jobstore is not None:
            job_id = self.jobstore.record_submit(
                job_id, service.key, service.priority,
                n_kernels=len(service.svc.segments),
                deadline=deadline, state=_js.RUNNING)
            with self._stats_lock:
                self._job_of_inst[inst] = job_id
                self._inst_of_job[job_id] = inst
        cl = service.client(self.engine)
        state = service.svc.make_input()

        def done(result, jct, error) -> None:
            if self.jobstore is not None:
                with self._stats_lock:
                    self._job_of_inst.pop(inst, None)
                    self._inst_of_job.pop(job_id, None)
            if isinstance(error, JobCancelled):
                with self._stats_lock:
                    self.cancelled_invocations += 1
                on_done(None, None)
                return
            if error is not None:
                on_done(None, error)
                return
            if self.jobstore is not None:
                self.jobstore.record_state(job_id, _js.DONE)
            if deadline is not None:
                with self._stats_lock:
                    self.deadlines_tagged += 1
                    if jct > deadline:
                        self.deadline_misses += 1
            on_done(jct, None)

        cl.run_async(state, done, deadline=deadline, instance=inst)
        return inst

    def submit_async(self, service: InferenceService, qos: str,
                     deadline=...):
        """Offer one invocation to the admission plane (see
        ``repro.serving.admission``); returns its ``AdmissionTicket``
        immediately. Requires ``admission=`` at construction."""
        if self.admission is None:
            raise RuntimeError(
                "ServingSystem.submit_async() needs the admission plane — "
                "construct with admission=True (or QoS classes)")
        if deadline is ...:
            return self.admission.submit(service, qos)
        return self.admission.submit(service, qos, deadline=deadline)

    def invoke_concurrent(self, plans) -> Dict[str, List[float]]:
        """plans: list of (name, service, n, interval, start_delay) tuples,
        optionally extended with a 6th ``deadline`` element (relative
        seconds per invocation). Runs each plan in its own client thread;
        returns JCTs per name.

        A runner thread that raises (a failing payload propagates out of
        ``invoke``) no longer dies silently leaving its name missing
        from the result — every plan's exception is captured and the
        first one (in plan order) re-raised after all threads joined."""
        if self.engine is None or self._stopped:
            raise RuntimeError("ServingSystem.invoke_concurrent() outside "
                               "a start()/stop() window")
        out: Dict[str, List[float]] = {}
        errors: Dict[str, BaseException] = {}
        threads = []

        def runner(name, service, n, interval, delay, deadline=None):
            if delay > 0:
                time.sleep(delay)
            try:
                out[name] = self.invoke(service, n=n, interval=interval,
                                        deadline=deadline)
            except BaseException as e:
                errors[name] = e

        for plan in plans:
            threads.append(threading.Thread(target=runner, args=plan))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            for plan in plans:           # re-raise the FIRST, plan order
                if plan[0] in errors:
                    raise errors[plan[0]]
        return out

    # ------------------------------------------------------------ ops plane
    def _on_kernel_complete(self, req, start: float, end: float) -> None:
        """Engine hook (device thread, engine lock held): write-ahead
        record of a finished kernel, before the boundary's scheduling
        side-effects."""
        with self._stats_lock:
            job = self._job_of_inst.get(req.task_instance)
        if job is not None:
            self.jobstore.record_completion(job, req.seq_index)

    def _poll_controls(self, stop_ev: threading.Event) -> None:
        """Poller thread: consume operator verbs from the store's control
        queue (written by the serve CLI against the same store file) and
        checkpoint profiles whenever an online epoch committed.

        Only the EXPECTED unapplicable-verb errors (``ValueError`` for an
        unknown/finished job, ``KeyError`` for a vanished instance) are
        absorbed — counted in ``rejected_controls`` and surfaced via
        ``status()``. Anything else is a real bug (e.g. a store error
        mid-``cancel``): it is logged with traceback, counted in
        ``poller_deaths``, and kills the poller rather than vanishing."""
        try:
            while not stop_ev.wait(0.05):
                for verb, job_id, arg in self.jobstore.pop_controls():
                    try:
                        if verb == "cancel":
                            self.cancel(job_id)
                        elif verb == "pause":
                            self.pause(job_id)
                        elif verb == "resume":
                            self.resume(job_id,
                                        int(arg) if arg is not None else None)
                        elif verb == "drain":
                            self.drain()
                        else:
                            raise ValueError(f"unknown control verb {verb!r}")
                    except (ValueError, KeyError):
                        # unapplicable operator verb (unknown/finished
                        # job): the row stays consumed, status() shows
                        # the rejection count + the job's actual state
                        with self._stats_lock:
                            self.rejected_controls += 1
                eng = self.engine
                if (eng is not None and eng.online is not None
                        and eng.online.commits != self._snap_commits):
                    self._snap_commits = eng.online.commits
                    self.jobstore.snapshot_profiles(self.profiles)
        except Exception:
            with self._stats_lock:
                self.poller_deaths += 1
            logger.exception("ops-control poller died on an unexpected "
                             "error; operator verbs will no longer apply "
                             "to this serving process")

    def _live_instance(self, job_id: int) -> int:
        with self._stats_lock:
            inst = self._inst_of_job.get(job_id)
        if inst is None:
            raise ValueError(f"job {job_id} has no live invocation")
        return inst

    def cancel(self, job_id: int) -> int:
        """Cancel a live invocation by job id: purge its queued kernels
        (its client unblocks with ``JobCancelled``), let in-flight
        kernels finish, record the terminal state. Returns the number of
        purged requests."""
        inst = self._live_instance(job_id)
        purged = self.engine.cancel(inst)
        if self.jobstore is not None:
            self.jobstore.record_state(job_id, _js.CANCELLED)
        return purged

    def pause(self, job_id: int) -> bool:
        """Pause a live invocation at its next kernel boundary; its
        client blocks on the paused kernel's Future until ``resume``."""
        inst = self._live_instance(job_id)
        landed = self.engine.pause(inst)
        if self.jobstore is not None:
            self.jobstore.record_state(job_id, _js.PAUSED)
        return landed

    def resume(self, job_id: int, device: Optional[int] = None) -> int:
        """Resume a paused invocation — on ``device``, or wherever the
        placement discipline elects now (cross-device migration)."""
        inst = self._live_instance(job_id)
        d = self.engine.resume(inst, device)
        if self.jobstore is not None:
            self.jobstore.record_state(job_id, _js.RUNNING)
        return d

    def drain(self, timeout: float = 10.0) -> bool:
        """Stop admitting, finish in-flight work, flush online epochs,
        checkpoint the store. Returns True when fully drained in time."""
        if self.engine is None:
            return True
        drained = self.engine.drain(timeout=timeout)
        if self.jobstore is not None:
            self.jobstore.snapshot_profiles(self.profiles)
            self.jobstore.checkpoint()
        return drained

    def status(self) -> dict:
        """Operator summary: job rows by state + engine counters +
        control-poller health + per-QoS-class admission stats. When a
        worker fleet has registered against the attached store, its
        rows (per-worker counters + states) ride along under
        ``workers`` — the aggregated view lives in
        ``repro.serving.workers.fleet_status``."""
        out = {"mode": self.mode.value,
               "devices": self.devices,
               "cancelled_invocations": self.cancelled_invocations,
               "rejected_controls": self.rejected_controls,
               "poller_deaths": self.poller_deaths,
               "poller_alive": (self._poller is not None
                                and self._poller.is_alive())}
        if self.admission is not None:
            out["admission"] = self.admission.stats()
        if self.jobstore is not None:
            jobs = self.jobstore.jobs()
            out["jobs"] = [{"job_id": j.job_id, "process": j.key.process,
                            "priority": j.priority, "state": j.state,
                            "completed": j.completed,
                            "n_kernels": j.n_kernels} for j in jobs]
            by_state: Dict[str, int] = {}
            for j in jobs:
                by_state[j.state] = by_state.get(j.state, 0) + 1
            out["by_state"] = by_state
            workers = self.jobstore.workers()
            if workers:
                out["workers"] = workers
        if self.engine is not None:
            out["fills"] = self.engine.fill_count
            out["steals"] = self.engine.steal_count
        return out

    def recover(self, services: List[InferenceService]) -> List[int]:
        """Re-run every incomplete invocation recorded in the store.

        Wall-clock payloads are live callables, so recovery here is
        INVOCATION-level at-least-once: each incomplete job's completion
        watermark resets and the invocation re-runs in full from its
        service definition (matched by ``TaskKey``) under its original
        job id. Invocations recorded ``done`` are never re-run — the
        exactly-once side of the contract. The simulator's
        ``SimScheduler.recover`` is the kernel-exact counterpart.
        Returns the recovered job ids (unknown keys are skipped)."""
        if self.jobstore is None:
            raise RuntimeError("recover() needs a jobstore attached")
        if self.engine is None or self._stopped:
            raise RuntimeError("recover() inside a start()/stop() window "
                               "only — the engine must be serving")
        by_key = {s.key: s for s in services}
        redone: List[int] = []
        for rec in self.jobstore.incomplete_jobs(include_paused=True):
            svc = by_key.get(rec.key)
            if svc is None:
                continue
            self.jobstore.reset_completions(rec.job_id)
            cl = svc.client(self.engine)
            self._invoke_one(cl, svc, deadline=rec.deadline,
                             job_id=rec.job_id)
            redone.append(rec.job_id)
        return redone
