"""Priority serving engine: hosts multiple model services on a node of
``devices=K`` serial device executors (default one) under the FIKIT
scheduler (the paper's cloud-serving deployment).

Lifecycle per the paper (Fig 3):
1. A new service is profiled: T exclusive measured runs -> SK/SG stats
   loaded into the scheduler (measurement phase).
2. All later invocations run in the sharing phase: kernel-ID identification
   only, priority queues + gap filling decide placement.

Any scheduling ``Mode`` can host the system: FIKIT (the paper), SHARING
(default GPU), EXCLUSIVE (serialized), or PREEMPT — kernel-boundary
preemptive sharing, where a lower-priority service's dispatches park in
the priority queues whenever any strictly-higher-priority invocation is
active (no gap filling). All modes share one decision core,
``repro.core.policy.FikitPolicy``; ``devices=K`` spreads invocations over
K device executors through ``repro.core.placement.PlacementLayer`` (device
election per invocation + idle-device work stealing), with one profile
store shared by all devices — a service is profiled once, scheduled
anywhere.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import jax

from repro.config import ModelConfig
from repro.core.client import HookClient
from repro.core.executor import WallClockEngine
from repro.core.profiler import ProfiledData, Profiler
from repro.core.scheduler import Mode
from repro.core.task import TaskKey
from repro.models import api
from repro.models.segmentation import SegmentedService


class InferenceService:
    """One hosted model + its priority + its profile state."""

    def __init__(self, cfg: ModelConfig, priority: int, batch: int = 1,
                 seq: int = 32, host_gap: float = 0.0, tail_gap: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.priority = priority
        self.key = TaskKey(cfg.name, (batch, seq))
        params = api.build_params(cfg, jax.random.key(seed))
        self.svc = SegmentedService(cfg, params, batch, seq,
                                    host_gap=host_gap, tail_gap=tail_gap)
        self.profiled = False

    def client(self, engine: WallClockEngine, identify: bool = True):
        return HookClient(engine, self.key, self.priority,
                          self.svc.segments, identify=identify)


class ServingSystem:
    """Owns the engine + profile store; runs measurement then sharing.

    ``discipline`` elects the device per invocation (placement);
    ``queue_discipline`` orders parked requests within each device's
    priority levels ("fifo" default / "sjf" / "edf"). Invocations may
    carry a relative ``deadline`` budget (seconds): it is tagged onto
    every kernel request (consulted by edf levels) and drives the
    ``deadline_misses``/``deadlines_tagged`` serving stats."""

    def __init__(self, mode: Mode = Mode.FIKIT, measure_runs: int = 5,
                 devices: int = 1, discipline: str = "least_loaded",
                 queue_discipline: str = "fifo", online_measure=False,
                 interference=None):
        """``online_measure`` (False / True / ``repro.core.online.
        OnlineConfig``) enables live SK/SG refinement during the sharing
        phase: every dispatched segment's device-time bracket feeds
        EMA-smoothed profile updates (committed in epochs), never-profiled
        services get cold-start provisional durations instead of being
        invisible to gap filling, and ``online_stats`` reports
        observation/commit/drift counters. Off (default) is the paper's
        strictly-offline two-phase behavior.

        ``interference`` (None / True / mapping /
        ``repro.core.interference.InterferenceModel``) enables
        interference-aware gap filling in the hosted engine; off (None,
        default) keeps scheduling bit-identical to interference-off."""
        self.profiles = ProfiledData()
        self.mode = mode
        self.measure_runs = measure_runs
        self.devices = devices
        self.discipline = discipline
        self.queue_discipline = queue_discipline
        self.online_measure = online_measure
        self.interference = interference
        self.engine: Optional[WallClockEngine] = None
        self.deadline_misses = 0
        self.deadlines_tagged = 0
        self._stats_lock = threading.Lock()
        self._final_online_stats: Optional[dict] = None

    def start(self) -> "ServingSystem":
        """Build + start a fresh engine. Clears any final-stats snapshot a
        previous start/stop cycle cached, so ``online_stats`` reflects THIS
        engine, not a stale restart leftover."""
        self._final_online_stats = None
        self.engine = WallClockEngine(
            self.mode, self.profiles, devices=self.devices,
            discipline=self.discipline,
            queue_discipline=self.queue_discipline,
            online=self.online_measure or None,
            interference=self.interference).start()
        return self

    def stop(self) -> None:
        self.engine.stop()
        if self.engine.online is not None and self.engine.online.config.enabled:
            self._final_online_stats = self.engine.online.stats()  # post-flush

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    @property
    def online_stats(self) -> Optional[dict]:
        """Online measurement counters: live while serving, the final
        (post-flush) snapshot after the context manager exits, None when
        ``online_measure`` is off."""
        if self._final_online_stats is not None:
            return self._final_online_stats
        if self.engine is not None:
            return self.engine.online_stats()
        return None

    # ------------------------------------------------------------ lifecycle
    def onboard(self, service: InferenceService) -> List[float]:
        """Measurement phase: T exclusive measured runs (paper: T in
        [10, 1000]); returns the measured-phase JCTs."""
        service.svc.warmup()
        prof = Profiler(service.key)
        jcts = []
        meas_engine = WallClockEngine(Mode.EXCLUSIVE).start()
        try:
            cl = HookClient(meas_engine, service.key, service.priority,
                            service.svc.segments)
            for _ in range(self.measure_runs):
                state = service.svc.make_input()
                _, jct = cl.measure_run(state, prof)
                jcts.append(jct)
        finally:
            meas_engine.stop()
        self.profiles.load(prof.statistics())
        service.profiled = True
        return jcts

    def invoke(self, service: InferenceService, n: int = 1,
               interval: float = 0.0,
               deadline: Optional[float] = None) -> List[float]:
        """n sharing-phase invocations; returns JCTs. ``deadline`` is a
        per-invocation completion budget in seconds; when given, every
        kernel request is deadline-tagged (edf levels order by it) and
        invocations finishing past the budget count into
        ``self.deadline_misses``."""
        assert self.engine is not None, "use as context manager"
        cl = service.client(self.engine)
        jcts = []
        for _ in range(n):
            state = service.svc.make_input()
            _, jct = cl.run(state, deadline=deadline)
            jcts.append(jct)
            if deadline is not None:
                with self._stats_lock:
                    self.deadlines_tagged += 1
                    if jct > deadline:
                        self.deadline_misses += 1
            if interval > 0:
                time.sleep(interval)
        return jcts

    def invoke_concurrent(self, plans) -> Dict[str, List[float]]:
        """plans: list of (name, service, n, interval, start_delay) tuples,
        optionally extended with a 6th ``deadline`` element (relative
        seconds per invocation). Runs each plan in its own client thread;
        returns JCTs per name."""
        assert self.engine is not None
        out: Dict[str, List[float]] = {}
        threads = []

        def runner(name, service, n, interval, delay, deadline=None):
            if delay > 0:
                time.sleep(delay)
            out[name] = self.invoke(service, n=n, interval=interval,
                                    deadline=deadline)

        for plan in plans:
            threads.append(threading.Thread(target=runner, args=plan))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return out
