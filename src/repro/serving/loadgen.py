"""Open-loop traffic generation + replay against the admission plane.

Open-loop means arrivals follow a pre-drawn schedule and NEVER wait for
completions — the serving-under-overload regime the closed-loop
``invoke_concurrent`` path cannot produce (a blocked client is implicit
backpressure). The generator draws Poisson processes, optionally
modulated by a diurnal rate curve (thinning), and the replayer feeds
the merged schedule to ``AdmissionPlane.submit`` from one feeder
thread, honoring inter-arrival times at a configurable speedup.

Used by ``benchmarks/bench_serving_load.py`` (≥10⁵ requests full scale)
and the ``repro.launch.serve load`` CLI verb.
"""
from __future__ import annotations

import math
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = ["Arrival", "poisson_arrivals", "diurnal_arrivals",
           "merge_schedules", "replay"]


@dataclass(frozen=True)
class Arrival:
    """One scheduled submit: time (s, schedule-relative), service, QoS
    class name, and an optional per-request relative deadline override."""
    t: float
    service: object
    qos: str
    deadline: object = None     # None = class default; use _UNSET semantics


def poisson_arrivals(rate: float, duration: float, service, qos: str,
                     rng: random.Random,
                     deadline=None) -> List[Arrival]:
    """Homogeneous Poisson arrivals at ``rate`` req/s over ``duration``
    seconds (exponential inter-arrival gaps)."""
    out: List[Arrival] = []
    t = rng.expovariate(rate) if rate > 0 else float("inf")
    while t < duration:
        out.append(Arrival(t, service, qos, deadline))
        t += rng.expovariate(rate)
    return out


def diurnal_arrivals(base_rate: float, duration: float, service, qos: str,
                     rng: random.Random, period: Optional[float] = None,
                     depth: float = 0.5, deadline=None) -> List[Arrival]:
    """Non-homogeneous Poisson arrivals with a sinusoidal "diurnal" rate
    ``base_rate * (1 + depth*sin(2πt/period))``, drawn by thinning
    against the peak rate. ``period`` defaults to the full duration (one
    day == one replay window); ``depth`` in [0, 1)."""
    if not 0 <= depth < 1:
        raise ValueError(f"diurnal depth must be in [0, 1), got {depth}")
    period = duration if period is None else period
    peak = base_rate * (1 + depth)
    out: List[Arrival] = []
    t = rng.expovariate(peak) if peak > 0 else float("inf")
    while t < duration:
        rate_t = base_rate * (1 + depth * math.sin(2 * math.pi * t / period))
        if rng.random() < rate_t / peak:       # thinning acceptance
            out.append(Arrival(t, service, qos, deadline))
        t += rng.expovariate(peak)
    return out


def merge_schedules(*schedules: Sequence[Arrival]) -> List[Arrival]:
    """Merge per-class schedules into one time-ordered replay tape."""
    merged: List[Arrival] = []
    for s in schedules:
        merged.extend(s)
    merged.sort(key=lambda a: a.t)
    return merged


@dataclass
class ReplayReport:
    offered: int = 0
    wall_s: float = 0.0
    schedule_s: float = 0.0
    tickets: List[object] = field(default_factory=list)
    lag_max_s: float = 0.0      # worst feeder lateness vs the schedule


def replay(plane, schedule: Sequence[Arrival], speed: float = 1.0,
           keep_tickets: bool = True,
           on_submit: Optional[Callable] = None) -> ReplayReport:
    """Feed ``schedule`` to ``plane.submit`` open-loop: each arrival is
    submitted at its scheduled time (compressed by ``speed``; 2.0 =
    twice as fast) regardless of what completed — exactly the sustained
    traffic an admission plane exists to absorb. Returns a report with
    the tickets (unless ``keep_tickets=False``; ``on_submit(arrival,
    ticket)`` still sees each one, e.g. to count outcomes online).

    The feeder catches up bursts without sleeping between already-due
    arrivals, and records its worst lateness so a bench can reject a
    replay whose feeder (not the plane) was the bottleneck."""
    rep = ReplayReport(schedule_s=(schedule[-1].t if schedule else 0.0))
    t0 = time.perf_counter()
    for a in schedule:
        due = t0 + a.t / speed
        now = time.perf_counter()
        if due > now:
            time.sleep(due - now)
        else:
            rep.lag_max_s = max(rep.lag_max_s, now - due)
        ticket = plane.submit(a.service, a.qos) if a.deadline is None \
            else plane.submit(a.service, a.qos, deadline=a.deadline)
        rep.offered += 1
        if keep_tickets:
            rep.tickets.append(ticket)
        if on_submit is not None:
            on_submit(a, ticket)
    rep.wall_s = time.perf_counter() - t0
    return rep


def wait_all(tickets: Sequence, timeout: float = 60.0) -> bool:
    """Wait until every ticket resolved; True if all made it in time."""
    deadline = time.monotonic() + timeout
    for t in tickets:
        left = deadline - time.monotonic()
        if left <= 0 or t.result(timeout=left) is None:
            return False
    return True


def feeder_thread(plane, schedule, speed: float = 1.0,
                  on_submit: Optional[Callable] = None
                  ) -> Tuple[threading.Thread, ReplayReport]:
    """Run ``replay`` on a background thread (the CLI's live mode);
    returns (started thread, report being filled in)."""
    rep = ReplayReport(schedule_s=(schedule[-1].t if schedule else 0.0))

    def _run():
        r = replay(plane, schedule, speed=speed, keep_tickets=True,
                   on_submit=on_submit)
        rep.offered, rep.wall_s = r.offered, r.wall_s
        rep.tickets, rep.lag_max_s = r.tickets, r.lag_max_s

    th = threading.Thread(target=_run, daemon=True, name="fikit-loadgen")
    th.start()
    return th, rep
