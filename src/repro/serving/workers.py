"""N engine workers draining ONE durable job store.

FIKIT's cloud framing assumes "always more task requests than the
number of GPU available": a single engine process is the bottleneck
long before the devices are. This module fans the serving path out to
N worker processes that share one ``JobStore`` file — the store is the
only coordination surface, exactly as the PR-7 ops plane intended.

The protocol, layer by layer:

- **Claiming** — ``JobStore.claim_jobs`` hands a worker a strict-
  priority batch of ``submitted`` jobs inside one ``BEGIN IMMEDIATE``
  transaction; two workers can never claim the same row.
- **Leases** — every claimed row carries ``owner`` + ``lease_expires``.
  A heartbeat thread renews them while the batch runs; if the worker
  dies, survivors ``reap_expired`` the rows back to ``submitted`` and
  the next claim re-runs exactly the remaining kernel suffix (the
  completion watermark survives — this IS the PR-7 recovery path, just
  triggered by a peer instead of a restart).
- **Sharding** — jobs are stamped with a ``qos`` shard key at submit
  time; a worker claims its own shards first and (optionally) STEALS
  from any shard when its own are empty, mirroring the placement
  layer's idle-device work stealing.
- **Equivalence pin** — a single worker claiming everything in one
  batch sorts the batch by job id, which is precisely
  ``JobStore.recovery_plan`` order: its decision trace is identical to
  ``SimScheduler.recover(store, mode).run()``. The differential suite
  holds this contract.

Workers run the pure-python scheduler core only (no JAX import — see
the lazy ``repro.serving.__init__``), so ``python -m
repro.serving.workers`` starts in milliseconds.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import threading
import time
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import jobstore as _js
from repro.core.faults import FaultPlan
from repro.core.jobstore import (JobRecord, coerce_store, spec_from_record,
                                 spec_to_obj)
from repro.core.policy import Mode
from repro.core.scheduler import SimScheduler, profile_tasks
from repro.core.task import TaskSpec

#: Coordination flags (``JobStore.set_flag`` namespace) the fleet obeys.
GO_FLAG = "workers_go"          # supervisor start gate (timing fairness)
STOP_FLAG = "workers_stop"      # graceful drain: finish batch, then exit


# --------------------------------------------------------------- wall sink
class _PacedStore:
    """Store proxy a worker's simulator writes through.

    Two jobs: (1) force every write's timestamp to WALL time (the
    virtual-time sim passes ``at=self.now``, which is meaningless across
    processes — fleet JCT stats subtract ``submitted_at`` stamped by a
    different clock); (2) optionally SLEEP ``pace_s`` per kernel
    completion, converting the virtual-time replay into wall-bounded
    work so multi-process goodput scaling is measurable. Everything
    else delegates to the wrapped store."""

    def __init__(self, store, pace_s: float = 0.0):
        self._store = store
        self._pace_s = pace_s

    def record_submit(self, job_id, key, priority, **kw):
        kw.pop("at", None)
        return self._store.record_submit(job_id, key, priority, **kw)

    def record_state(self, job_id, state, at=None):
        return self._store.record_state(job_id, state)

    def record_completion(self, job_id, seq, at=None):
        if self._pace_s > 0.0:
            time.sleep(self._pace_s)    # no store lock held while pacing
        return self._store.record_completion(job_id, seq)

    def snapshot_profiles(self, data, at=None):
        return self._store.snapshot_profiles(data)

    def __getattr__(self, name):
        return getattr(self._store, name)


# ------------------------------------------------------------------ worker
@dataclass
class WorkerConfig:
    """One engine worker's knobs.

    ``shards`` restricts claims to those qos shard keys (None = claim
    any); ``steal=True`` lets a sharded worker fall back to any-shard
    claims when its own shards are empty. ``pace_s`` is the per-kernel
    wall pacing the batch simulator runs under (0 = as fast as the
    store can write). ``drain_on_empty`` exits the claim loop once the
    store has nothing pending AND nothing leased; ``wait_go`` parks the
    worker on the supervisor's ``workers_go`` flag before the first
    claim so a fleet starts its clock together. ``fault_plan`` wires a
    scripted crash into the FIRST batch (test hook)."""
    worker_id: str = "w0"
    mode: Mode = Mode.FIKIT
    lease_s: float = 5.0
    heartbeat_s: float = 1.0
    poll_s: float = 0.05
    batch: int = 16
    shards: Optional[Tuple[str, ...]] = None
    steal: bool = True
    pace_s: float = 0.0
    drain_on_empty: bool = True
    wait_go: bool = False
    fault_plan: Optional[FaultPlan] = None


class EngineWorker:
    """One claim-run-repeat loop over a shared ``JobStore``.

    Each batch is executed by a real ``SimScheduler`` with the store
    attached, so the PR-7 write-order contract (write-ahead
    completions, terminal state last) holds per worker; the lease
    protocol extends it across workers."""

    def __init__(self, store, config: Optional[WorkerConfig] = None):
        self.store = coerce_store(store)
        self.cfg = config or WorkerConfig()
        self.last_sim: Optional[SimScheduler] = None
        self.jobs_done = 0
        self.kernels_done = 0
        self.steals = 0
        self.batches = 0
        self.lost_lease = False

    # ------------------------------------------------------------- loop
    def run(self) -> dict:
        """Drain the store; returns this worker's summary counters."""
        cfg, store = self.cfg, self.store
        store.register_worker(cfg.worker_id)
        if cfg.wait_go:
            while store.flag(GO_FLAG) is None:
                if store.flag(STOP_FLAG) is not None:
                    store.worker_update(cfg.worker_id, state="stopped")
                    return self.summary()
                time.sleep(0.005)
        try:
            while store.flag(STOP_FLAG) is None:
                store.reap_expired(by=cfg.worker_id)
                recs = store.claim_jobs(cfg.worker_id, limit=cfg.batch,
                                        lease_s=cfg.lease_s,
                                        shards=cfg.shards)
                stolen = 0
                if not recs and cfg.steal and cfg.shards is not None:
                    recs = store.claim_jobs(cfg.worker_id,
                                            limit=cfg.batch,
                                            lease_s=cfg.lease_s)
                    stolen = sum(1 for r in recs
                                 if r.qos not in cfg.shards)
                if not recs:
                    if (cfg.drain_on_empty and store.pending_jobs() == 0
                            and store.leased_jobs() == 0):
                        break
                    time.sleep(cfg.poll_s)
                    continue
                self._run_batch(recs, stolen)
        finally:
            store.worker_update(cfg.worker_id, state="stopped")
        return self.summary()

    def summary(self) -> dict:
        """This worker's lifetime counters, as the subprocess prints."""
        return {"worker_id": self.cfg.worker_id,
                "jobs_done": self.jobs_done,
                "kernels_done": self.kernels_done,
                "steals": self.steals, "batches": self.batches,
                "lost_lease": self.lost_lease}

    # ------------------------------------------------------------ batch
    def _run_batch(self, recs: List[JobRecord], stolen: int) -> None:
        """Run one claimed batch through a jobstore-wired simulator.

        The batch sorts by job id — ``recovery_plan`` order — which is
        what pins workers=1 trace-identical to the single-process
        ``SimScheduler.recover`` path."""
        cfg, store = self.cfg, self.store
        live = []
        for rec in sorted(recs, key=lambda r: r.job_id):
            if rec.remaining <= 0:      # claimed a fully-recorded job
                store.record_state(rec.job_id, _js.DONE)
                self.jobs_done += 1
                continue
            live.append(rec)
        if not live:
            return
        specs = [spec_from_record(r) for r in live]
        ids = [r.job_id for r in live]
        bases = [r.completed for r in live]
        profiled = store.load_profiles()
        if profiled is None:
            # no snapshot in the store: measure deterministically so
            # every worker computes the identical profile
            profiled = profile_tasks(specs, T=3, jitter=0.0,
                                     measurement_overhead=0.0)
        plan, self.cfg = cfg.fault_plan, replace(cfg, fault_plan=None)
        stop = threading.Event()
        beat = threading.Thread(target=self._heartbeat, args=(stop,),
                                daemon=True, name="fikit-lease-beat")
        beat.start()
        try:
            sim = SimScheduler(specs, cfg.mode, profiled=profiled,
                               jobstore=_PacedStore(store, cfg.pace_s),
                               job_ids=ids, seq_base=bases,
                               fault_plan=plan)
            sim.run()
        finally:
            stop.set()
            beat.join()
        self.last_sim = sim
        kernels = sum(len(s.kernels) for s in specs)
        self.jobs_done += len(live)
        self.kernels_done += kernels
        self.steals += stolen
        self.batches += 1
        store.worker_update(cfg.worker_id, jobs_done=len(live),
                            kernels_done=kernels, steals=stolen,
                            batches=1)

    def _heartbeat(self, stop: threading.Event) -> None:
        """Renew this worker's leases until the batch ends. A renewal
        that touches zero rows means a peer reaped the leases out from
        under us (heartbeat stalled past ``lease_s``) — recorded on
        ``lost_lease`` for the operator; the store's structural guards
        (``DuplicateCompletion``) stop conflicting writes."""
        while not stop.wait(self.cfg.heartbeat_s):
            if self.store.renew_leases(self.cfg.worker_id,
                                       lease_s=self.cfg.lease_s) == 0:
                self.lost_lease = True


# --------------------------------------------------------- admission seam
class SpecService:
    """Minimal service adapter: a replayable ``TaskSpec`` with the
    ``key``/``priority`` attributes the admission plane reads. What a
    store-backed fleet serves instead of a live JAX model."""

    def __init__(self, spec: TaskSpec):
        self.spec = spec
        self.key = spec.key
        self.priority = spec.priority

    def __repr__(self):
        return f"SpecService({self.key.process!r}, prio={self.priority})"


class StoreBackend:
    """Admission-plane dispatch backend over a ``JobStore``.

    ``AdmissionPlane(backend=...)`` routes admitted groups here instead
    of ``ServingSystem._invoke_async``: ``dispatch`` persists the
    group's spec as a ``submitted`` row stamped with its shard key, a
    watcher thread resolves the ticket callback when a worker drives
    the row terminal, and ``overloaded`` supplies per-worker
    backpressure — the claimable backlog is capped at
    ``per_worker_backlog`` times the number of live workers, so
    admission tightens when the fleet shrinks."""

    def __init__(self, store, *, per_worker_backlog: int = 64,
                 poll_s: float = 0.01, retry_after: float = 0.05):
        self.store = coerce_store(store)
        self.per_worker_backlog = per_worker_backlog
        self.poll_s = poll_s
        self.retry_after = retry_after
        self._watch: Dict[int, Callable] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def dispatch(self, service, on_done, deadline: Optional[float] = None,
                 shard: Optional[str] = None) -> int:
        """Persist one admitted invocation; returns its job id.
        ``on_done(jct, error)`` fires from the watcher thread with the
        store-observed JCT once a worker completes the row, or
        ``(None, None)`` if it was cancelled."""
        spec = service.spec
        jid = self.store.record_submit(
            None, spec.key, spec.priority, n_kernels=len(spec.kernels),
            spec=spec_to_obj(spec), deadline=deadline,
            state=_js.SUBMITTED, qos=shard)
        with self._lock:
            self._watch[jid] = on_done
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._watcher, daemon=True,
                    name="fikit-store-watch")
                self._thread.start()
        return jid

    def overloaded(self, shard: Optional[str] = None) -> Optional[float]:
        """Backpressure probe: seconds-to-retry hint when the (shard's)
        claimable backlog exceeds the live fleet's budget, else None."""
        live = sum(1 for w in self.store.workers()
                   if w["state"] == "running")
        limit = self.per_worker_backlog * max(1, live)
        backlog = self.store.pending_jobs(
            None if shard is None else [shard])
        return self.retry_after if backlog >= limit else None

    def _watcher(self) -> None:
        while not self._stop.wait(self.poll_s):
            with self._lock:
                watched = dict(self._watch)
            if not watched:
                continue
            for rec in self.store.jobs():
                cb = watched.get(rec.job_id)
                if cb is None or rec.state not in _js.TERMINAL_STATES:
                    continue
                with self._lock:
                    self._watch.pop(rec.job_id, None)
                if rec.state == _js.DONE:
                    cb(max(rec.updated_at - rec.submitted_at, 0.0), None)
                else:
                    cb(None, None)      # cancelled — counted like sync

    def close(self) -> None:
        """Stop the watcher thread (pending callbacks never fire)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def enqueue_specs(store, specs: Sequence[TaskSpec],
                  qos: Optional[object] = None) -> List[int]:
    """Persist ``specs`` as claimable rows (the non-admission path a
    bench or test uses to preload a fleet's queue). ``qos`` stamps the
    shard key: a string for all, or a callable ``spec -> key``."""
    store = coerce_store(store)
    ids = []
    for spec in specs:
        key = qos(spec) if callable(qos) else qos
        ids.append(store.record_submit(
            None, spec.key, spec.priority, n_kernels=len(spec.kernels),
            spec=spec_to_obj(spec), deadline=spec.deadline,
            state=_js.SUBMITTED, qos=key))
    return ids


# -------------------------------------------------------------- supervisor
@dataclass
class WorkerSupervisor:
    """Spawn and tend N worker subprocesses over one store file.

    Shard assignment mirrors the placement layer's election seam: with
    ``shard=True`` the store's distinct qos keys are partitioned
    round-robin across workers (worker i gets keys ``i::n``), each
    worker stealing from any shard once its own are empty; with
    ``shard=False`` every worker claims from the whole queue. The
    supervisor registers nothing itself — workers self-register — but
    it holds the start gate: workers launch with ``wait_go`` and only
    begin claiming when every fleet member is registered, so measured
    goodput excludes interpreter start-up."""
    path: str
    n: int = 2
    mode: str = "fikit"
    lease_s: float = 5.0
    heartbeat_s: float = 1.0
    batch: int = 16
    pace_s: float = 0.0
    shard: bool = False
    poll_s: float = 0.02
    procs: List[subprocess.Popen] = field(default_factory=list)
    t_go: Optional[float] = None

    def _shards_of(self, i: int, keys: List[str]) -> Optional[List[str]]:
        if not self.shard or not keys:
            return None
        mine = keys[i::self.n]
        return mine or keys         # more workers than shards: share all

    def start(self, timeout: float = 30.0) -> "WorkerSupervisor":
        """Launch the fleet, wait for every worker to register, then
        open the start gate. Raises on a worker failing to register."""
        from repro.core.jobstore import JobStore
        with JobStore(self.path) as store:
            store.clear_flag(GO_FLAG)
            store.clear_flag(STOP_FLAG)
            keys = store.shards()
        src_root = str(Path(__file__).resolve().parents[2])
        for i in range(self.n):
            cmd = [sys.executable, "-m", "repro.serving.workers",
                   "--jobstore", self.path, "--worker-id", f"w{i}",
                   "--mode", self.mode, "--lease", str(self.lease_s),
                   "--heartbeat", str(self.heartbeat_s),
                   "--batch", str(self.batch), "--pace", str(self.pace_s),
                   "--poll", str(self.poll_s), "--wait-go"]
            mine = self._shards_of(i, keys)
            if mine is not None:
                cmd += ["--shards", ",".join(mine)]
            import os
            env = dict(os.environ)
            env["PYTHONPATH"] = src_root + os.pathsep + env.get(
                "PYTHONPATH", "")
            self.procs.append(subprocess.Popen(
                cmd, env=env, stdout=subprocess.PIPE,
                stderr=subprocess.PIPE, text=True))
        deadline = time.monotonic() + timeout
        with JobStore(self.path) as store:
            while time.monotonic() < deadline:
                up = [w for w in store.workers()
                      if w["state"] == "running"]
                if len(up) >= self.n:
                    break
                if any(p.poll() not in (None, 0) for p in self.procs):
                    raise RuntimeError("worker died before registering: "
                                       + self._gather_errors())
                time.sleep(0.01)
            else:
                raise RuntimeError(f"{self.n} workers did not register "
                                   f"within {timeout}s")
            self.t_go = time.time()
            store.set_flag(GO_FLAG, "1")
        return self

    def _gather_errors(self) -> str:
        outs = []
        for p in self.procs:
            if p.poll() not in (None, 0):
                _, err = p.communicate()
                outs.append((err or "").strip()[-500:])
        return " | ".join(outs)

    def wait(self, timeout: float = 120.0) -> List[dict]:
        """Join every worker; returns their printed summaries. Raises
        if any worker exited non-zero (stderr attached)."""
        summaries = []
        deadline = time.monotonic() + timeout
        for p in self.procs:
            left = max(0.1, deadline - time.monotonic())
            out, err = p.communicate(timeout=left)
            if p.returncode != 0:
                raise RuntimeError(f"worker exited {p.returncode}: "
                                   f"{(err or '').strip()[-500:]}")
            summaries.append(json.loads(out.strip().splitlines()[-1]))
        return summaries

    def stop(self) -> None:
        """Graceful drain: set the stop flag (workers finish their
        current batch, then exit)."""
        from repro.core.jobstore import JobStore
        with JobStore(self.path) as store:
            store.set_flag(STOP_FLAG, "1")

    def kill(self) -> None:
        """Hard-stop any worker still running (test teardown)."""
        for p in self.procs:
            if p.poll() is None:
                p.kill()
                p.communicate()


# ------------------------------------------------------------ fleet status
def _pctl(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def fleet_status(store) -> dict:
    """Aggregate the fleet's view of one store: per-worker goodput
    (kernels/s over the worker's registered lifetime), per-class JCT
    percentiles over ``done`` jobs (wall seconds, submit to terminal),
    claimable/leased backlog, and total lease churn."""
    store = coerce_store(store)
    workers = []
    for w in store.workers():
        elapsed = max((w["last_heartbeat"] or 0.0)
                      - (w["started_at"] or 0.0), 1e-9)
        w = dict(w)
        w["goodput_kps"] = w["kernels_done"] / elapsed
        workers.append(w)
    classes: Dict[str, List[float]] = {}
    done = cancelled = 0
    for rec in store.jobs():
        if rec.state == _js.DONE:
            done += 1
            classes.setdefault(rec.qos or "-", []).append(
                max(rec.updated_at - rec.submitted_at, 0.0))
        elif rec.state == _js.CANCELLED:
            cancelled += 1
    per_class = {}
    for name, jcts in sorted(classes.items()):
        jcts.sort()
        per_class[name] = {
            "jobs": len(jcts),
            "jct_mean": sum(jcts) / len(jcts),
            "jct_p50": _pctl(jcts, 0.50), "jct_p99": _pctl(jcts, 0.99)}
    return {"workers": workers, "classes": per_class,
            "jobs_done": done, "jobs_cancelled": cancelled,
            "pending": store.pending_jobs(),
            "leased": store.leased_jobs(),
            "lease_churn": store.lease_churn()}


# -------------------------------------------------------------- entrypoint
def main(argv: Optional[Sequence[str]] = None) -> int:
    """Run ONE worker process against a store file; prints the summary
    counters as JSON on exit. This is what the supervisor spawns."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.workers",
        description="One FIKIT engine worker draining a shared job store")
    ap.add_argument("--jobstore", required=True)
    ap.add_argument("--worker-id", default="w0")
    ap.add_argument("--mode", default="fikit",
                    choices=[m.value for m in Mode])
    ap.add_argument("--lease", type=float, default=5.0)
    ap.add_argument("--heartbeat", type=float, default=1.0)
    ap.add_argument("--poll", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--pace", type=float, default=0.0)
    ap.add_argument("--shards", default=None,
                    help="comma-separated qos shard keys to claim first")
    ap.add_argument("--no-steal", action="store_true")
    ap.add_argument("--no-drain-on-empty", action="store_true",
                    help="poll forever instead of exiting when the "
                         "store empties (stop via the stop flag)")
    ap.add_argument("--wait-go", action="store_true")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="fault injection: hard-crash at this global "
                         "kernel boundary of the first batch")
    args = ap.parse_args(argv)
    shards = (tuple(s for s in args.shards.split(",") if s)
              if args.shards else None)
    plan = (FaultPlan(crash_at=args.crash_at, hard=True)
            if args.crash_at is not None else None)
    cfg = WorkerConfig(
        worker_id=args.worker_id, mode=Mode(args.mode),
        lease_s=args.lease, heartbeat_s=args.heartbeat,
        poll_s=args.poll, batch=args.batch, shards=shards,
        steal=not args.no_steal, pace_s=args.pace,
        drain_on_empty=not args.no_drain_on_empty,
        wait_go=args.wait_go, fault_plan=plan)
    from repro.core.jobstore import JobStore
    with JobStore(args.jobstore) as store:
        summary = EngineWorker(store, cfg).run()
    print(json.dumps(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
