"""Async admission plane for serving: the front door between open-loop
client traffic and the FIKIT engine.

The serving substrate (``ServingSystem`` over ``WallClockEngine`` +
``PlacementLayer``) schedules whatever reaches it, but until this layer
existed every request cost a parked client thread and an unbounded
engine queue — a thread-per-request toy. The admission plane makes the
front end explicit, per Strait's framing of priority-aware inference
serving (PAPERS.md):

- **QoS classes** (``QoSClass``): named per-tenant classes, each mapped
  onto a FIKIT priority level (0 = highest), with a bounded admission
  queue, an optional default SLO deadline budget, and a continuous-
  batching cap.
- **Backpressure**: a submit into a full class queue is REJECTED
  immediately (never silently dropped) with a ``retry_after`` hint;
  submits during drain/stop are rejected with the ``requeue`` signal,
  and tickets still queued at ``stop()`` resolve as REQUEUED — both
  tell a well-behaved client to resubmit rather than that the work
  failed.
- **SLO-aware shedding**: at dispatch time a request whose EDF deadline
  budget is already unmeetable (``now + predicted JCT > deadline``,
  predicted from an EMA of observed per-service JCTs, primeable from
  measurement-phase runs) is SHED before it wastes device time. A
  never-observed (cold) service is never shed.
- **Continuous batching**: the dispatcher coalesces consecutive queued
  invocations of the same service (same class, up to ``max_batch``)
  into ONE engine task stream — one ``task_begin``, one kernel-request
  sequence, one scheduler admission — and resolves every member ticket
  when the group completes. Under overload this multiplies goodput
  without touching the scheduler.

Dispatch is strict-priority: each pass serves the highest non-empty
class first, so a lower class can only be admitted while every higher
queue is empty. That makes the shed-ordering invariant — *no high-QoS
request is shed while a lower class is admitted* — structural; the
plane still counts ``priority_inversions`` (always 0) so the property
suite can pin it.

One dispatcher thread drives everything: launches go through
``ServingSystem._invoke_async`` -> ``HookClient.run_async`` ->
``WallClockEngine.submit(on_complete=...)``, so no thread ever parks on
a per-request Future. Admission OFF (``enabled=False``, or simply not
attaching a plane) leaves the direct ``invoke`` path byte-for-byte
untouched — pinned by the trace differential in
``tests/test_admission_plane.py``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["QoSClass", "AdmissionTicket", "AdmissionPlane",
           "DEFAULT_CLASSES", "SHARD_ROUTERS", "REJECTED", "SHED",
           "COMPLETED", "FAILED", "CANCELLED", "REQUEUED"]

#: ticket outcomes
REJECTED = "rejected"      # backpressure: bounded queue full / not admitting
SHED = "shed"              # SLO-aware: deadline budget already unmeetable
COMPLETED = "completed"    # ran to completion on the engine
FAILED = "failed"          # the invocation raised (payload/host-work error)
CANCELLED = "cancelled"    # an ops-plane cancel verb hit the invocation
REQUEUED = "requeued"      # still queued at stop(): resubmit later

_UNSET = object()

#: Pluggable shard routing for backend dispatch, mirroring the placement
#: layer's device-election seam: a router maps an admitted (service,
#: qos-class-name) pair to the shard key stamped on the dispatched job —
#: ``"qos"`` keeps each QoS class together (gold jobs land on gold
#: workers), ``"service"`` keeps each service's stream together (cache
#: affinity). Register more by name.
SHARD_ROUTERS: Dict[str, object] = {
    "qos": lambda service, qos: qos,
    "service": lambda service, qos: getattr(
        getattr(service, "key", None), "process", None) or str(service),
}


@dataclass(frozen=True)
class QoSClass:
    """One tenant class: FIKIT priority + admission bound + SLO budget.

    ``priority`` is the FIKIT level (0 = highest, the paper's Q0..Q9);
    ``queue_limit`` bounds the admission queue (backpressure trips past
    it); ``deadline`` is the class's default relative SLO budget in
    seconds (None = no deadline, never shed); ``max_batch`` caps how
    many same-service invocations coalesce into one task stream."""
    name: str
    priority: int
    queue_limit: int = 256
    deadline: Optional[float] = None
    max_batch: int = 8

    def __post_init__(self):
        if not 0 <= self.priority <= 9:
            raise ValueError(f"QoSClass {self.name!r}: priority "
                             f"{self.priority} outside the paper's Q0..Q9")
        if self.queue_limit < 1:
            raise ValueError(f"QoSClass {self.name!r}: queue_limit must "
                             f"be >= 1, got {self.queue_limit}")
        if self.max_batch < 1:
            raise ValueError(f"QoSClass {self.name!r}: max_batch must "
                             f"be >= 1, got {self.max_batch}")


DEFAULT_CLASSES: Tuple[QoSClass, ...] = (
    QoSClass("gold", priority=0, queue_limit=64, max_batch=4),
    QoSClass("silver", priority=2, queue_limit=256, max_batch=8),
    QoSClass("bronze", priority=5, queue_limit=1024, max_batch=16),
)


class AdmissionTicket:
    """The client's handle on one admitted (or refused) invocation.

    Resolves exactly once; ``result(timeout)`` blocks until then and
    returns the outcome string. Rejections resolve synchronously inside
    ``submit`` — ``retry_after`` then estimates (seconds) when capacity
    should free up, and ``requeue`` is True when the refusal is a
    transient not-admitting signal (drain/stop) rather than overload."""

    __slots__ = ("service", "qos", "arrival", "deadline", "outcome",
                 "jct", "latency", "error", "retry_after", "requeue",
                 "batch_size", "_event")

    def __init__(self, service, qos: str, arrival: float,
                 deadline: Optional[float]):
        self.service = service
        self.qos = qos
        self.arrival = arrival
        self.deadline = deadline       # absolute, plane clock; None = no SLO
        self.outcome: Optional[str] = None
        self.jct: Optional[float] = None
        self.latency: Optional[float] = None   # resolve time - arrival
        self.error: Optional[BaseException] = None
        self.retry_after: Optional[float] = None
        self.requeue = False
        self.batch_size = 0
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Optional[str]:
        """Block until resolved (or ``timeout``); returns the outcome,
        or None when the timeout expired first."""
        self._event.wait(timeout)
        return self.outcome

    def _resolve(self, outcome: str, now: float, jct=None, error=None,
                 retry_after=None, requeue=False) -> None:
        self.outcome = outcome
        self.jct = jct
        self.latency = now - self.arrival
        self.error = error
        self.retry_after = retry_after
        self.requeue = requeue
        self._event.set()

    def __repr__(self):
        return (f"AdmissionTicket({self.qos}, outcome={self.outcome}, "
                f"batch={self.batch_size})")


class _ClassState:
    """Per-class queue + conservation counters + latency samples."""

    __slots__ = ("cls", "queue", "offered", "admitted", "rejected",
                 "shed", "requeued", "completed", "failed", "cancelled",
                 "in_deadline", "latencies")

    def __init__(self, cls: QoSClass):
        self.cls = cls
        self.queue: deque = deque()
        self.offered = 0
        self.admitted = 0      # handed to the engine
        self.rejected = 0      # backpressure (queue full / not admitting)
        self.shed = 0          # deadline unmeetable at dispatch
        self.requeued = 0      # still queued at stop()
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.in_deadline = 0   # completed within their SLO budget
        self.latencies: List[float] = []


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted sample."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return sorted_vals[i]


class AdmissionPlane:
    """The admission front-end over one ``ServingSystem``.

    ``system`` only needs ``_invoke_async(service, on_done, deadline=)``
    (and ``invoke`` for the wired-but-disabled fall-through), so tests
    drive the plane against a stub system deterministically.

    ``max_inflight`` bounds concurrently-running task GROUPS (batched
    invocations count once) — the knob that creates queueing, and hence
    backpressure and shedding, under overload. ``dispatcher=False``
    skips the background thread; callers then ``pump()`` manually (the
    deterministic mode the property tests use). ``record_events=True``
    keeps an append-only decision log of (seq, action, class, ...)
    tuples for invariant checking.

    **Conservation invariant** (the plane's load-bearing contract,
    pinned by the property suite and the ``require_conservation`` bench
    gate): every offered request resolves exactly one way, per class —

        offered == admitted + rejected + shed + requeued

    and, once the plane has stopped,

        admitted == completed + failed + cancelled

    No path may drop a ticket silently or resolve it twice; anything
    that admits, rejects, sheds, or requeues MUST bump exactly one
    counter under ``_lock`` and resolve the ticket exactly once.
    ``stats()`` exposes the counters; code that adds a new outcome must
    extend both equations or the conservation checks go red."""

    def __init__(self, system, classes: Sequence[QoSClass] = None,
                 max_inflight: int = 4, clock=time.perf_counter,
                 enabled: bool = True, dispatcher: bool = True,
                 record_events: bool = False, ema_alpha: float = 0.3,
                 backend=None, shard_by: str = "qos"):
        classes = tuple(DEFAULT_CLASSES if classes is None else classes)
        if not classes:
            raise ValueError("AdmissionPlane needs at least one QoSClass")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate QoS class names: {names}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if shard_by not in SHARD_ROUTERS:
            raise ValueError(f"unknown shard router {shard_by!r} "
                             f"(have {sorted(SHARD_ROUTERS)})")
        self._system = system
        #: dispatch backend: None routes launches to the in-process
        #: engine (``system._invoke_async``, the default path — kept
        #: bit-identical); an object with ``dispatch(service, on_done,
        #: deadline=, shard=)`` + ``overloaded(shard)`` (e.g.
        #: ``repro.serving.workers.StoreBackend``) persists them for a
        #: worker fleet instead, with per-worker backpressure folded
        #: into admission.
        self._backend = backend
        self._shard_of = SHARD_ROUTERS[shard_by]
        # strict-priority dispatch order: highest QoS (lowest level) first
        self.classes = tuple(sorted(classes,
                                    key=lambda c: (c.priority, c.name)))
        self._states = [_ClassState(c) for c in self.classes]
        self._by_name = {c.cls.name: c for c in self._states}
        self.max_inflight = max_inflight
        self.clock = clock
        self.enabled = enabled
        self.ema_alpha = ema_alpha
        self._ema: Dict[object, float] = {}     # service.key -> EMA JCT (s)
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._inflight = 0
        self._draining = False
        self._stopping = False
        self._started = False
        self.priority_inversions = 0    # must stay 0: pinned by tests
        self.record_events = record_events
        self.events: List[tuple] = []
        self._event_seq = 0
        self._thread = (threading.Thread(target=self._run, daemon=True,
                                         name="fikit-admission")
                        if (dispatcher and enabled) else None)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "AdmissionPlane":
        if self._thread is not None and not self._started:
            self._thread.start()
        self._started = True
        return self

    def drain(self, timeout: float = 10.0) -> bool:
        """Stop admitting (submits reject with the requeue signal), keep
        dispatching until every queue is empty and nothing is in flight.
        Returns True when fully drained within ``timeout``."""
        with self._cond:
            self._draining = True
            self._cond.notify_all()
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self._thread is None:
                self.pump()                      # manual mode drains inline
            with self._lock:
                if self._inflight == 0 and not any(s.queue
                                                   for s in self._states):
                    return True
            time.sleep(0.002)
        return False

    def stop(self) -> None:
        """Stop the dispatcher; tickets still queued resolve REQUEUED (a
        resubmit-later signal, not a failure). Idempotent."""
        with self._cond:
            if self._stopping:
                return
            self._stopping = True
            self._cond.notify_all()
        if self._thread is not None and self._started:
            self._thread.join(timeout=5)
        now = self.clock()
        leftovers = []
        with self._lock:
            for st in self._states:
                while st.queue:
                    t = st.queue.popleft()
                    st.requeued += 1
                    self._log("requeue", st.cls.name)
                    leftovers.append(t)
        for t in leftovers:
            t._resolve(REQUEUED, now, requeue=True)

    # --------------------------------------------------------------- intake
    def submit(self, service, qos: str, deadline=_UNSET,
               arrival: Optional[float] = None) -> AdmissionTicket:
        """Offer one invocation of ``service`` under class ``qos``.

        Returns immediately with a ticket: queued for dispatch, or
        already resolved REJECTED (queue full -> ``retry_after`` hint;
        draining/stopped -> ``requeue=True``). ``deadline`` overrides
        the class's default SLO budget (relative seconds; None = no
        deadline); ``arrival`` backdates the offered time (trace
        replay)."""
        try:
            st = self._by_name[qos]
        except KeyError:
            raise ValueError(f"unknown QoS class {qos!r} "
                             f"(have {sorted(self._by_name)})") from None
        now = self.clock() if arrival is None else arrival
        rel = st.cls.deadline if deadline is _UNSET else deadline
        abs_deadline = None if rel is None else now + rel
        t = AdmissionTicket(service, st.cls.name, now, abs_deadline)
        if not self.enabled:
            return self._submit_passthrough(st, t, rel)
        retry = (None if self._backend is None else
                 self._backend.overloaded(self._shard_of(service,
                                                         st.cls.name)))
        with self._cond:
            st.offered += 1
            if self._stopping or self._draining:
                st.rejected += 1
                self._log("reject", st.cls.name, "not-admitting")
                t._resolve(REJECTED, self.clock(), requeue=True)
            elif retry is not None:
                # per-worker backpressure: the backend's claimable
                # backlog already exceeds the live fleet's budget
                st.rejected += 1
                self._log("reject", st.cls.name, "backend-overloaded")
                t._resolve(REJECTED, self.clock(), retry_after=retry)
            elif len(st.queue) >= st.cls.queue_limit:
                st.rejected += 1
                self._log("reject", st.cls.name, "queue-full")
                t._resolve(REJECTED, self.clock(),
                           retry_after=self._retry_after(st))
            else:
                st.queue.append(t)
                self._cond.notify_all()
        return t

    def _submit_passthrough(self, st: _ClassState, t: AdmissionTicket,
                            rel: Optional[float]) -> AdmissionTicket:
        """Wired-but-disabled: the direct blocking ``invoke`` path, so
        the engine sees EXACTLY the no-plane call sequence (the trace
        differential contract). Only counters differ — and they live in
        the plane, not the engine."""
        with self._lock:
            st.offered += 1
            st.admitted += 1
        try:
            jcts = self._system.invoke(t.service, n=1, deadline=rel)
        except BaseException as e:
            with self._lock:
                st.failed += 1
            t._resolve(FAILED, self.clock(), error=e)
            return t
        now = self.clock()
        with self._lock:
            if jcts:
                st.completed += 1
                st.latencies.append(now - t.arrival)
                if t.deadline is None or now <= t.deadline:
                    st.in_deadline += 1
            else:
                st.cancelled += 1
        t._resolve(COMPLETED if jcts else CANCELLED, now,
                   jct=jcts[0] if jcts else None)
        return t

    def _retry_after(self, st: _ClassState) -> Optional[float]:
        """Backpressure hint: rough seconds until this class's queue
        should have space, from the observed service-time EMA."""
        ema = self._ema.get(getattr(st.queue[0].service, "key", None)) \
            if st.queue else None
        if ema is None and self._ema:
            ema = sum(self._ema.values()) / len(self._ema)
        if ema is None:
            return None
        groups = max(1, len(st.queue) // st.cls.max_batch)
        return groups * ema / self.max_inflight

    # ------------------------------------------------------------- dispatch
    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._stopping and not self._work_ready():
                    self._cond.wait(timeout=0.05)
                if self._stopping:
                    return
                groups = self._collect_groups()
            for st, members in groups:
                self._launch_group(st, members)

    def pump(self) -> int:
        """Manual dispatch (no dispatcher thread): run passes until no
        group launches; returns how many invocations were admitted.
        Deterministic — the property tests' entry point."""
        admitted = 0
        while True:
            with self._lock:
                groups = self._collect_groups()
            if not groups:
                return admitted
            for st, members in groups:
                admitted += len(members)
                self._launch_group(st, members)

    def _work_ready(self) -> bool:
        return (self._inflight < self.max_inflight
                and any(s.queue for s in self._states))

    def _collect_groups(self):
        """One strict-priority dispatch pass (lock held): pop batches
        from the highest non-empty class, shedding hopeless members,
        until the in-flight cap is reached. Returns launchable groups."""
        groups = []
        while self._inflight < self.max_inflight:
            st = next((s for s in self._states if s.queue), None)
            if st is None:
                break
            higher_queued = 0
            for s in self._states:
                if s is st:
                    break
                higher_queued += len(s.queue)
            now = self.clock()
            head = st.queue[0]
            members: List[AdmissionTicket] = []
            sheds: List[AdmissionTicket] = []
            while (st.queue and len(members) < st.cls.max_batch
                   and st.queue[0].service is head.service):
                t = st.queue.popleft()
                if self._hopeless(t, now):
                    st.shed += 1
                    self._log("shed", st.cls.name, "deadline-unmeetable",
                              higher_queued)
                    sheds.append(t)
                else:
                    members.append(t)
            for t in sheds:
                t._resolve(SHED, now)
            if not members:
                continue                     # everything popped was shed
            if higher_queued:                # structurally impossible:
                self.priority_inversions += 1   # strict-priority scan
            st.admitted += len(members)
            self._inflight += 1
            for t in members:
                t.batch_size = len(members)
            self._log("admit", st.cls.name, len(members), higher_queued)
            groups.append((st, members))
        return groups

    def _hopeless(self, t: AdmissionTicket, now: float) -> bool:
        """SLO-aware shed rule: the EDF budget is already unmeetable.
        Cold services (no observed JCT yet) are never shed."""
        if t.deadline is None:
            return False
        predicted = self._ema.get(getattr(t.service, "key", None))
        if predicted is None:
            return False
        return now + predicted > t.deadline

    def _launch_group(self, st: _ClassState, members) -> None:
        """Hand one coalesced group to the engine as a single task
        stream; the earliest member deadline governs EDF ordering."""
        deadlines = [t.deadline for t in members if t.deadline is not None]
        rel = None
        if deadlines:
            rel = max(0.0, min(deadlines) - self.clock())
        def cb(jct, error):
            self._group_done(st, members, jct, error)
        if self._backend is not None:
            self._backend.dispatch(
                members[0].service, cb, deadline=rel,
                shard=self._shard_of(members[0].service, st.cls.name))
        else:
            self._system._invoke_async(members[0].service, cb,
                                       deadline=rel)

    def _group_done(self, st: _ClassState, members, jct, error) -> None:
        """Completion callback (device thread, no engine lock): resolve
        every member ticket, learn the service-time EMA, free the
        in-flight slot, wake the dispatcher."""
        now = self.clock()
        key = getattr(members[0].service, "key", None)
        with self._cond:
            self._inflight -= 1
            for t in members:
                if error is None and jct is not None:
                    st.completed += 1
                    st.latencies.append(now - t.arrival)
                    if t.deadline is None or now <= t.deadline:
                        st.in_deadline += 1
                elif jct is None and error is None:
                    st.cancelled += 1
                else:
                    st.failed += 1
            if jct is not None and key is not None:
                prev = self._ema.get(key)
                self._ema[key] = (jct if prev is None else
                                  self.ema_alpha * jct
                                  + (1 - self.ema_alpha) * prev)
            self._cond.notify_all()
        for t in members:
            if error is None and jct is not None:
                t._resolve(COMPLETED, now, jct=jct)
            elif jct is None and error is None:
                t._resolve(CANCELLED, now)
            else:
                t._resolve(FAILED, now, error=error)

    # ---------------------------------------------------------------- intro
    def note_latency(self, service, jct: float) -> None:
        """Prime (or update) the service-time EMA — e.g. from the
        measurement phase's exclusive JCTs, so shedding is SLO-aware
        from the first sharing-phase request."""
        key = getattr(service, "key", None)
        if key is None:
            return
        with self._lock:
            prev = self._ema.get(key)
            self._ema[key] = (jct if prev is None else
                              self.ema_alpha * jct
                              + (1 - self.ema_alpha) * prev)

    def predicted_jct(self, service) -> Optional[float]:
        with self._lock:
            return self._ema.get(getattr(service, "key", None))

    def _log(self, action: str, cls: str, *detail) -> None:
        if self.record_events:
            self.events.append((self._event_seq, action, cls) + detail)
            self._event_seq += 1

    def stats(self) -> dict:
        """Per-class conservation counters + latency percentiles +
        goodput, plus the plane-wide invariant counters."""
        with self._lock:
            out = {
                "enabled": self.enabled,
                "inflight": self._inflight,
                "priority_inversions": self.priority_inversions,
                "classes": {},
            }
            for st in self._states:
                lat = sorted(st.latencies)
                offered = st.offered
                out["classes"][st.cls.name] = {
                    "priority": st.cls.priority,
                    "offered": offered,
                    "admitted": st.admitted,
                    "rejected": st.rejected,
                    "shed": st.shed,
                    "requeued": st.requeued,
                    "completed": st.completed,
                    "failed": st.failed,
                    "cancelled": st.cancelled,
                    "queued": len(st.queue),
                    "p50_ms": 1e3 * _percentile(lat, 0.50),
                    "p99_ms": 1e3 * _percentile(lat, 0.99),
                    "mean_ms": (1e3 * sum(lat) / len(lat)) if lat else 0.0,
                    "goodput": (st.in_deadline / offered) if offered else 0.0,
                }
            return out


def coerce_admission(spec):
    """Normalize ``ServingSystem(admission=)``: None -> None (plane
    absent, the pre-admission serving system), True -> default classes,
    a QoSClass sequence -> those classes, a dict -> ``AdmissionPlane``
    kwargs (``classes``/``max_inflight``/``enabled``/...). Returns the
    kwargs dict for the plane constructor, or None."""
    if spec is None:
        return None
    if spec is True:
        return {}
    if isinstance(spec, QoSClass):
        return {"classes": (spec,)}
    if isinstance(spec, dict):
        return dict(spec)
    if isinstance(spec, (list, tuple)):
        return {"classes": tuple(spec)}
    raise TypeError(f"admission= expects None/True/QoSClass(es)/dict, "
                    f"got {spec!r}")
