"""Online kernel measurement: live SK/SG refinement during sharing-mode
execution.

The paper's measurement phase is exclusive and expensive (Fig 15: +34.5%
to +71.8% JCT), which forces a strictly-offline two-phase design — profile
once, ``load()`` at startup, never learn again. A serving system under
shifting traffic needs the opposite (cf. Tally's non-intrusive online
measurement of concurrent DL kernels, and Strait's case for perceiving
interference live): every ``kernel_end`` the engines already observe IS a
free duration sample, and the launch-to-launch spacing of one task's
stream is a (noisy) gap sample. ``OnlineMeasurement`` turns those samples
into EMA-smoothed SK/SG updates without a measurement phase, while
staying inside FIKIT's <5% sharing-stage overhead budget (Fig 14):

- **Observation is O(1)**: a dict upsert per kernel completion, no
  timing calls of its own (the engines pass the start/end they already
  have — the sim's virtual timeline, the wall-clock device thread's
  ``perf_counter`` brackets).
- **Commits are batched in epochs** — every ``epoch_observations``
  samples or ``epoch_seconds`` seconds, whichever comes first — because
  each ``ProfiledData.version`` bump invalidates the priority queues'
  duration index and triggers a full O(n log n) rebuild on the next
  decision (``repro.core.queues`` lazy binding). Per-event commits would
  put that rebuild on every completion; per-epoch commits amortize it to
  noise.
- **Per-device buffers, merged on commit**: each device's observations
  accumulate independently (the placement layer tags the device), and one
  commit folds all of them into the shared ``ProfiledData`` — one version
  bump per dirty TaskKey per epoch, regardless of device count.
- **Cold start**: ``ProfiledData(cold_start=True)`` serves provisional
  durations for never-profiled kernels (per-TaskKey mean SK, then the
  global mean) instead of the ``-1.0`` sentinel, so a cold task is
  gap-fillable immediately and its real profile converges online.
- **Drift counters**: every observation with a strict (non-cold)
  prediction accrues observed-vs-predicted error, surfaced via
  ``stats()`` into ``SimReport.online_stats`` and the serving stats — the
  signal that a loaded profile has gone stale.
- **Interference coefficients** (optional, an attached
  ``repro.core.interference.InterferenceModel``): the policy tags every
  interference-scored fill launch with its (holder, filler) class pair
  (``note_fill_pair``); when the filler's completion is observed, the
  observed/predicted duration ratio becomes a slowdown sample for that
  pair, EMA-committed into the model in the SAME epochs as SK/SG, and
  the duration sample itself is DE-RATED by the pair's current
  coefficient before entering the SK buffers (so contended fills don't
  inflate the uncontended SK estimate).

The standing contract: with online measurement OFF (``online=None`` /
``OnlineConfig(enabled=False)``) nothing in this module runs and decision
traces are bit-identical to the pre-online implementation — pinned by the
randomized differential suites. With it ON, scheduling decisions may
differ (that is the point), but every safety invariant (fill below
holder, stream order, conservation) still holds — pinned by the
hypothesis suites.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.kernel_id import KernelID
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.task import TaskKey


@dataclass
class OnlineConfig:
    """Tuning for the online measurement loop.

    ``ema_alpha`` weights the newest epoch's batch mean against the
    standing SK/SG value (1.0 = always trust the latest epoch, small =
    long memory). ``epoch_observations``/``epoch_seconds`` bound how stale
    the committed profile may get — an epoch commits when EITHER
    threshold is crossed. ``cold_start`` switches the bound
    ``ProfiledData`` to provisional predictions for unprofiled kernels.
    ``enabled=False`` constructs the subsystem but never observes or
    commits — the wired-but-off configuration the differential suite pins
    bit-identical to no subsystem at all."""
    enabled: bool = True
    ema_alpha: float = 0.25
    epoch_observations: int = 64
    epoch_seconds: float = 1.0
    cold_start: bool = True

    @staticmethod
    def coerce(spec) -> Optional["OnlineConfig"]:
        """Normalize the engines' ``online=`` argument: None/False -> None
        (subsystem not built), True -> defaults, a config -> itself."""
        if spec is None or spec is False:
            return None
        if spec is True:
            return OnlineConfig()
        if isinstance(spec, OnlineConfig):
            return spec
        raise TypeError(f"online= expects None/bool/OnlineConfig, "
                        f"got {spec!r}")


class _DeviceBuffer:
    """One device's pending (uncommitted) observations."""

    __slots__ = ("dur", "gap", "observations")

    def __init__(self):
        # (TaskKey, KernelID) -> [sum, count]
        self.dur: Dict[Tuple[TaskKey, KernelID], List[float]] = {}
        self.gap: Dict[Tuple[TaskKey, KernelID], List[float]] = {}
        self.observations = 0

    def add_dur(self, key, kid, v: float) -> None:
        s = self.dur.get((key, kid))
        if s is None:
            self.dur[(key, kid)] = [v, 1]
        else:
            s[0] += v
            s[1] += 1
        self.observations += 1

    def add_gap(self, key, kid, v: float) -> None:
        s = self.gap.get((key, kid))
        if s is None:
            self.gap[(key, kid)] = [v, 1]
        else:
            s[0] += v
            s[1] += 1


class OnlineMeasurement:
    """Observes sharing-mode kernel completions; commits EMA-smoothed
    SK/SG updates into a ``ProfiledData`` in epochs.

    Drivers call:

    - ``observe(device, instance, key, kid, start, end, last=...)`` on
      every kernel completion (the placement layer does this for both
      engines);
    - ``observe_gap_error(predicted, actual)`` when the policy opens a
      gap with a known actual (the sim's feedback path) — pure drift
      accounting, no profile update;
    - ``task_gone(instance)`` when a task retires (drops the
      gap-attribution anchor);
    - ``commit()`` to force the pending epoch out (engines flush on
      shutdown so short runs still learn).

    Thread safety follows the engines': the wall-clock engine calls every
    entry point under its policy lock; the simulator is single-threaded.
    """

    def __init__(self, profiled: ProfiledData,
                 config: Optional[OnlineConfig] = None,
                 clock: Callable[[], float] = lambda: 0.0,
                 interference=None):
        self.profiled = profiled
        self.config = config or OnlineConfig()
        self._clock = clock
        self.interference = (interference if interference is not None
                             and getattr(interference, "enabled", False)
                             else None)
        if self.config.cold_start and self.config.enabled:
            profiled.enable_cold_start()
        self._buffers: Dict[int, _DeviceBuffer] = {}
        # instance -> (device, key, kid, end) of its last observed kernel,
        # anchoring the launch-to-launch gap sample for THAT kid
        self._last: Dict[int, Tuple[int, TaskKey, KernelID, float]] = {}
        # (instance, kid) -> FIFO of (holder_class, filler_class) tags for
        # in-flight interference-scored fills awaiting their completion
        self._pending_pairs: Dict[Tuple[int, KernelID], List] = {}
        # (holder_class, filler_class) -> [ratio_sum, count] this epoch
        self._pair_pending: Dict[Tuple[str, str], List[float]] = {}
        self._epoch_obs = 0
        self._last_commit: Optional[float] = None
        # counters (monotonic, surfaced via stats())
        self.observations = 0
        self.gap_observations = 0
        self.commits = 0
        self.committed_keys = 0
        self.cold_observations = 0
        self.drift_obs = 0
        self.drift_abs_sum = 0.0
        self.drift_pred_sum = 0.0
        self.gap_drift_obs = 0
        self.gap_drift_abs_sum = 0.0
        self.interference_pair_obs = 0
        self.interference_updates = 0

    # ------------------------------------------------------------ observing
    def observe(self, device: int, instance: int, key: TaskKey,
                kid: KernelID, start: float, end: float, *,
                last: bool = False) -> bool:
        """Record one completed kernel. Returns True iff this observation
        closed an epoch (a commit happened)."""
        if not self.config.enabled:
            return False
        now = self._clock()
        if self._last_commit is None:
            self._last_commit = now
        buf = self._buffers.get(device)
        if buf is None:
            buf = self._buffers[device] = _DeviceBuffer()
        dur = max(0.0, end - start)
        # interference attribution: was this completion a fill the policy
        # scored with a class pair? (FIFO tag matching per (instance, kid);
        # with max_inflight > 1 and repeated kids a tag can land on the
        # wrong occurrence of the same kernel — accepted EMA noise, the
        # durations are statistically exchangeable)
        pair = None
        tags = self._pending_pairs.get((instance, kid))
        if tags:
            pair = tags.pop(0)
            if not tags:
                del self._pending_pairs[(instance, kid)]
        pred = self.profiled.predict_duration_raw(key, kid)
        sk_dur = dur
        if pair is not None and self.interference is not None:
            if pred > 0.0 and dur > 0.0:
                # observed slowdown sample for this class pair
                p = self._pair_pending.setdefault(pair, [0.0, 0])
                p[0] += dur / pred
                p[1] += 1
                self.interference_pair_obs += 1
            # de-rate the contended sample back to an uncontended SK
            # estimate using the model's current belief
            sk_dur = dur / max(1.0, self.interference.coeff(*pair))
        buf.add_dur(key, kid, sk_dur)
        self.observations += 1
        self._epoch_obs += 1
        # drift: compare against the STRICT prediction (no cold estimate),
        # so cold kernels count as cold, not as infinitely wrong
        if pred >= 0.0:
            self.drift_obs += 1
            self.drift_abs_sum += abs(sk_dur - pred)
            self.drift_pred_sum += pred
        else:
            self.cold_observations += 1
        # gap attribution: device idle between consecutive kernels of ONE
        # stream approximates the host gap after the PREVIOUS kernel (the
        # same bracketing measure_run uses, under sharing noise — fillers
        # occupying the gap inflate the sample; EMA + epochs smooth it).
        # A negative raw gap (overlapping brackets — wall-clock callback
        # jitter, or a stale anchor) is SKIPPED, not clamped: a fabricated
        # 0.0 sample would drag the SG estimate toward zero.
        prev = self._last.get(instance)
        if prev is not None and prev[0] == device:
            gap = start - prev[3]
            if gap >= 0.0:
                buf.add_gap(prev[1], prev[2], gap)
                self.gap_observations += 1
        if last:
            self._last.pop(instance, None)
        else:
            self._last[instance] = (device, key, kid, end)
        if (self._epoch_obs >= self.config.epoch_observations
                or now - self._last_commit >= self.config.epoch_seconds):
            self.commit(now)
            return True
        return False

    def observe_gap_error(self, predicted: float, actual: float) -> None:
        """Drift accounting for the policy's SG predictions (paper Fig 12
        feedback path): no profile update, just observed-vs-predicted."""
        if not self.config.enabled:
            return
        self.gap_drift_obs += 1
        self.gap_drift_abs_sum += abs(actual - predicted)

    def note_fill_pair(self, instance: int, kid: KernelID,
                       holder_class: str, filler_class: str) -> None:
        """Tag an interference-scored fill launch with its class pair so
        the eventual completion's duration can be attributed (called by
        the policy at fill-launch time)."""
        if not self.config.enabled:
            return
        self._pending_pairs.setdefault((instance, kid), []).append(
            (holder_class, filler_class))

    def task_gone(self, instance: int) -> None:
        """Drop the gap anchor — and any in-flight fill tags — of a
        retired/migrated task. The placement layer calls this BEFORE a
        steal detaches the task, so a cross-device launch can never be
        attributed against the old device's timeline."""
        self._last.pop(instance, None)
        if self._pending_pairs:
            stale = [k for k in self._pending_pairs if k[0] == instance]
            for k in stale:
                del self._pending_pairs[k]

    # ------------------------------------------------------------ committing
    def commit(self, now: Optional[float] = None) -> int:
        """Fold every device's pending observations into the shared
        ``ProfiledData`` (one ``load()`` — one version bump — per dirty
        TaskKey). Returns the number of TaskKeys updated."""
        if not self.config.enabled:
            return 0
        alpha = self.config.ema_alpha
        merged_dur: Dict[Tuple[TaskKey, KernelID], List[float]] = {}
        merged_gap: Dict[Tuple[TaskKey, KernelID], List[float]] = {}
        for buf in self._buffers.values():
            for k, (s, c) in buf.dur.items():
                m = merged_dur.setdefault(k, [0.0, 0])
                m[0] += s
                m[1] += c
            for k, (s, c) in buf.gap.items():
                m = merged_gap.setdefault(k, [0.0, 0])
                m[0] += s
                m[1] += c
        self._buffers.clear()
        self._epoch_obs = 0
        self._last_commit = self._clock() if now is None else now
        if not merged_dur and not merged_gap:
            return 0

        dirty: Dict[TaskKey, TaskProfile] = {}

        def live(key: TaskKey) -> TaskProfile:
            prof = dirty.get(key)
            if prof is None:
                cur = self.profiled.get(key)
                prof = cur.clone() if cur is not None \
                    else TaskProfile(key=key)
                prof.ema_alpha = alpha
                dirty[key] = prof
            return prof

        for (key, kid), (s, c) in merged_dur.items():
            prof = live(key)
            batch = s / c
            old = prof.SK.get(kid)
            prof.SK[kid] = batch if old is None \
                else (1.0 - alpha) * old + alpha * batch
            prof.obs_count[kid] = prof.obs_count.get(kid, 0) + c
        for (key, kid), (s, c) in merged_gap.items():
            prof = live(key)
            batch = s / c
            old = prof.SG.get(kid)
            prof.SG[kid] = batch if old is None \
                else (1.0 - alpha) * old + alpha * batch
            prof.gap_obs_count[kid] = prof.gap_obs_count.get(kid, 0) + c
        for prof in dirty.values():
            self.profiled.load(prof)
        self.commits += 1
        self.committed_keys += len(dirty)
        # interference coefficients commit in the SAME epochs as SK/SG:
        # one EMA fold per class pair from this epoch's batch-mean ratio
        if self.interference is not None and self._pair_pending:
            for pair, (s, c) in self._pair_pending.items():
                self.interference.update(pair, s / c, alpha)
                self.interference_updates += 1
            self._pair_pending.clear()
        return len(dirty)

    # ---------------------------------------------------------------- stats
    @property
    def pending_observations(self) -> int:
        return sum(b.observations for b in self._buffers.values())

    def stats(self) -> Dict[str, float]:
        """Counters for ``SimReport.online_stats`` / serving stats."""
        return {
            "observations": self.observations,
            "gap_observations": self.gap_observations,
            "commits": self.commits,
            "committed_keys": self.committed_keys,
            "pending_observations": self.pending_observations,
            "cold_observations": self.cold_observations,
            "cold_predictions": self.profiled.cold_predictions,
            "drift_obs": self.drift_obs,
            "drift_mean_abs_err": (self.drift_abs_sum / self.drift_obs
                                   if self.drift_obs else 0.0),
            "drift_mean_rel_err": (self.drift_abs_sum / self.drift_pred_sum
                                   if self.drift_pred_sum > 0.0 else 0.0),
            "gap_drift_obs": self.gap_drift_obs,
            "gap_drift_mean_abs_err": (
                self.gap_drift_abs_sum / self.gap_drift_obs
                if self.gap_drift_obs else 0.0),
            "interference_pair_obs": self.interference_pair_obs,
            "interference_updates": self.interference_updates,
        }
