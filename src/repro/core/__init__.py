"""FIKIT core: the paper's contribution.

Kernel identification (paper §3.2), two-phase measurement/sharing profiling,
priority queues Q0-Q9, Algorithm 1 (FIKIT procedure), Algorithm 2
(BestPrioFit), real-time feedback (Fig 12), and the scheduler with
EXCLUSIVE / SHARING / FIKIT execution modes over a serial device executor
(discrete-event simulated or real wall-clock JAX execution).
"""
from repro.core.kernel_id import KernelID, kernel_id_for  # noqa: F401
from repro.core.task import (  # noqa: F401
    KernelRequest, Priority, TaskKey, TaskSpec, TraceKernel,
)
from repro.core.profiler import Profiler, TaskProfile  # noqa: F401
from repro.core.queues import PriorityQueues  # noqa: F401
from repro.core.fikit import EPSILON, best_prio_fit, fikit_procedure  # noqa: F401
from repro.core.scheduler import Mode, SimScheduler  # noqa: F401
