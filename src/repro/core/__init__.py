"""FIKIT core: the paper's contribution.

Kernel identification (paper §3.2), two-phase measurement/sharing profiling,
priority queues Q0-Q9, Algorithm 1 (FIKIT procedure), Algorithm 2
(BestPrioFit), real-time feedback (Fig 12), and ONE engine-agnostic
scheduling state machine (``FikitPolicy``) with EXCLUSIVE / SHARING /
FIKIT / PREEMPT execution modes, driven by two thin engines over serial
device executors: the discrete-event simulator (``SimScheduler``) and the
real wall-clock JAX executor (``WallClockEngine``). ``PlacementLayer``
spreads one prioritized workload mix over K per-device policies (device
election disciplines + idle-device work stealing); K=1 is a pass-through
pinned trace-identical to a bare policy.
"""
from repro.core.kernel_id import KernelID, kernel_id_for  # noqa: F401
from repro.core.task import (  # noqa: F401
    KernelRequest, Priority, TaskKey, TaskSpec, TraceKernel,
)
from repro.core.profiler import ProfiledData, Profiler, TaskProfile  # noqa: F401
from repro.core.online import OnlineConfig, OnlineMeasurement  # noqa: F401
from repro.core.queues import PriorityQueues  # noqa: F401
from repro.core.fikit import (  # noqa: F401
    EPSILON, best_prio_fit, best_prio_fit_scan, fikit_procedure,
)
from repro.core.policy import (  # noqa: F401
    FikitPolicy, ListTrace, NullTrace, RingTrace, make_trace_sink,
)
from repro.core.placement import DISCIPLINES, PlacementLayer  # noqa: F401
from repro.core.scheduler import Mode, SimScheduler  # noqa: F401
