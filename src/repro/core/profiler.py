"""Measurement phase (paper §3.2, Fig 6): per-kernel execution time and
inter-kernel idle (gap) collection over T runs, reduced to the SK / SG
statistics with Kronecker-delta means:

    SK_j = mean of K_{ID_{t,i}} over all (t, i) with ID_{t,i} == j
    SG_j = mean of G_{ID_{t,i}} over all (t, i < N_t) with ID_{t,i} == j
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.kernel_id import KernelID
from repro.core.task import TaskKey


@dataclass
class TaskProfile:
    """Profiled statistics for one TaskKey (the paper's
    ``TaskKey = (SK, SG)`` output)."""
    key: TaskKey
    SK: Dict[KernelID, float] = field(default_factory=dict)
    SG: Dict[KernelID, float] = field(default_factory=dict)
    runs: int = 0

    @property
    def unique_ids(self):
        return set(self.SK)

    def predict_duration(self, kid: KernelID) -> float:
        return self.SK.get(kid, -1.0)

    def predict_gap(self, kid: KernelID) -> float:
        return self.SG.get(kid, 0.0)


class Profiler:
    """Collects per-run kernel records and emits SK/SG statistics.

    Usage per measured run::

        prof.start_run()
        prof.record(kid, duration)          # kernel executed
        prof.record_gap(gap)                # idle observed after last kid
        prof.end_run()
        ...
        profile = prof.statistics()
    """

    def __init__(self, key: TaskKey):
        self.key = key
        self._runs: List[List[Tuple[KernelID, float, Optional[float]]]] = []
        self._cur: Optional[List] = None

    # ------------------------------------------------------------- recording
    def start_run(self) -> None:
        if self._cur is not None:
            raise RuntimeError("previous run not ended")
        self._cur = []

    def record(self, kid: KernelID, duration: float) -> None:
        if self._cur is None:
            raise RuntimeError("start_run() first")
        self._cur.append([kid, float(duration), None])

    def record_gap(self, gap: float) -> None:
        """Gap after the most recently recorded kernel."""
        if self._cur is None or not self._cur:
            raise RuntimeError("no kernel to attach gap to")
        self._cur[-1][2] = float(gap)

    def end_run(self) -> None:
        if self._cur is None:
            raise RuntimeError("start_run() first")
        # last kernel of a run has no following gap (paper: N_t - 1 gaps)
        if self._cur:
            self._cur[-1][2] = None
        self._runs.append(self._cur)
        self._cur = None

    @property
    def num_runs(self) -> int:
        return len(self._runs)

    # ------------------------------------------------------------ statistics
    def statistics(self) -> TaskProfile:
        ksum: Dict[KernelID, float] = {}
        kcnt: Dict[KernelID, int] = {}
        gsum: Dict[KernelID, float] = {}
        gcnt: Dict[KernelID, int] = {}
        for run in self._runs:
            for kid, dur, gap in run:
                ksum[kid] = ksum.get(kid, 0.0) + dur
                kcnt[kid] = kcnt.get(kid, 0) + 1
                if gap is not None:
                    gsum[kid] = gsum.get(kid, 0.0) + gap
                    gcnt[kid] = gcnt.get(kid, 0) + 1
        prof = TaskProfile(key=self.key, runs=len(self._runs))
        prof.SK = {k: ksum[k] / kcnt[k] for k in ksum}
        prof.SG = {k: gsum[k] / gcnt[k] for k in gsum}
        return prof


class ProfiledData:
    """The scheduler's global loaded profile (Algorithm 1 ``ProfiledData``):
    TaskKey -> TaskProfile.

    Predictions are served from flat ``(TaskKey, KernelID) -> float`` dicts
    rebuilt on ``load()``, so the per-decision hot path
    (``predict_duration``/``predict_gap``) is ONE dict probe instead of a
    TaskKey lookup followed by a KernelID lookup. ``version`` increments on
    every ``load()`` — the priority-queue duration index keys its cache
    validity on it. Mutating a ``TaskProfile``'s SK/SG dicts after loading
    is not seen until the profile is loaded again.
    """

    def __init__(self):
        self._by_key: Dict[TaskKey, TaskProfile] = {}
        self._sk: Dict[Tuple[TaskKey, KernelID], float] = {}
        self._sg: Dict[Tuple[TaskKey, KernelID], float] = {}
        self.version = 0

    def load(self, profile: TaskProfile) -> None:
        prev = self._by_key.get(profile.key)
        if prev is not None:
            for kid in prev.SK:
                self._sk.pop((profile.key, kid), None)
            for kid in prev.SG:
                self._sg.pop((profile.key, kid), None)
        self._by_key[profile.key] = profile
        for kid, v in profile.SK.items():
            self._sk[(profile.key, kid)] = v
        for kid, v in profile.SG.items():
            self._sg[(profile.key, kid)] = v
        self.version += 1

    def get(self, key: TaskKey) -> Optional[TaskProfile]:
        return self._by_key.get(key)

    def __contains__(self, key: TaskKey) -> bool:
        return key in self._by_key

    def predict_duration(self, key: TaskKey, kid: KernelID) -> float:
        return self._sk.get((key, kid), -1.0)

    def predict_gap(self, key: TaskKey, kid: KernelID) -> float:
        return self._sg.get((key, kid), 0.0)
