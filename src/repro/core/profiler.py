"""Measurement phase (paper §3.2, Fig 6): per-kernel execution time and
inter-kernel idle (gap) collection over T runs, reduced to the SK / SG
statistics with Kronecker-delta means:

    SK_j = mean of K_{ID_{t,i}} over all (t, i) with ID_{t,i} == j
    SG_j = mean of G_{ID_{t,i}} over all (t, i < N_t) with ID_{t,i} == j

Beyond the paper's strictly-offline two-phase design, ``TaskProfile`` and
``ProfiledData`` also carry the state the ONLINE measurement loop
(``repro.core.online.OnlineMeasurement``) refines during sharing-mode
execution: per-kernel observation counters (``obs_count``/``gap_obs_count``),
the EMA smoothing factor a profile was last updated with (``ema_alpha``),
and an optional cold-start estimator that serves a provisional duration for
never-profiled kernels instead of the ``-1.0`` sentinel.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.interference import COMPUTE_BOUND
from repro.core.kernel_id import KernelID
from repro.core.task import TaskKey


@dataclass
class TaskProfile:
    """Profiled statistics for one TaskKey (the paper's
    ``TaskKey = (SK, SG)`` output).

    ``runs`` counts offline measured runs; ``obs_count``/``gap_obs_count``
    count ONLINE observations folded into each kernel's SK/SG entry (empty
    for a purely offline profile), and ``ema_alpha`` records the smoothing
    factor of the last online update (None when never updated online).
    Together with the current SK/SG values these fields are the complete
    EMA state, so a profile refined online round-trips losslessly through
    ``repro.core.profile_store``.

    ``kclass`` maps a kernel to its resource class ("compute"/"memory",
    see ``repro.core.interference``); kernels absent from it are treated
    as compute-bound, which makes pre-classification profiles load
    cleanly."""
    key: TaskKey
    SK: Dict[KernelID, float] = field(default_factory=dict)
    SG: Dict[KernelID, float] = field(default_factory=dict)
    runs: int = 0
    obs_count: Dict[KernelID, int] = field(default_factory=dict)
    gap_obs_count: Dict[KernelID, int] = field(default_factory=dict)
    ema_alpha: Optional[float] = None
    kclass: Dict[KernelID, str] = field(default_factory=dict)

    @property
    def unique_ids(self):
        return set(self.SK)

    @property
    def online_observations(self) -> int:
        """Total online duration observations folded into this profile."""
        return sum(self.obs_count.values())

    def predict_duration(self, kid: KernelID) -> float:
        return self.SK.get(kid, -1.0)

    def predict_gap(self, kid: KernelID) -> float:
        return self.SG.get(kid, 0.0)

    def clone(self) -> "TaskProfile":
        """Shallow-copy the per-kernel dicts (KernelIDs are interned and
        values are floats/ints, so a per-dict copy is a full copy)."""
        return TaskProfile(key=self.key, SK=dict(self.SK), SG=dict(self.SG),
                           runs=self.runs, obs_count=dict(self.obs_count),
                           gap_obs_count=dict(self.gap_obs_count),
                           ema_alpha=self.ema_alpha,
                           kclass=dict(self.kclass))


class Profiler:
    """Collects per-run kernel records and emits SK/SG statistics.

    Usage per measured run::

        prof.start_run()
        prof.record(kid, duration)          # kernel executed
        prof.record_gap(gap)                # idle observed after last kid
        prof.end_run()
        ...
        profile = prof.statistics()
    """

    def __init__(self, key: TaskKey):
        self.key = key
        self._runs: List[List[Tuple[KernelID, float, Optional[float]]]] = []
        self._cur: Optional[List] = None
        self._kclass: Dict[KernelID, str] = {}

    # ------------------------------------------------------------- recording
    def start_run(self) -> None:
        if self._cur is not None:
            raise RuntimeError("previous run not ended")
        self._cur = []

    def record(self, kid: KernelID, duration: float,
               kclass: Optional[str] = None) -> None:
        if self._cur is None:
            raise RuntimeError("start_run() first")
        self._cur.append([kid, float(duration), None])
        if kclass is not None:
            self._kclass[kid] = kclass    # last observation wins

    def record_gap(self, gap: float) -> None:
        """Gap after the most recently recorded kernel."""
        if self._cur is None or not self._cur:
            raise RuntimeError("no kernel to attach gap to")
        self._cur[-1][2] = float(gap)

    def end_run(self) -> None:
        if self._cur is None:
            raise RuntimeError("start_run() first")
        # last kernel of a run has no following gap (paper: N_t - 1 gaps)
        if self._cur:
            self._cur[-1][2] = None
        self._runs.append(self._cur)
        self._cur = None

    @property
    def num_runs(self) -> int:
        return len(self._runs)

    # ------------------------------------------------------------ statistics
    def statistics(self) -> TaskProfile:
        ksum: Dict[KernelID, float] = {}
        kcnt: Dict[KernelID, int] = {}
        gsum: Dict[KernelID, float] = {}
        gcnt: Dict[KernelID, int] = {}
        for run in self._runs:
            for kid, dur, gap in run:
                ksum[kid] = ksum.get(kid, 0.0) + dur
                kcnt[kid] = kcnt.get(kid, 0) + 1
                if gap is not None:
                    gsum[kid] = gsum.get(kid, 0.0) + gap
                    gcnt[kid] = gcnt.get(kid, 0) + 1
        prof = TaskProfile(key=self.key, runs=len(self._runs))
        prof.SK = {k: ksum[k] / kcnt[k] for k in ksum}
        prof.SG = {k: gsum[k] / gcnt[k] for k in gsum}
        prof.kclass = dict(self._kclass)
        return prof


class ProfiledData:
    """The scheduler's global loaded profile (Algorithm 1 ``ProfiledData``):
    TaskKey -> TaskProfile.

    Predictions are served from flat ``(TaskKey, KernelID) -> float`` dicts
    rebuilt on ``load()``, so the per-decision hot path
    (``predict_duration``/``predict_gap``) is ONE dict probe instead of a
    TaskKey lookup followed by a KernelID lookup. ``version`` increments on
    every ``load()`` — the priority-queue duration index keys its cache
    validity on it. Mutating a ``TaskProfile``'s SK/SG dicts after loading
    is not seen until the profile is loaded again.

    Cold start
    ----------
    With ``cold_start=False`` (the default, the paper's behavior) an
    unprofiled ``(TaskKey, KernelID)`` predicts the ``-1.0`` sentinel,
    which excludes the kernel from gap filling entirely — a cold task is
    invisible to FIKIT until someone profiles it. ``cold_start=True`` (or
    ``enable_cold_start()``) serves a PROVISIONAL duration instead: the
    mean SK of the task's own profiled kernels when the TaskKey is known,
    falling back to the global mean over every loaded SK entry, and only
    then to ``-1.0`` (nothing loaded at all — no basis for an estimate).
    Estimates are deterministic functions of the loaded state, recomputed
    on ``load()``, so the queue duration index (cached per ``version``)
    and the O(n) reference scans always agree on them. ``predictions
    served cold`` are counted in ``cold_predictions``. Gap predictions are
    NOT cold-started: a fabricated gap would open fake fill windows,
    whereas a missing gap (0.0) merely skips an optimization.
    """

    def __init__(self, cold_start: bool = False):
        self._by_key: Dict[TaskKey, TaskProfile] = {}
        self._sk: Dict[Tuple[TaskKey, KernelID], float] = {}
        self._sg: Dict[Tuple[TaskKey, KernelID], float] = {}
        self._class: Dict[Tuple[TaskKey, KernelID], str] = {}
        self._cold_start = cold_start
        self._key_mean: Dict[TaskKey, float] = {}
        self._sk_sum = 0.0
        self._sk_cnt = 0
        self.cold_predictions = 0
        self.version = 0
        #: optional attached ``repro.core.interference.InterferenceModel``
        #: (set by engines when interference scoring is on) so learned
        #: coefficients persist with the profiles via ``profile_store``.
        self.interference = None

    @property
    def cold_start(self) -> bool:
        return self._cold_start

    def enable_cold_start(self) -> None:
        """Switch cold-start estimation on (idempotent). Prediction values
        for PROFILED kernels are unaffected, so decision traces only change
        where the ``-1.0`` sentinel used to make a kernel invisible."""
        self._cold_start = True

    def load(self, profile: TaskProfile) -> None:
        prev = self._by_key.get(profile.key)
        if prev is not None:
            for kid, v in prev.SK.items():
                self._sk.pop((profile.key, kid), None)
                self._sk_sum -= v
                self._sk_cnt -= 1
            for kid in prev.SG:
                self._sg.pop((profile.key, kid), None)
            for kid in prev.kclass:
                self._class.pop((profile.key, kid), None)
        self._by_key[profile.key] = profile
        for kid, v in profile.SK.items():
            self._sk[(profile.key, kid)] = v
            self._sk_sum += v
            self._sk_cnt += 1
        for kid, v in profile.SG.items():
            self._sg[(profile.key, kid)] = v
        for kid, c in profile.kclass.items():
            self._class[(profile.key, kid)] = c
        if profile.SK:
            self._key_mean[profile.key] = (sum(profile.SK.values())
                                           / len(profile.SK))
        else:
            self._key_mean.pop(profile.key, None)
        self.version += 1

    def get(self, key: TaskKey) -> Optional[TaskProfile]:
        return self._by_key.get(key)

    def __contains__(self, key: TaskKey) -> bool:
        return key in self._by_key

    def keys(self):
        return self._by_key.keys()

    def predict_duration(self, key: TaskKey, kid: KernelID) -> float:
        v = self._sk.get((key, kid))
        if v is not None:
            return v
        if not self._cold_start:
            return -1.0
        return self._cold_estimate(key)

    def predict_duration_raw(self, key: TaskKey, kid: KernelID) -> float:
        """The paper's strict prediction: ``-1.0`` sentinel for anything
        unprofiled, never a cold-start estimate. The online measurement
        loop uses this to tell drift (wrong prediction) from cold
        (no prediction)."""
        return self._sk.get((key, kid), -1.0)

    def _cold_estimate(self, key: TaskKey) -> float:
        m = self._key_mean.get(key)
        if m is not None:
            self.cold_predictions += 1
            return m
        if self._sk_cnt:
            self.cold_predictions += 1
            return self._sk_sum / self._sk_cnt
        return -1.0          # nothing loaded: no estimate was served

    def predict_gap(self, key: TaskKey, kid: KernelID) -> float:
        return self._sg.get((key, kid), 0.0)

    def predict_class(self, key: TaskKey, kid: KernelID) -> str:
        """The kernel's profiled resource class; unclassified kernels
        (including every pre-classification profile) default to
        compute-bound."""
        return self._class.get((key, kid), COMPUTE_BOUND)
