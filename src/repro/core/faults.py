"""Fault injection for the scheduling engines.

A ``FaultPlan`` scripts what goes wrong — and when — against the one
place failures are observable and recoverable: the KERNEL BOUNDARY.
Kernels are non-preemptible (a launched kernel always finishes), so every
durable-state transition in the ops plane (``repro.core.jobstore``)
happens between kernels; a crash injected anywhere else would model a
failure mode the scheduler is not (and per the paper cannot be)
responsible for surviving mid-kernel.

Boundaries are counted globally across the run: boundary ``i`` is the
completion processing of the i-th kernel (0-based) to finish on any
device. At each boundary the driving engine asks the plan what to do:

- ``controls[i]`` — a list of lifecycle verbs to apply first:
  ``("cancel", instance)``, ``("pause", instance)``,
  ``("resume", instance)`` or ``("resume", instance, device)``. These
  drive the placement layer's lifecycle seam deterministically, which is
  how the cancellation-conservation property tests script verb storms.
- ``crash_at == i`` — the process dies at this boundary, AFTER the job
  store has durably recorded the completion (the write-ahead contract:
  the completion record is the boundary's commit point). ``hard=True``
  calls ``os._exit(CRASH_EXIT)`` — no exception handlers, no atexit, no
  buffered-IO flush, the closest in-process stand-in for SIGKILL — for
  subprocess kill-and-restart tests. ``hard=False`` raises
  ``InjectedCrash`` so a test can sweep every boundary in-process and
  then re-open the store file cold, proving the same durability without
  a process spawn per crash point.

A plan with no crash point and no controls is inert: the engines consult
it but never act, and decision traces stay bit-identical to a run with no
plan at all (pinned by the wired-but-disabled differential cases in
``tests/test_recovery.py``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

#: exit status of a hard injected crash — distinguishable from python
#: tracebacks (1) and signal deaths (<0 from subprocess's perspective)
CRASH_EXIT = 86


class InjectedCrash(RuntimeError):
    """Raised by a soft ``FaultPlan`` crash: the simulated process death.

    Carries the boundary index it fired at, so sweep tests can assert the
    crash happened where it was scripted."""

    def __init__(self, boundary: int):
        super().__init__(f"injected crash at kernel boundary {boundary}")
        self.boundary = boundary


@dataclass
class FaultPlan:
    """Scripted faults/verbs keyed by global kernel-boundary index.

    ``crash_at=None`` with empty ``controls`` is the inert wired-but-
    disabled configuration. The plan is single-use: it counts boundaries
    internally (``boundaries_seen``), so build a fresh plan per run."""
    crash_at: Optional[int] = None
    hard: bool = False
    controls: Dict[int, List[Tuple]] = field(default_factory=dict)
    boundaries_seen: int = 0

    @property
    def inert(self) -> bool:
        return self.crash_at is None and not self.controls

    def at_boundary(self) -> Tuple[bool, List[Tuple]]:
        """Advance to the next boundary. Returns ``(crash, verbs)``: the
        verbs to apply at this boundary, and whether the process dies
        after applying them. The engine applies verbs FIRST — a scripted
        cancel-then-crash at one boundary must persist the cancel."""
        i = self.boundaries_seen
        self.boundaries_seen += 1
        return self.crash_at == i, self.controls.get(i, [])

    def crash(self) -> None:
        """Execute the crash decided by ``at_boundary``."""
        boundary = self.boundaries_seen - 1
        if self.hard:
            os._exit(CRASH_EXIT)
        raise InjectedCrash(boundary)
