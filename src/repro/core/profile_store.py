"""Persistence for profiled data: TaskKey -> (SK, SG) as JSON.

The paper loads profiling output into the scheduler's memory at startup;
this store is the on-disk format between the measurement and sharing
phases. Profiles refined by the ONLINE measurement loop
(``repro.core.online``) round-trip losslessly too: per-kernel observation
counters (``obs``/``gap_obs``) and the EMA smoothing factor of the last
online update (``ema_alpha``) are written when present, so a serving
process can checkpoint its live-learned SK/SG state and resume smoothing
where it left off. Entries written by older versions (no online fields)
load with empty counters — the formats are mutually compatible.

Interference state (PR 6) rides the same store: a profile's per-kernel
resource classes are written as a ``class`` entry field when present, and
when the ``ProfiledData`` carries an attached
``repro.core.interference.InterferenceModel`` the file becomes a dict
``{"profiles": [...], "interference": {...}}`` so learned coefficients
checkpoint and resume with the profiles. Plain stores keep the original
top-level list format, and pre-classification files (no ``class`` field)
load with every kernel defaulting to compute-bound — both pinned by test.
"""
from __future__ import annotations

import json
import os

from repro.core.interference import InterferenceModel
from repro.core.kernel_id import KernelID
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.task import TaskKey


def _kid_to_json(kid: KernelID) -> list:
    return [kid.name, list(kid.grid), list(kid.block)]


def _kid_from_json(j) -> KernelID:
    return KernelID(j[0], tuple(_detuple(x) for x in j[1]),
                    tuple(_detuple(x) for x in j[2]))


def _detuple(x):
    return tuple(x) if isinstance(x, list) else x


def profiles_to_obj(data: ProfiledData):
    """Serialize a ``ProfiledData`` to the store's JSON-compatible object
    (a list, or a dict envelope when an interference model is attached).
    ``save_profiles`` writes this to a file; ``repro.core.jobstore`` embeds
    it in the durable job store's profile-snapshot column."""
    out = []
    for key, prof in data._by_key.items():
        entry = {
            "process": key.process,
            "args": list(key.args),
            "runs": prof.runs,
            "SK": [[_kid_to_json(k), v] for k, v in prof.SK.items()],
            "SG": [[_kid_to_json(k), v] for k, v in prof.SG.items()],
        }
        # online-measurement state: only written when the profile carries
        # any, so purely-offline stores keep the original compact format
        if prof.obs_count:
            entry["obs"] = [[_kid_to_json(k), n]
                            for k, n in prof.obs_count.items()]
        if prof.gap_obs_count:
            entry["gap_obs"] = [[_kid_to_json(k), n]
                                for k, n in prof.gap_obs_count.items()]
        if prof.ema_alpha is not None:
            entry["ema_alpha"] = prof.ema_alpha
        if prof.kclass:
            entry["class"] = [[_kid_to_json(k), c]
                              for k, c in prof.kclass.items()]
        out.append(entry)
    model = getattr(data, "interference", None)
    if model is not None:
        # dict envelope only when there is a model to checkpoint; plain
        # stores keep the original top-level list format
        out = {"profiles": out,
               "interference": {
                   "enabled": model.enabled,
                   "coeffs": [[h, f, v]
                              for (h, f), v in model.snapshot().items()],
               }}
    return out


def save_profiles(path: str, data: ProfiledData) -> None:
    out = profiles_to_obj(data)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f)


def profiles_from_obj(raw, cold_start: bool = False) -> ProfiledData:
    """Rebuild a ``ProfiledData`` from ``profiles_to_obj`` output (or any
    legacy top-level-list store payload)."""
    data = ProfiledData(cold_start=cold_start)
    entries = raw
    if isinstance(raw, dict):
        entries = raw["profiles"]
        imeta = raw.get("interference")
        if imeta is not None:
            data.interference = InterferenceModel(
                {(h, f): v for h, f, v in imeta.get("coeffs", [])},
                enabled=imeta.get("enabled", True))
    for entry in entries:
        key = TaskKey(entry["process"], tuple(entry["args"]))
        prof = TaskProfile(key=key, runs=entry["runs"],
                           ema_alpha=entry.get("ema_alpha"))
        prof.SK = {_kid_from_json(k): v for k, v in entry["SK"]}
        prof.SG = {_kid_from_json(k): v for k, v in entry["SG"]}
        prof.obs_count = {_kid_from_json(k): n
                          for k, n in entry.get("obs", [])}
        prof.gap_obs_count = {_kid_from_json(k): n
                              for k, n in entry.get("gap_obs", [])}
        prof.kclass = {_kid_from_json(k): c
                       for k, c in entry.get("class", [])}
        data.load(prof)
    return data


def load_profiles(path: str, cold_start: bool = False) -> ProfiledData:
    """Load a profile store. ``cold_start=True`` builds the returned
    ``ProfiledData`` with the provisional-duration estimator enabled (the
    online serving configuration)."""
    if not os.path.exists(path):
        return ProfiledData(cold_start=cold_start)
    with open(path) as f:
        raw = json.load(f)
    return profiles_from_obj(raw, cold_start=cold_start)
