"""Persistence for profiled data: TaskKey -> (SK, SG) as JSON.

The paper loads profiling output into the scheduler's memory at startup;
this store is the on-disk format between the measurement and sharing phases.
"""
from __future__ import annotations

import json
import os
from typing import Dict

from repro.core.kernel_id import KernelID
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.task import TaskKey


def _kid_to_json(kid: KernelID) -> list:
    return [kid.name, list(kid.grid), list(kid.block)]


def _kid_from_json(j) -> KernelID:
    return KernelID(j[0], tuple(_detuple(x) for x in j[1]),
                    tuple(_detuple(x) for x in j[2]))


def _detuple(x):
    return tuple(x) if isinstance(x, list) else x


def save_profiles(path: str, data: ProfiledData) -> None:
    out = []
    for key, prof in data._by_key.items():
        out.append({
            "process": key.process,
            "args": list(key.args),
            "runs": prof.runs,
            "SK": [[_kid_to_json(k), v] for k, v in prof.SK.items()],
            "SG": [[_kid_to_json(k), v] for k, v in prof.SG.items()],
        })
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f)


def load_profiles(path: str) -> ProfiledData:
    data = ProfiledData()
    if not os.path.exists(path):
        return data
    with open(path) as f:
        raw = json.load(f)
    for entry in raw:
        key = TaskKey(entry["process"], tuple(entry["args"]))
        prof = TaskProfile(key=key, runs=entry["runs"])
        prof.SK = {_kid_from_json(k): v for k, v in entry["SK"]}
        prof.SG = {_kid_from_json(k): v for k, v in entry["SG"]}
        data.load(prof)
    return data
