"""Hook client (paper §3.2): intercepts every GPU-kernel (program segment)
dispatch of a service, constructs the kernel ID in real time, and forwards
the launch request to the FIKIT scheduler.

Paper mechanism: LD_PRELOAD CUDA hook + ``-rdynamic`` symbol recovery + UDP
to the scheduler process. Here: the service's segments are called through
``HookClient.dispatch`` which builds the ``KernelID`` from the segment name
and avals (zero-cost identification — no timing in the sharing stage) and
submits to the in-process ``WallClockEngine``.

Two phases per the paper:
- ``measure_run``: exclusive execution with per-kernel timing
  (block_until_ready bracketing, the cudaEvent analog) feeding a Profiler —
  this is the expensive measurement stage.
- ``run``: the FIKIT sharing stage — identification only, scheduler decides
  placement; the client never times anything.
"""
from __future__ import annotations

import itertools
import time
from typing import Callable, Optional, Sequence, Tuple

from repro.core.executor import WallClockEngine
from repro.core.kernel_id import KernelID, kernel_id_for
from repro.core.profiler import Profiler
from repro.core.task import KernelRequest, TaskKey

_instances = itertools.count(1)


def new_instance() -> int:
    """Allocate a fresh, process-unique task instance id. The serving
    layer allocates one AHEAD of ``HookClient.run(instance=...)`` so it
    can map the instance to its durable job record (and target it with
    lifecycle verbs) before the first engine event fires."""
    return next(_instances)


class Segment:
    """One dispatchable unit of a service: name + callable(state) -> state.

    ``host_work`` is the host-side post-processing attributable to this
    segment (sampling, detokenization, batching bookkeeping...) executed by
    the client AFTER the segment's result is available — the origin of the
    inter-kernel gap."""

    def __init__(self, name: str, fn: Callable, host_work: Optional[Callable] = None):
        self.name = name
        self.fn = fn
        self.host_work = host_work

    def kernel_id(self, state) -> KernelID:
        ins = state if isinstance(state, (tuple, list)) else (state,)
        return kernel_id_for(self.name, inputs=[x for x in ins
                                                if hasattr(x, "shape")])


class HookClient:
    def __init__(self, engine: WallClockEngine, key: TaskKey, priority: int,
                 segments: Sequence[Segment], identify: bool = True):
        self.engine = engine
        self.key = key
        self.priority = priority
        self.segments = list(segments)
        self.identify = identify   # off = "base" env (no kernel-ID hook)

    # ------------------------------------------------------------- sharing
    def run(self, state, deadline: Optional[float] = None,
            instance: Optional[int] = None) -> Tuple[object, float]:
        """Execute one task (all segments) under the scheduler. Returns
        (final_state, wall JCT).

        ``deadline`` is a completion budget in seconds RELATIVE to this
        call; it is converted to the engine's absolute clock
        (``perf_counter``) and tagged onto every kernel request, where
        ``edf``-disciplined queue levels order by it. The caller judges a
        miss by comparing the returned JCT against the budget.

        ``instance`` pins the task instance id (from ``new_instance()``)
        so callers can target the run with lifecycle verbs; default is a
        fresh id."""
        inst = next(_instances) if instance is None else instance
        t_begin = time.perf_counter()
        abs_deadline = None if deadline is None else t_begin + deadline
        self.engine.task_begin(inst, self.key, self.priority)
        try:
            for i, seg in enumerate(self.segments):
                kid = (seg.kernel_id(state) if self.identify
                       else KernelID(seg.name))
                req = KernelRequest(task_key=self.key, kernel_id=kid,
                                    priority=self.priority,
                                    task_instance=inst, seq_index=i,
                                    payload=_bind(seg.fn, state),
                                    deadline=abs_deadline)
                fut = self.engine.submit(req)
                state, _, _ = fut.result()
                if seg.host_work is not None:
                    state = seg.host_work(state)
        finally:
            self.engine.task_end(inst)
        return state, time.perf_counter() - t_begin

    # -------------------------------------------------------------- async
    def run_async(self, state, on_done, deadline: Optional[float] = None,
                  instance: Optional[int] = None) -> int:
        """Non-blocking counterpart of ``run``: execute one task (all
        segments) by chaining the engine's completion callbacks instead
        of parking this thread on a Future per kernel. Returns the task
        instance id immediately; ``on_done(final_state, jct, error)``
        fires exactly once from a device thread (no engine lock held)
        when the task retires — ``error`` is the first exception
        (``JobCancelled`` for an ops-plane cancel, the payload's own
        exception otherwise) and ``final_state`` is None on error.

        This is the admission plane's submit path: one dispatcher thread
        can keep hundreds of invocations in flight because nothing here
        ever blocks (EXCLUSIVE mode is the exception — its ``task_begin``
        admission wait still parks the caller)."""
        inst = next(_instances) if instance is None else instance
        t_begin = time.perf_counter()
        abs_deadline = None if deadline is None else t_begin + deadline
        segments = self.segments
        self.engine.task_begin(inst, self.key, self.priority)

        def finish(result, error) -> None:
            self.engine.task_end(inst)
            on_done(result, time.perf_counter() - t_begin, error)

        def step(i: int, state) -> None:
            seg = segments[i]
            kid = (seg.kernel_id(state) if self.identify
                   else KernelID(seg.name))
            req = KernelRequest(task_key=self.key, kernel_id=kid,
                                priority=self.priority,
                                task_instance=inst, seq_index=i,
                                payload=_bind(seg.fn, state),
                                deadline=abs_deadline)

            def completed(req, out, t0, t1, err) -> None:
                if err is not None:
                    finish(None, err)
                    return
                try:
                    if seg.host_work is not None:
                        out = seg.host_work(out)
                    if i + 1 < len(segments):
                        step(i + 1, out)
                    else:
                        finish(out, None)
                except BaseException as e:   # host_work / next-submit fail
                    finish(None, e)

            self.engine.submit(req, on_complete=completed)

        try:
            step(0, state)
        except BaseException as e:     # first submit failed synchronously
            finish(None, e)
        return inst

    # ----------------------------------------------------------- measurement
    def measure_run(self, state, profiler: Profiler) -> Tuple[object, float]:
        """One exclusive measured run (paper Fig 6): per-kernel duration via
        device-side bracketing + inter-kernel gap via launch timestamps."""
        inst = next(_instances)
        t_begin = time.perf_counter()
        self.engine.task_begin(inst, self.key, self.priority)
        profiler.start_run()
        last_end: Optional[float] = None
        try:
            for i, seg in enumerate(self.segments):
                kid = seg.kernel_id(state)
                req = KernelRequest(task_key=self.key, kernel_id=kid,
                                    priority=self.priority,
                                    task_instance=inst, seq_index=i,
                                    payload=_bind(seg.fn, state))
                fut = self.engine.submit(req)
                state, k_start, k_end = fut.result()
                if last_end is not None:
                    profiler.record_gap(max(0.0, k_start - last_end))
                profiler.record(kid, k_end - k_start)
                last_end = k_end
                if seg.host_work is not None:
                    state = seg.host_work(state)
        finally:
            profiler.end_run()
            self.engine.task_end(inst)
        return state, time.perf_counter() - t_begin


def _bind(fn, state):
    def call():
        return fn(state)
    return call
