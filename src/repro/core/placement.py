"""Multi-device placement over ``FikitPolicy`` — one priority workload mix
spread across K devices.

FIKIT's kernel-level scheduling (arXiv:2311.10359) is defined per-GPU. In a
cluster there is one mix of prioritized services spread over many devices,
and placement — which device a task lands on — decides QoS as much as the
per-device schedule does (cf. Strait, arXiv:2604.28175). ``PlacementLayer``
adds exactly that layer while keeping every per-device guarantee intact
(cf. Tally, arXiv:2410.07381: the sharing layer must not compromise
per-device isolation):

- It owns K independent ``FikitPolicy`` instances, one per device, each
  with its OWN indexed ``PriorityQueues`` and its own trace sink (the
  per-device decision log rides the policy's existing trace seam — there
  is no second trace mechanism).
- ``task_begin`` routes a new task to a device through a pluggable
  *placement discipline*; every later event of that task (``submit``,
  ``kernel_end``, ``task_end``) follows it to the elected device.
- When a device goes idle while another is backlogged, the layer *steals*
  a fully-parked task: its queued requests leave the source device's
  indexed queues (O(log n) ``remove`` each, in stream order — a steal can
  never reorder a task's stream), the task record migrates
  (``FikitPolicy.detach_task`` / ``attach_task``), and the requests
  re-submit on the destination, where the idle device launches them
  immediately. Only tasks with ZERO kernels in flight are candidates, so
  one task's kernels never run on two devices at once.

- It is the ops plane's lifecycle seam (``cancel`` / ``pause`` /
  ``resume``): all three verbs act at kernel boundaries only (a pause
  with kernels in flight defers to the task's next boundary), ride the
  same ``detach_task``/``attach_task`` mechanism as stealing, and a
  resume is a fresh placement decision — which is how a paused task
  migrates to a different device.

K=1 is a pure pass-through: the single discipline answer is device 0,
stealing is structurally impossible, and the layer adds no trace events —
so a K=1 ``PlacementLayer`` is decision-trace-identical to a bare
``FikitPolicy``. That equivalence is pinned by
``tests/test_placement_differential.py`` and, because both engines now
drive the policy through this layer, by the entire pre-existing
differential suite as well.

Placement disciplines (``discipline=`` ctor arg; a callable plugs in a
custom one):

    "least_loaded"       — device minimizing predicted outstanding SK sum
                           (queued + launched-but-unfinished work), ties to
                           fewest resident tasks, then lowest device id.
    "priority_affinity"  — priority bands map onto the device range
                           (priority * K // NUM_PRIORITIES), so
                           high-priority tasks concentrate on the low
                           devices and bulk work on the high ones.
    "round_robin"        — strict rotation, ignores load.
    callable             — ``fn(layer, instance, key, priority, arrival)
                           -> device index``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Set, Union

from repro.core.fikit import EPSILON
from repro.core.policy import FikitPolicy, Mode, TraceSpec
from repro.core.profiler import ProfiledData
from repro.core.queues import QueueDisciplineSpec
from repro.core.task import NUM_PRIORITIES, KernelRequest, TaskKey


def _least_loaded(layer: "PlacementLayer", instance: int, key: TaskKey,
                  priority: int, arrival: float) -> int:
    return min(range(layer.devices),
               key=lambda d: (layer._load[d], len(layer._instances[d]), d))


def _priority_affinity(layer: "PlacementLayer", instance: int, key: TaskKey,
                       priority: int, arrival: float) -> int:
    return priority * layer.devices // NUM_PRIORITIES


def _round_robin(layer: "PlacementLayer", instance: int, key: TaskKey,
                 priority: int, arrival: float) -> int:
    d = layer._rr
    layer._rr = (d + 1) % layer.devices
    return d


#: The placement-discipline registry: device-election strategies for
#: ``PlacementLayer(discipline=...)``. Each entry is a callable
#: ``fn(layer, instance, key, priority, arrival) -> device index`` in
#: ``range(layer.devices)``.
#:
#: Contract for every discipline (built-in or custom): it MUST return 0
#: when ``layer.devices == 1``. K=1 placement is a pinned pass-through —
#: the entire single-device differential suite runs through the layer, so
#: a discipline that routes anywhere else at K=1 breaks the
#: trace-identity guarantee (and ``task_begin`` rejects out-of-range
#: devices outright). To add a discipline: register it here, then extend
#: ``tests/test_placement_differential.py`` — the randomized invariant
#: sweep rotates through ``sorted(DISCIPLINES)`` automatically, but add a
#: directed test for the discipline's routing property and keep the K=1
#: head-to-head green. Distinct from the per-level QUEUE disciplines
#: (``repro.core.queues.QUEUE_DISCIPLINES``), which order parked requests
#: WITHIN one device's priority levels.
DISCIPLINES: Dict[str, Callable] = {
    "least_loaded": _least_loaded,
    "priority_affinity": _priority_affinity,
    "round_robin": _round_robin,
}

DisciplineSpec = Union[str, Callable]


class PlacementLayer:
    """K per-device ``FikitPolicy`` instances + routing + work stealing.

    Mirrors the single-policy driver API so engines drive it the same way
    they drove a bare policy — only ``fill_complete`` and the ``launch``
    hook gain a device index:

    - ``task_begin(instance, key, priority, arrival=None) -> bool``
    - ``submit(req) -> bool``
    - ``fill_complete(device)``
    - ``kernel_end(instance, kernel_id, *, last=False, actual_gap=None)``
    - ``task_end(instance) -> List[int]``

    ``launch`` is called as ``launch(device, req, filler)``.

    Thread safety follows the policies': the layer itself adds no lock, so
    a threaded engine must serialize calls exactly as it already does for
    a bare policy (the wall-clock engine holds its lock around every
    policy entry point).
    """

    def __init__(self, devices: int, mode: Mode,
                 profiled: Optional[ProfiledData] = None, *,
                 discipline: DisciplineSpec = "least_loaded",
                 queue_discipline: QueueDisciplineSpec = "fifo",
                 steal: bool = True,
                 pipeline_depth: int = 2, feedback: bool = True,
                 epsilon: float = EPSILON,
                 clock: Callable[[], float] = lambda: 0.0,
                 launch: Callable[[int, KernelRequest, bool], None] = None,
                 threadsafe: bool = True,
                 trace: TraceSpec = "list",
                 reference: bool = False,
                 online=None,
                 interference=None):
        if launch is None:
            raise TypeError("PlacementLayer requires a launch hook")
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        self.devices = devices
        self.mode = mode
        self.profiled = profiled or ProfiledData()
        #: optional ``repro.core.online.OnlineMeasurement``: the layer
        #: feeds it every kernel completion (with the observing device, so
        #: observations buffer per device and merge on epoch commit) and
        #: shares it with every per-device policy for gap-drift accounting
        self.online = online
        #: optional ``repro.core.interference.InterferenceModel``, shared
        #: by every per-device policy (one coefficient table per node —
        #: class-pair contention is a property of the hardware, not of a
        #: device index)
        self.interference = interference
        self.steal_enabled = steal and devices > 1
        self._clock = clock
        self._launch_hook = launch
        custom_discipline = callable(discipline)
        if custom_discipline:
            self._discipline = discipline
            self.discipline = getattr(discipline, "__name__", "custom")
        else:
            try:
                self._discipline = DISCIPLINES[discipline]
            except KeyError:
                raise ValueError(
                    f"unknown placement discipline: {discipline!r} "
                    f"(known: {sorted(DISCIPLINES)})") from None
            self.discipline = discipline

        def device_launcher(d: int):
            return lambda req, filler: self._on_launch(d, req, filler)

        # each policy gets its own trace sink: a str/int spec constructs a
        # fresh sink per policy; passing a sink OBJECT shares it across all
        # devices (useful for a merged custom log, surprising otherwise).
        # queue_discipline likewise instantiates per device: every policy
        # owns its own indexed PriorityQueues under the same spec.
        self.queue_discipline = queue_discipline
        self.policies: List[FikitPolicy] = [
            FikitPolicy(mode, self.profiled, pipeline_depth=pipeline_depth,
                        feedback=feedback, epsilon=epsilon, clock=clock,
                        launch=device_launcher(d), threadsafe=threadsafe,
                        trace=trace, discipline=queue_discipline,
                        reference=reference, online=online,
                        interference=interference)
            for d in range(devices)]

        self._device_of: Dict[int, int] = {}
        self._key_of: Dict[int, TaskKey] = {}
        self._instances: List[Set[int]] = [set() for _ in range(devices)]
        self._inflight: Dict[int, int] = {}     # launched, not yet completed
        self._parked: Dict[int, "OrderedDict[int, KernelRequest]"] = {}
        # instances with zero kernels in flight and >= 1 parked request —
        # the steal candidates, maintained O(1) at every flight/park
        # transition so an idle device's steal probe never rescans tasks
        self._stealable: Set[int] = set()
        self._retired: Set[int] = set()
        self._load: List[float] = [0.0] * devices   # predicted SK backlog
        self._rr = 0
        # _load only feeds least_loaded election; custom callables may read
        # layer.predicted_load(), so they keep the bookkeeping too
        self._needs_load = (devices > 1
                            and (self._discipline is _least_loaded
                                 or custom_discipline))
        self.steal_count = 0
        self.spurious_kernel_completions = 0
        self.spurious_task_ends = 0
        # ops-plane lifecycle state (cancel/pause/resume — all applied at
        # kernel boundaries only; kernels are non-preemptible)
        self._paused: Dict[int, tuple] = {}      # inst -> (ActiveTask, reqs)
        self._pause_pending: Set[int] = set()    # awaiting in-flight drain
        self._cancelled: Set[int] = set()        # tolerate late task_end

    # ------------------------------------------------------------- lifecycle
    def task_begin(self, instance: int, key: TaskKey, priority: int,
                   arrival: Optional[float] = None) -> bool:
        """Elect a device for the task, then begin it there."""
        if arrival is None:
            arrival = self._clock()
        d = self._discipline(self, instance, key, priority, arrival)
        if not 0 <= d < self.devices:
            raise ValueError(f"discipline {self.discipline!r} placed task "
                             f"{instance} on device {d} of {self.devices}")
        self._device_of[instance] = d
        self._key_of[instance] = key
        self._instances[d].add(instance)
        self._inflight[instance] = 0
        return self.policies[d].task_begin(instance, key, priority,
                                           arrival=arrival)

    def task_end(self, instance: int) -> List[int]:
        if instance in self._cancelled:
            # the client's own retirement arriving after an ops-plane
            # cancel already retired the task — expected, not spurious
            self._cancelled.discard(instance)
            return []
        self._pause_pending.discard(instance)
        d = self._device_of.get(instance)
        if d is None:
            # duplicate/late retirement for a purged instance: tolerate
            # like kernel_end does (FikitPolicy.task_end pops tolerantly
            # too, so this was a no-op before the placement layer existed)
            self.spurious_task_ends += 1
            return []
        if self.online is not None:
            self.online.task_gone(instance)
        admitted = self.policies[d].task_end(instance)
        self._instances[d].discard(instance)
        self._retired.add(instance)
        self._stealable.discard(instance)
        self._maybe_purge(instance)
        self._maybe_steal()
        return admitted

    # --------------------------------------------------------------- routing
    def submit(self, req: KernelRequest) -> bool:
        paused = self._paused.get(req.task_instance)
        if paused is not None:
            # a paused task's client keeps issuing; buffer with the
            # detached backlog and replay in stream order on resume
            paused[1].append(req)
            return False
        d = self._device_of[req.task_instance]
        if self.devices > 1:
            # load/park bookkeeping feeds device election and steal
            # candidacy; at K=1 neither exists, so the pass-through skips
            # it and a single-device submit costs what a bare policy's does
            if self._needs_load:
                self._load[d] += self._predict(req)
            if self.steal_enabled:
                # record the park BEFORE forwarding: the policy may consume
                # the request synchronously (direct launch, or queued-then-
                # filled inside the same call) and the launch hook pops the
                # record again
                self._parked.setdefault(req.task_instance,
                                        OrderedDict())[req.uid] = req
        launched = self.policies[d].submit(req)
        if not launched and self.steal_enabled:
            self._update_stealable(req.task_instance)
            self._maybe_steal()
            # the steal may have migrated THIS task and launched the very
            # request that just parked; report what actually happened
            parked = self._parked.get(req.task_instance)
            launched = parked is None or req.uid not in parked
        return launched

    def fill_complete(self, device: int) -> None:
        self.policies[device].fill_complete()

    def kernel_end(self, instance: int, kernel_id, *, last: bool = False,
                   actual_gap: Optional[float] = None,
                   start: Optional[float] = None,
                   end: Optional[float] = None) -> None:
        """``start``/``end`` are the completed kernel's device-time
        brackets when the engine knows them — the online measurement
        loop's duration sample. Passed BEFORE the policy's ``kernel_end``
        so an epoch commit triggered by this very observation already
        serves refreshed predictions to the fill decision it runs."""
        d = self._device_of.get(instance)
        if d is None:
            # duplicate/late completion for an already-purged instance (an
            # engine bug, or a device thread racing a retry): tolerate and
            # count it, like FikitPolicy.fill_complete's clamp — a KeyError
            # here would kill a wall-clock device thread
            self.spurious_kernel_completions += 1
            return
        if self.online is not None and start is not None and end is not None:
            self.online.observe(d, instance, self._key_of[instance],
                                kernel_id, start, end, last=last)
        n = self._inflight.get(instance, 0)
        if n > 0:
            self._inflight[instance] = n - 1
        if self._needs_load:
            self._load[d] = max(
                0.0, self._load[d] - max(
                    0.0,
                    self.profiled.predict_duration(self._key_of[instance],
                                                   kernel_id)))
        self.policies[d].kernel_end(instance, kernel_id, last=last,
                                    actual_gap=actual_gap)
        self._maybe_purge(instance)
        if (instance in self._pause_pending
                and not self._inflight.get(instance, 0)):
            # a pause requested mid-kernel lands at THIS boundary: the
            # task's last in-flight kernel just finished
            self._do_pause(instance)
        if self.steal_enabled:
            # this completion may have made the task fully parked (zero in
            # flight, requests queued) — the moment it becomes stealable
            self._update_stealable(instance)
            self._maybe_steal()

    def _on_launch(self, device: int, req: KernelRequest,
                   filler: bool) -> None:
        """Per-device policy launch hook: track flight state, forward."""
        inst = req.task_instance
        self._inflight[inst] = self._inflight.get(inst, 0) + 1
        if self.steal_enabled:
            parked = self._parked.get(inst)
            if parked is not None:
                parked.pop(req.uid, None)
            self._stealable.discard(inst)       # a kernel is now in flight
        self._launch_hook(device, req, filler)

    # ------------------------------------------------------ lifecycle verbs
    def cancel(self, instance: int):
        """Cancel ``instance`` at a kernel boundary: purge its parked
        requests, retire it, but let in-flight kernels run to completion
        (kernels are non-preemptible — their completions are tolerated
        through the existing late-completion machinery). Returns
        ``(purged, admitted)``: the purged requests in stream order and
        any instances newly admitted by EXCLUSIVE serialization."""
        entry = self._paused.pop(instance, None)
        if entry is not None:
            # cancelling a paused task: its backlog is already detached
            self._cancelled.add(instance)
            return list(entry[1]), []
        self._pause_pending.discard(instance)
        d = self._device_of.get(instance)
        if d is None:
            if instance in self._retired or instance in self._cancelled:
                # cancel raced completion (or a second cancel): the task
                # already left the layer — terminal no-op, nothing purged
                return [], []
            raise ValueError(f"cannot cancel unknown instance {instance}")
        if self.online is not None:
            self.online.task_gone(instance)
        parked = (list(self._parked[instance].values())
                  if self.steal_enabled and instance in self._parked
                  else None)
        purged, admitted = self.policies[d].cancel_task(instance, parked)
        self._cancelled.add(instance)
        self._instances[d].discard(instance)
        self._retired.add(instance)
        self._stealable.discard(instance)
        if self.steal_enabled and instance in self._parked:
            self._parked[instance].clear()
        if self._needs_load:
            self._load[d] = max(0.0, self._load[d]
                                - sum(self._predict(r) for r in purged))
        self._maybe_purge(instance)
        self._maybe_steal()
        return purged, admitted

    def pause(self, instance: int) -> bool:
        """Pause ``instance``: detach it (and its parked backlog) from
        its device. With kernels in flight the pause DEFERS to the next
        kernel boundary of the task (returns False); otherwise it takes
        effect now (returns True). Idempotent. EXCLUSIVE mode has no
        pause — admission serialization would deadlock behind a paused
        admitted task."""
        if self.mode is Mode.EXCLUSIVE:
            raise ValueError("pause/resume are not supported in "
                             "EXCLUSIVE mode")
        if instance in self._paused:
            return True
        if self._device_of.get(instance) is None:
            raise ValueError(f"cannot pause unknown instance {instance}")
        if self._inflight.get(instance, 0) > 0:
            self._pause_pending.add(instance)
            return False
        self._do_pause(instance)
        return True

    def _do_pause(self, instance: int) -> None:
        """Take the pause at a kernel boundary: detach the task record
        and its parked requests out of the device's policy, park both in
        the layer (the engine checkpoints the store; the layer keeps the
        live objects), free the device."""
        d = self._device_of.pop(instance)
        self._pause_pending.discard(instance)
        if self.online is not None:
            # a resumed task may land on a different device/timeline: its
            # launch-to-launch gap anchor would be meaningless
            self.online.task_gone(instance)
        parked = (list(self._parked[instance].values())
                  if self.steal_enabled and instance in self._parked
                  else None)
        at, reqs = self.policies[d].pause_task(instance, parked)
        self._instances[d].discard(instance)
        self._stealable.discard(instance)
        self._inflight.pop(instance, None)
        self._parked.pop(instance, None)
        self._key_of.pop(instance, None)
        if self._needs_load:
            self._load[d] = max(0.0, self._load[d]
                                - sum(self._predict(r) for r in reqs))
        self._paused[instance] = (at, list(reqs))
        self._maybe_steal()                     # the device may be idle now

    def resume(self, instance: int, device: Optional[int] = None) -> int:
        """Re-admit a paused task, on ``device`` or (by default) wherever
        the placement discipline elects NOW — a resumed task is a fresh
        placement decision, which is how a pause/resume pair migrates a
        task off a hot device. Replays the detached backlog in stream
        order. Returns the hosting device."""
        entry = self._paused.pop(instance, None)
        if entry is None:
            if instance in self._pause_pending:
                # resume raced a deferred pause: the pause never took
                # effect, the task never left its device
                self._pause_pending.discard(instance)
                return self._device_of[instance]
            raise ValueError(f"instance {instance} is not paused")
        at, reqs = entry
        if device is None:
            device = self._discipline(self, at.instance, at.key,
                                      at.priority, at.arrival)
        if not 0 <= device < self.devices:
            raise ValueError(f"resume of {instance} onto device {device} "
                             f"of {self.devices}")
        self._device_of[instance] = device
        self._key_of[instance] = at.key
        self._instances[device].add(instance)
        self._inflight[instance] = 0
        self.policies[device].attach_task(at)
        for r in reqs:                 # full submit(): load/park/steal
            self.submit(r)             # bookkeeping comes back with it
        return device

    @property
    def paused(self) -> Set[int]:
        return set(self._paused)

    # -------------------------------------------------------------- stealing
    def _update_stealable(self, instance: int) -> None:
        """Recompute one instance's steal candidacy: fully parked (zero in
        flight, >= 1 queued request) and not retired."""
        if (instance not in self._retired
                and not self._inflight.get(instance, 0)
                and self._parked.get(instance)):
            self._stealable.add(instance)
        else:
            self._stealable.discard(instance)

    def _maybe_steal(self) -> None:
        """Give every idle device a chance to steal a parked task."""
        if not self.steal_enabled or not self._stealable:
            return
        for s in range(self.devices):
            if not self._instances[s]:
                self._steal_to(s)
                if not self._stealable:
                    return

    def _steal_to(self, s: int) -> bool:
        """Steal the best fully-parked task onto idle device ``s``. Best =
        highest priority (ties: earliest arrival, lowest instance) — the
        task most hurt by waiting out a foreign holder. O(candidates), not
        O(resident tasks): the candidate set is maintained incrementally.
        Returns True iff a task moved."""
        best = None
        for i in self._stealable:
            b = self._device_of[i]
            if b == s:
                continue                        # already here (defensive)
            at = self.policies[b].active[i]
            cand = (at.priority, at.arrival, at.instance, b)
            if best is None or cand < best:
                best = cand
        if best is None:
            return False
        _, _, inst, b = best
        if self.online is not None:
            # the task changes devices: its launch-to-launch gap anchor is
            # meaningless across timelines, drop it
            self.online.task_gone(inst)
        at, reqs = self.policies[b].detach_task(
            inst, list(self._parked[inst].values()))
        self._instances[b].discard(inst)
        self._instances[s].add(inst)
        self._device_of[inst] = s
        if self._needs_load:
            moved = sum(self._predict(r) for r in reqs)
            self._load[b] = max(0.0, self._load[b] - moved)
            self._load[s] += moved
        self.steal_count += 1
        dst = self.policies[s]
        dst.attach_task(at)
        for r in reqs:                 # device s is idle: these launch now
            dst.submit(r)
        self._update_stealable(inst)
        return True

    # -------------------------------------------------------------- plumbing
    def _predict(self, req: KernelRequest) -> float:
        return max(0.0, self.profiled.predict_duration(req.task_key,
                                                       req.kernel_id))

    def _maybe_purge(self, instance: int) -> None:
        """Drop a retired instance's bookkeeping once its last completion
        has been observed (task_end and final kernel_end arrive in either
        order in the wall-clock engine)."""
        if instance in self._retired and not self._inflight.get(instance, 0):
            self._retired.discard(instance)
            self._inflight.pop(instance, None)
            self._parked.pop(instance, None)
            self._stealable.discard(instance)
            self._device_of.pop(instance, None)
            self._key_of.pop(instance, None)

    # ----------------------------------------------------------- inspection
    def device_of(self, instance: int) -> Optional[int]:
        """Device currently hosting ``instance`` (None once purged)."""
        return self._device_of.get(instance)

    def queued_of(self, instance: int) -> int:
        if self.steal_enabled:                 # _parked mirrors the queues
            parked = self._parked.get(instance)
            return len(parked) if parked else 0
        d = self._device_of.get(instance)      # inspection-only: scan
        if d is None:
            return 0
        return sum(1 for r in self.policies[d].queues
                   if r.task_instance == instance)

    def inflight_of(self, instance: int) -> int:
        return self._inflight.get(instance, 0)

    def predicted_load(self, device: int) -> float:
        return self._load[device]

    @property
    def traces(self) -> List:
        return [p.trace for p in self.policies]

    @property
    def fill_count(self) -> int:
        return sum(p.fill_count for p in self.policies)

    @property
    def overshoot_time(self) -> float:
        return sum(p.overshoot_time for p in self.policies)

    @property
    def queued(self) -> int:
        return sum(p.queued for p in self.policies)

    @property
    def spurious_fill_completions(self) -> int:
        return sum(p.spurious_fill_completions for p in self.policies)
