"""Kernel identification (paper §3.2, Fig 4).

Paper: ``kernel ID = (function name, blockDim, gridDim)`` recovered via
CUDA hooks + a ``-rdynamic`` recompiled framework. The ID deliberately does
NOT include kernel inputs (they are ``void*`` at the CUDA runtime level), so
kernels with the same function and parallelization but different input
scales share an ID — mitigated by averaging (SK) + runtime feedback.

TPU/JAX adaptation: the dispatch unit is a jit-compiled program segment.
The natural analog of (name, blockDim, gridDim) is
(segment name, input shapes/dtypes, mesh fingerprint) — exactly the key JAX
uses for compiled-executable lookup, and, like the paper's ID, it is
available at dispatch time with zero measurement cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True, order=True)
class KernelID:
    name: str
    grid: Tuple = ()          # paper: gridDim  | here: output aval fingerprint
    block: Tuple = ()         # paper: blockDim | here: input aval fingerprint

    def __str__(self) -> str:
        g = "x".join(map(str, self.grid)) or "-"
        b = "x".join(map(str, self.block)) or "-"
        return f"{self.name}<<<{g},{b}>>>"

    def encode(self) -> str:
        return f"{self.name}|{self.grid}|{self.block}"


def _aval_fp(x) -> Tuple:
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return tuple(x.shape) + (np.dtype(x.dtype).name,)
    return (type(x).__name__,)


def kernel_id_for(name: str, inputs=(), outputs=(), mesh_fp: str = "") \
        -> KernelID:
    """Construct a KernelID from a segment name and its avals."""
    block = tuple(f for x in inputs for f in _aval_fp(x))
    grid = tuple(f for x in outputs for f in _aval_fp(x))
    if mesh_fp:
        grid = grid + (mesh_fp,)
    return KernelID(name=name, grid=grid, block=block)
