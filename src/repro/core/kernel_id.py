"""Kernel identification (paper §3.2, Fig 4).

Paper: ``kernel ID = (function name, blockDim, gridDim)`` recovered via
CUDA hooks + a ``-rdynamic`` recompiled framework. The ID deliberately does
NOT include kernel inputs (they are ``void*`` at the CUDA runtime level), so
kernels with the same function and parallelization but different input
scales share an ID — mitigated by averaging (SK) + runtime feedback.

TPU/JAX adaptation: the dispatch unit is a jit-compiled program segment.
The natural analog of (name, blockDim, gridDim) is
(segment name, input shapes/dtypes, mesh fingerprint) — exactly the key JAX
uses for compiled-executable lookup, and, like the paper's ID, it is
available at dispatch time with zero measurement cost.

KernelIDs are *interned*: constructing the same (name, grid, block) returns
the same object, with the hash precomputed once. Every scheduling decision
does SK/SG dict lookups keyed by KernelID, so the per-lookup cost drops to
one cached-int hash plus (usually) an identity comparison. The intern table
is bounded by the number of distinct compiled segments — the same set JAX
keeps alive in its executable cache.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


class KernelID:
    __slots__ = ("name", "grid", "block", "_hash")

    _intern: Dict[tuple, "KernelID"] = {}

    # paper: gridDim / blockDim | here: output / input aval fingerprints
    def __new__(cls, name: str, grid: Tuple = (), block: Tuple = ()):
        key = (name, grid, block)
        self = cls._intern.get(key)
        if self is None:
            self = object.__new__(cls)
            object.__setattr__(self, "name", name)
            object.__setattr__(self, "grid", grid)
            object.__setattr__(self, "block", block)
            object.__setattr__(self, "_hash", hash(key))
            # setdefault: safe under concurrent first-construction
            self = cls._intern.setdefault(key, self)
        return self

    def _key(self) -> tuple:
        return (self.name, self.grid, self.block)

    def __hash__(self) -> int:
        return self._hash

    def __eq__(self, other) -> bool:
        if self is other:
            return True
        if not isinstance(other, KernelID):
            return NotImplemented
        return self._key() == other._key()

    def __lt__(self, other) -> bool:
        if not isinstance(other, KernelID):
            return NotImplemented
        return self._key() < other._key()

    def __le__(self, other) -> bool:
        if not isinstance(other, KernelID):
            return NotImplemented
        return self._key() <= other._key()

    def __gt__(self, other) -> bool:
        if not isinstance(other, KernelID):
            return NotImplemented
        return self._key() > other._key()

    def __ge__(self, other) -> bool:
        if not isinstance(other, KernelID):
            return NotImplemented
        return self._key() >= other._key()

    def __setattr__(self, name, value):
        raise AttributeError("KernelID is immutable")

    def __delattr__(self, name):
        raise AttributeError("KernelID is immutable")

    def __reduce__(self):
        # pickle round-trips re-intern
        return (KernelID, (self.name, self.grid, self.block))

    def __repr__(self) -> str:
        return (f"KernelID(name={self.name!r}, grid={self.grid!r}, "
                f"block={self.block!r})")

    def __str__(self) -> str:
        g = "x".join(map(str, self.grid)) or "-"
        b = "x".join(map(str, self.block)) or "-"
        return f"{self.name}<<<{g},{b}>>>"

    def encode(self) -> str:
        return f"{self.name}|{self.grid}|{self.block}"


def _aval_fp(x) -> Tuple:
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return tuple(x.shape) + (np.dtype(x.dtype).name,)
    return (type(x).__name__,)


def kernel_id_for(name: str, inputs=(), outputs=(), mesh_fp: str = "") \
        -> KernelID:
    """Construct a KernelID from a segment name and its avals."""
    block = tuple(f for x in inputs for f in _aval_fp(x))
    grid = tuple(f for x in outputs for f in _aval_fp(x))
    if mesh_fp:
        grid = grid + (mesh_fp,)
    return KernelID(name=name, grid=grid, block=block)
