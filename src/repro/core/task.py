"""Tasks, task keys and kernel requests (paper §3.2).

A *task* is one invocation of a service (e.g. one inference). A task's GPU
work is a sequence of kernels; between consecutive kernels the device idles
for the task's host-side "gap". ``TaskKey`` is the paper's unique task
identifier (process name + startup parameters) keying the profiled data.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

from repro.core.kernel_id import KernelID

NUM_PRIORITIES = 10


class Priority(int):
    """0 = highest, 9 = lowest (paper Fig 7)."""

    def __new__(cls, v: int):
        if not 0 <= int(v) < NUM_PRIORITIES:
            raise ValueError(f"priority must be in [0, {NUM_PRIORITIES})")
        return super().__new__(cls, v)


@dataclass(frozen=True)
class TaskKey:
    """Paper: 'According to the process name and startup parameters of the
    task, the Task Key is generated as the unique identifier of the task.'"""
    process: str
    args: Tuple = ()

    def encode(self) -> str:
        return f"{self.process}|{self.args}"


@dataclass(frozen=True)
class TraceKernel:
    """One kernel occurrence in a task trace: duration + following host gap
    (both seconds). Used by the simulator and as ground truth in tests.

    ``kclass`` is the kernel's ground-truth resource class
    (``repro.core.interference``: "compute" / "memory"), recorded into the
    profile by the measurement phase and used by the simulator's physical
    interference environment. ``None`` (default) means unclassified,
    treated as compute-bound everywhere."""
    kid: KernelID
    duration: float
    gap_after: float = 0.0
    kclass: Optional[str] = None


_req_counter = itertools.count()


@dataclass
class KernelRequest:
    """A kernel launch request traveling hook-client -> scheduler (paper's
    UDP message).

    ``deadline`` is an optional absolute completion deadline (same clock as
    the driving engine: virtual seconds in the simulator, ``perf_counter``
    seconds in the wall-clock engine) carried from the owning task. It is
    only consulted by ``edf``-disciplined priority-queue levels; requests
    without a deadline sort after every dated request and keep FIFO order
    among themselves."""
    task_key: TaskKey
    kernel_id: KernelID
    priority: int
    task_instance: int = 0        # which running task instance
    seq_index: int = 0            # kernel index within the task
    submit_time: float = 0.0
    payload: Any = None           # sim: true duration | wallclock: callable
    deadline: Optional[float] = None
    uid: int = field(default_factory=lambda: next(_req_counter))

    def __repr__(self):
        return (f"KernelRequest({self.task_key.process}#{self.task_instance}"
                f" k{self.seq_index} prio={self.priority})")


@dataclass
class TaskSpec:
    """A runnable task: its key, priority and kernel trace.

    max_inflight models the client's launch-ahead: 1 = synchronous client
    (issues kernel i+1 only after observing kernel i's completion plus its
    host gap); m > 1 = CUDA-style async client that keeps up to m kernels
    in flight, issuing launch i+1 a host-gap after launch i. Device-bound
    tasks with large m are what inflate a high-priority co-tenant's JCT in
    default sharing mode (paper Fig 2 "A,B Sharing 1").
    """
    key: TaskKey
    priority: int
    kernels: List[TraceKernel]
    arrival: float = 0.0
    max_inflight: int = 1
    #: optional absolute completion deadline (sim seconds). Tagged onto
    #: every kernel request of the task; drives ``edf`` queue levels and
    #: the ``SimReport.deadline_misses`` counter.
    deadline: Optional[float] = None

    @property
    def solo_jct(self) -> float:
        """JCT when running exclusively (kernels + internal gaps)."""
        if not self.kernels:
            return 0.0
        total = sum(k.duration + k.gap_after for k in self.kernels)
        return total - self.kernels[-1].gap_after
