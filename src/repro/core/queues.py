"""Priority queues Q0..Q9 (paper Fig 7): the scheduler scans queues from
highest (Q0) to lowest (Q9); within a queue, requests keep FIFO order."""
from __future__ import annotations

import threading
from collections import deque
from typing import Iterator, List, Optional

from repro.core.task import NUM_PRIORITIES, KernelRequest


class PriorityQueues:
    def __init__(self, levels: int = NUM_PRIORITIES):
        self.levels = levels
        self._qs: List[deque] = [deque() for _ in range(levels)]
        self._lock = threading.RLock()

    def push(self, req: KernelRequest) -> None:
        with self._lock:
            self._qs[req.priority].append(req)

    def __getitem__(self, priority: int) -> deque:
        return self._qs[priority]

    def remove(self, req: KernelRequest) -> None:
        with self._lock:
            self._qs[req.priority].remove(req)

    def pop_highest(self) -> Optional[KernelRequest]:
        """FIFO pop from the highest-priority non-empty queue."""
        with self._lock:
            for q in self._qs:
                if q:
                    return q.popleft()
        return None

    def peek_highest(self) -> Optional[KernelRequest]:
        with self._lock:
            for q in self._qs:
                if q:
                    return q[0]
        return None

    def highest_nonempty(self) -> Optional[int]:
        with self._lock:
            for p, q in enumerate(self._qs):
                if q:
                    return p
        return None

    def __len__(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._qs)

    def __iter__(self) -> Iterator[KernelRequest]:
        with self._lock:
            for q in self._qs:
                yield from list(q)

    def lock(self):
        return self._lock
