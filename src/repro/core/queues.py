"""Priority queues Q0..Q9 (paper Fig 7) with per-level queue disciplines.

The scheduler scans queues from highest (Q0) to lowest (Q9). WITHIN a
level, ordering is a pluggable *queue discipline* (``QUEUE_DISCIPLINES``):

- ``fifo`` (default) — the paper's behavior, pinned bit-identical to the
  pre-discipline implementation: pops release the oldest-parked request,
  and gap filling (``best_fit_under``) selects the LONGEST fitting stream
  head, ties resolved to the earliest-parked one.
- ``sjf``  — shortest-job-first (cf. Strait's interference-aware ordering):
  pops release the stream head with the SHORTEST predicted SK duration
  (unprofiled heads carry the -1.0 sentinel and sort shortest), and gap
  filling selects the shortest profiled head that fits the idle gap — a
  successor search over the same duration index the FIFO predecessor
  search uses. Ties resolve to the earliest-parked head. Without a bound
  profile there are no predictions, and ``sjf`` degrades to FIFO order
  deterministically.
- ``edf``  — earliest-deadline-first (cf. RTGPU-style deadline-driven
  scheduling): requests carry an optional absolute ``deadline``; pops
  release the earliest-deadline stream head, and gap filling keeps the
  paper's primary criterion (longest fit — gap utilization is still the
  point) but resolves predicted-duration TIES to the earliest deadline
  instead of the earliest-parked request. A request without a deadline
  sorts after every dated request and falls back to FIFO order among
  undated peers — an all-undated ``edf`` level is behaviorally identical
  to a ``fifo`` level.

Disciplines are fixed per level at construction
(``discipline_by_level=``: one name for all levels, a ``{level: name}``
mapping, or a full per-level sequence). Unknown names raise ``ValueError``
naming ``sorted(QUEUE_DISCIPLINES)``. Bulk release on holder retirement
intentionally stays in park (FIFO) order regardless of discipline: a
release launches EVERY affected request onto the serial device queue, and
park order is the one ordering that is provably stream-safe.

Indexed representation
----------------------
The paper's <5% overhead budget means each scheduling decision must cost
far less than a 0.1-2 ms kernel launch, at production queue depths. The
naive structure (one deque per level, linear scans everywhere) makes
``best_prio_fit`` O(total queued) per fill decision. Each level therefore
maintains coupled views:

- ``fifo``     — OrderedDict uid -> request: park order; O(1) push, O(1)
  remove-by-request, O(1) oldest (``pop_highest``/``peek_highest``).
- ``streams``  — (task_key, instance) -> deque of that stream's parked
  requests in seq order. Only the *head* of a stream is eligible for gap
  filling (a CUDA stream's kernels must reach the device in issue order),
  so the fill decision only ever looks at one request per stream.
- ``index``    — bisect-sorted list over the level's stream heads, keyed
  by predicted duration. FIFO/SJF levels store ``(predicted_duration,
  -push_seq, uid)``: "longest head under the idle gap" is a predecessor
  search, "shortest profiled head under the gap" a successor search —
  both O(log n), and ties on duration resolve to the earliest-parked head
  either way. EDF levels store ``(predicted_duration, deadline, push_seq,
  uid)`` so the longest-fit predecessor search can resolve duration ties
  to the earliest deadline with one extra bisect to the run start.
- ``dindex``   — EDF levels only: bisect-sorted ``(deadline, push_seq,
  uid)`` over stream heads (undated requests carry ``inf``), driving
  earliest-deadline-first pops in O(log n). Maintained independently of
  the profile binding — deadlines need no predictions.

Predicted durations come from a bound ``ProfiledData``; the binding is
lazy (first indexed decision) and keyed on ``ProfiledData.version`` so a
profile (re)load invalidates cached durations and triggers one O(n log n)
rebuild instead of serving stale predictions. This is also the seam the
ONLINE measurement loop (``repro.core.online``) rides: an epoch commit
bumps ``version`` once per dirty TaskKey, and the next decision rebuilds
against the refreshed SK values — which is exactly why online updates are
batched in epochs rather than committed per kernel completion. The
binding is additionally keyed on the profile's ``cold_start`` flag:
flipping ``enable_cold_start()`` mid-run does not bump ``version`` (cold
estimates are pure functions of already-loaded state), but it changes
what ``predict_duration`` returns for unprofiled heads, so an index built
before the flip would serve stale ``-1.0`` sentinels while the O(n)
reference scan serves fresh estimates within the same decision.

Interference-aware filling (``interference=`` an enabled
``repro.core.interference.InterferenceModel``) additionally partitions
each level's duration index by the head's resource class (``cindex``:
class -> bisect-sorted entries, same tuples as ``index``). A fill
decision with a known holder class then runs the same predecessor /
successor searches once per class against a per-class limit
``idle_time / coeff(holder_class, class)`` — a candidate fits only if its
predicted duration times the pair's slowdown coefficient still fits the
gap. With no model (the pinned default) or no holder class the plain
single-index search runs unchanged, bit-identical to the
pre-interference implementation.

A request's priority must be fixed while parked (it is: priority is a
property of the owning task), so a stream never spans levels and
per-level stream heads are exactly the global stream heads.

``reference=True`` switches ``pop_highest``/``peek_highest`` to an O(n)
scan over the stream heads that recomputes every discipline key from
scratch — the oracle the differential tests pin the indexed pops against
(the fill-side oracle is ``repro.core.fikit.best_prio_fit_scan``).

``threadsafe=False`` elides the RLock (a no-op context manager) for
single-threaded drivers like the discrete-event simulator; the threaded
wall-clock engine keeps the real lock.
"""
from __future__ import annotations

import itertools
import math
import threading
from bisect import bisect_left, insort
from collections import OrderedDict, deque
from collections.abc import Mapping
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.task import NUM_PRIORITIES, KernelRequest

#: sentinel: ``ProfiledData.predict_duration`` returns -1.0 for unprofiled
#: kernels; the reference scan's ``best > -1.0`` guard excludes exactly
#: those, and the indexed predecessor search must agree.
_UNPROFILED = -1.0

#: The queue-discipline registry. To add a discipline: append its name
#: here, implement the indexed selection in ``best_fit_under`` and
#: ``_pop_choice``, the O(n) oracles in ``_pop_choice_scan`` and
#: ``repro.core.fikit.best_prio_fit_scan``, and extend the randomized
#: differential suite in ``tests/test_policy_differential.py`` (the
#: ROADMAP's rule for touching decision logic).
QUEUE_DISCIPLINES: Tuple[str, ...] = ("fifo", "sjf", "edf")

#: Accepted ``discipline_by_level`` / ``FikitPolicy(discipline=...)`` spec:
#: a single name for all levels, a ``{level: name}`` mapping (unnamed
#: levels default to ``fifo``), or a full per-level sequence.
QueueDisciplineSpec = Union[None, str, Mapping, Sequence]


def _check_discipline(name) -> str:
    if name not in QUEUE_DISCIPLINES:
        raise ValueError(f"unknown queue discipline: {name!r} "
                         f"(known: {sorted(QUEUE_DISCIPLINES)})")
    return name


def normalize_disciplines(spec: QueueDisciplineSpec,
                          levels: int) -> Tuple[str, ...]:
    """Resolve a discipline spec to one name per level, validating names.

    ``None`` or ``"fifo"`` -> all-FIFO; a single name applies to every
    level; a mapping names specific levels (others FIFO); a sequence must
    name all ``levels`` levels. Unknown names or out-of-range levels raise
    ``ValueError``."""
    if spec is None:
        return ("fifo",) * levels
    if isinstance(spec, str):
        return (_check_discipline(spec),) * levels
    if isinstance(spec, Mapping):
        for lvl, name in spec.items():
            if not (isinstance(lvl, int) and 0 <= lvl < levels):
                raise ValueError(
                    f"discipline level {lvl!r} out of range [0, {levels})")
            _check_discipline(name)
        return tuple(spec.get(p, "fifo") for p in range(levels))
    names = tuple(spec)
    if len(names) != levels:
        raise ValueError(f"discipline_by_level sequence must name all "
                         f"{levels} levels, got {len(names)}")
    for name in names:
        _check_discipline(name)
    return names


def _dl(req: KernelRequest) -> float:
    """EDF sort key: undated requests sort after every dated one."""
    return req.deadline if req.deadline is not None else math.inf


class _NullLock:
    """No-op reentrant context manager for single-threaded fast paths."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_LOCK = _NullLock()


class _Level:
    """One priority level's coupled FIFO / stream / index views."""

    __slots__ = ("discipline", "fifo", "seq", "streams", "index", "indexed",
                 "dindex", "dindexed", "cindex", "cindexed")

    def __init__(self, discipline: str = "fifo"):
        self.discipline = discipline
        self.fifo: "OrderedDict[int, KernelRequest]" = OrderedDict()
        self.seq: Dict[int, int] = {}              # uid -> push sequence
        self.streams: Dict[tuple, deque] = {}      # stream -> parked reqs
        self.index: List[tuple] = []               # duration index (heads)
        self.indexed: Dict[int, tuple] = {}
        self.dindex: List[tuple] = []              # EDF deadline index
        self.dindexed: Dict[int, tuple] = {}
        self.cindex: Dict[str, List[tuple]] = {}   # class -> duration index
        self.cindexed: Dict[int, str] = {}         # uid -> resource class


def _stream_of(req: KernelRequest) -> tuple:
    return (req.task_key, req.task_instance)


class PriorityQueues:
    def __init__(self, levels: int = NUM_PRIORITIES, *,
                 profiled=None, threadsafe: bool = True,
                 discipline_by_level: QueueDisciplineSpec = None,
                 reference: bool = False, interference=None):
        self.levels = levels
        self._disciplines = normalize_disciplines(discipline_by_level,
                                                  levels)
        self._levels: List[_Level] = [_Level(d) for d in self._disciplines]
        self._any_nonfifo = any(d != "fifo" for d in self._disciplines)
        self._reference = reference
        self._size = 0
        self._lock = threading.RLock() if threadsafe else _NULL_LOCK
        self._push_seq = itertools.count()
        self._profiled = profiled
        self._version = profiled.version if profiled is not None else -1
        self._cold = profiled.cold_start if profiled is not None else False
        self._interference = interference
        self._iron = (interference is not None
                      and getattr(interference, "enabled", False))

    def discipline_of(self, priority: int) -> str:
        """The queue discipline governing level ``priority``."""
        return self._disciplines[priority]

    @property
    def bound_version(self) -> int:
        """The ``ProfiledData.version`` the duration index was last built
        against (-1: never bound). A mismatch with the live profile's
        ``version`` means the next indexed decision pays one O(n log n)
        rebuild — the invalidation contract the online measurement tests
        pin."""
        return self._version

    # -------------------------------------------------------------- mutation
    def push(self, req: KernelRequest) -> None:
        with self._lock:
            lvl = self._levels[req.priority]
            seq = next(self._push_seq)
            lvl.fifo[req.uid] = req
            lvl.seq[req.uid] = seq
            stream = _stream_of(req)
            dq = lvl.streams.get(stream)
            if dq is None:
                dq = lvl.streams[stream] = deque()
            dq.append(req)
            if len(dq) == 1:
                if self._profiled is not None:
                    self._index_head(lvl, req, seq)
                if lvl.discipline == "edf":
                    self._dindex_head(lvl, req, seq)
            self._size += 1

    def remove(self, req: KernelRequest) -> None:
        with self._lock:
            self._remove(req)

    def pop_highest(self) -> Optional[KernelRequest]:
        """Pop one request from the highest-priority non-empty queue,
        selected by that level's discipline (FIFO: oldest; SJF: shortest
        predicted head; EDF: earliest-deadline head). Only stream HEADS are
        popped, so a pop can never reorder a stream. O(1) for FIFO levels,
        O(log n) for SJF/EDF."""
        with self._lock:
            if self._any_nonfifo and self._profiled is not None:
                self.ensure_index(self._profiled)
            for lvl in self._levels:
                if lvl.fifo:
                    req = self._pop_choice(lvl)
                    self._remove(req)
                    return req
        return None

    def _pop_choice(self, lvl: _Level) -> KernelRequest:
        """Select (without removing) the request a pop should release from
        ``lvl`` under its discipline."""
        if self._reference:
            return self._pop_choice_scan(lvl)
        disc = lvl.discipline
        if disc == "sjf" and lvl.index:
            # successor run of the minimal duration; earliest-parked tie.
            # (-seq <= 0 < 1, so (dur, 1) upper-bounds the dur run.)
            d0 = lvl.index[0][0]
            k = bisect_left(lvl.index, (d0, 1))
            return lvl.fifo[lvl.index[k - 1][2]]
        if disc == "edf" and lvl.dindex:
            return lvl.fifo[lvl.dindex[0][2]]
        # FIFO level — or a discipline level with no index to serve it
        # (no bound profile): degrade to FIFO order deterministically.
        return next(iter(lvl.fifo.values()))

    def _pop_choice_scan(self, lvl: _Level) -> KernelRequest:
        """O(n) reference oracle for ``_pop_choice``: recompute every
        stream head's discipline key from scratch (fresh predictions, no
        index). Pinned trace-identical to the indexed path by
        ``tests/test_policy_differential.py``."""
        disc = lvl.discipline
        best = None
        best_key = None
        for dq in lvl.streams.values():
            head = dq[0]
            seq = lvl.seq[head.uid]
            if disc == "sjf":
                dur = (self._profiled.predict_duration(head.task_key,
                                                       head.kernel_id)
                       if self._profiled is not None else _UNPROFILED)
                key = (dur, seq)
            elif disc == "edf":
                key = (_dl(head), seq)
            else:
                key = (seq,)
            if best is None or key < best_key:
                best, best_key = head, key
        return best

    def _remove(self, req: KernelRequest) -> None:
        lvl = self._levels[req.priority]
        if req.uid not in lvl.fifo:
            raise ValueError(f"{req!r} not queued")
        del lvl.fifo[req.uid]
        del lvl.seq[req.uid]
        stream = _stream_of(req)
        dq = lvl.streams[stream]
        if dq[0] is req:
            dq.popleft()
            self._unindex(lvl, req)
            if dq:                      # successor becomes the stream head
                head = dq[0]
                if self._profiled is not None:
                    self._index_head(lvl, head, lvl.seq[head.uid])
                if lvl.discipline == "edf":
                    self._dindex_head(lvl, head, lvl.seq[head.uid])
            else:
                del lvl.streams[stream]
        else:                           # mid-stream removal: rare, O(stream)
            dq.remove(req)
        self._size -= 1

    # -------------------------------------------------------- head indexes
    def _index_head(self, lvl: _Level, req: KernelRequest, seq: int) -> None:
        dur = self._profiled.predict_duration(req.task_key, req.kernel_id)
        if lvl.discipline == "edf":
            entry = (dur, _dl(req), seq, req.uid)
        else:
            entry = (dur, -seq, req.uid)
        insort(lvl.index, entry)
        lvl.indexed[req.uid] = entry
        if self._iron:
            cls = self._profiled.predict_class(req.task_key, req.kernel_id)
            cidx = lvl.cindex.get(cls)
            if cidx is None:
                cidx = lvl.cindex[cls] = []
            insort(cidx, entry)
            lvl.cindexed[req.uid] = cls

    def _dindex_head(self, lvl: _Level, req: KernelRequest,
                     seq: int) -> None:
        dentry = (_dl(req), seq, req.uid)
        insort(lvl.dindex, dentry)
        lvl.dindexed[req.uid] = dentry

    def _unindex(self, lvl: _Level, req: KernelRequest) -> None:
        entry = lvl.indexed.pop(req.uid, None)
        if entry is not None:
            i = bisect_left(lvl.index, entry)
            # entry uids are unique, so the slot is exact
            del lvl.index[i]
            cls = lvl.cindexed.pop(req.uid, None)
            if cls is not None:
                cidx = lvl.cindex[cls]
                del cidx[bisect_left(cidx, entry)]
        dentry = lvl.dindexed.pop(req.uid, None)
        if dentry is not None:
            del lvl.dindex[bisect_left(lvl.dindex, dentry)]

    def ensure_index(self, profiled) -> None:
        """Bind/refresh the head indexes against ``profiled``.

        O(1) when already bound to this profile version; a full O(n log n)
        rebuild when the profile object, its version, or its ``cold_start``
        flag changed (profiles reload rarely; decisions happen constantly).
        The cold flag is part of the binding key because flipping
        ``enable_cold_start()`` changes unprofiled heads' predictions
        without bumping ``version`` — an index built before the flip would
        disagree with the fresh-prediction reference scan."""
        if (profiled is self._profiled and self._version == profiled.version
                and self._cold == profiled.cold_start):
            return
        with self._lock:
            self._profiled = profiled
            self._version = profiled.version
            self._cold = profiled.cold_start
            for lvl in self._levels:
                entries = []
                dentries = []
                centries: Dict[str, List[tuple]] = {}
                for dq in lvl.streams.values():
                    head = dq[0]
                    seq = lvl.seq[head.uid]
                    dur = profiled.predict_duration(head.task_key,
                                                    head.kernel_id)
                    if lvl.discipline == "edf":
                        entry = (dur, _dl(head), seq, head.uid)
                        dentries.append((_dl(head), seq, head.uid))
                    else:
                        entry = (dur, -seq, head.uid)
                    entries.append(entry)
                    if self._iron:
                        cls = profiled.predict_class(head.task_key,
                                                     head.kernel_id)
                        centries.setdefault(cls, []).append(entry)
                entries.sort()
                lvl.index = entries
                lvl.indexed = {e[-1]: e for e in entries}
                if lvl.discipline == "edf":
                    dentries.sort()
                    lvl.dindex = dentries
                    lvl.dindexed = {e[-1]: e for e in dentries}
                if self._iron:
                    for cidx in centries.values():
                        cidx.sort()
                    lvl.cindex = centries
                    lvl.cindexed = {e[-1]: c
                                    for c, cidx in centries.items()
                                    for e in cidx}

    def best_fit_under(self, idle_time: float, holder_class: str = None
                       ) -> Tuple[Optional[KernelRequest], float]:
        """Gap-fill selection across levels, per-level discipline-aware.

        FIFO levels replicate the paper's Algorithm 2 bit-for-bit: the
        longest stream head with predicted duration strictly inside
        (best_so_far, idle_time); starting the running best at -1.0
        excludes unprofiled heads (the -1.0 sentinel), and descending past
        a level whose best fit is non-positive replicates the reference
        scan's ``if best_kernel_time > 0: break`` stop rule. SJF levels
        instead select the SHORTEST profiled fitting head (successor
        search); EDF levels keep the longest-fit criterion but break
        duration ties to the earliest deadline. An SJF/EDF level that holds
        any profiled fitting head claims the decision (search stops there);
        its candidate replaces a carried best only if strictly longer — the
        same strictly-better rule FIFO levels apply.

        With a bound enabled interference model AND a ``holder_class``,
        the same searches run per resource class against a tightened
        per-class limit ``idle_time / coeff(holder_class, class)`` — see
        ``_best_fit_interference``. Without either, the plain single-index
        search below runs unchanged.

        At most a few bisects per level; at most ``levels`` levels. Does
        NOT dequeue. Call ``ensure_index`` first. The O(n) oracle with
        identical semantics is ``repro.core.fikit.best_prio_fit_scan``."""
        if holder_class is not None and self._iron:
            return self._best_fit_interference(idle_time, holder_class)
        best_req: Optional[KernelRequest] = None
        best_dur = _UNPROFILED
        for lvl in self._levels:
            idx = lvl.index
            if not idx:
                continue
            disc = lvl.discipline
            if disc == "fifo":
                i = bisect_left(idx, (idle_time,))
                if i == 0:
                    continue                # every head >= idle_time
                dur, _negseq, uid = idx[i - 1]
                if dur <= best_dur:
                    continue                # not strictly longer
                best_req, best_dur = lvl.fifo[uid], dur
                if best_dur > 0:
                    break                   # fit found at this level
            elif disc == "sjf":
                # successor search: shortest PROFILED head under the gap.
                # (-seq <= 0 < 1 bounds the unprofiled sentinel run.)
                j = bisect_left(idx, (_UNPROFILED, 1))
                if j == len(idx):
                    continue                # no profiled heads
                dur = idx[j][0]
                if dur >= idle_time:
                    continue                # shortest profiled doesn't fit
                if dur > best_dur:
                    k = bisect_left(idx, (dur, 1))   # earliest-parked tie
                    best_req, best_dur = lvl.fifo[idx[k - 1][2]], dur
                break                       # this level claims the decision
            else:  # edf
                i = bisect_left(idx, (idle_time,))
                if i == 0:
                    continue
                dur = idx[i - 1][0]
                if dur <= _UNPROFILED:
                    continue                # only unprofiled heads fit
                if dur > best_dur:
                    lo = bisect_left(idx, (dur,))    # earliest-deadline tie
                    best_req, best_dur = lvl.fifo[idx[lo][3]], dur
                break                       # this level claims the decision
        return best_req, best_dur

    def _best_fit_interference(self, idle_time: float, holder_class: str
                               ) -> Tuple[Optional[KernelRequest], float]:
        """Interference-aware ``best_fit_under``: the per-level search runs
        once per resource class over ``cindex`` with a per-class limit
        ``idle_time / coeff(holder_class, class)``, then merges the
        per-class candidates under the SAME selection/tie rules the plain
        search applies (FIFO/EDF: longest raw duration; SJF: shortest;
        ties to earliest-parked, EDF duration ties to earliest deadline).
        Returned durations stay RAW predicted durations — the caller debits
        the gap by the coefficient-scaled effective duration. Both sides of
        the fit comparison use ``dur < limit`` (never ``dur * coeff <
        idle_time``) so the O(n) scan oracle computes bit-identical
        float comparisons."""
        model = self._interference
        best_req: Optional[KernelRequest] = None
        best_dur = _UNPROFILED
        for lvl in self._levels:
            disc = lvl.discipline
            if disc == "fifo":
                cand = None          # best (dur, -seq, uid) across classes
                for cls, cidx in lvl.cindex.items():
                    if not cidx:
                        continue
                    limit = idle_time / model.coeff(holder_class, cls)
                    i = bisect_left(cidx, (limit,))
                    if i == 0:
                        continue            # every head of cls >= limit
                    e = cidx[i - 1]
                    if cand is None or e > cand:
                        cand = e            # longest; tie: earliest-parked
                if cand is None:
                    continue
                dur = cand[0]
                if dur <= best_dur:
                    continue                # unprofiled, or not longer
                best_req, best_dur = lvl.fifo[cand[2]], dur
                if best_dur > 0:
                    break                   # fit found at this level
            elif disc == "sjf":
                cand = None                 # min (dur, seq, uid)
                for cls, cidx in lvl.cindex.items():
                    if not cidx:
                        continue
                    limit = idle_time / model.coeff(holder_class, cls)
                    j = bisect_left(cidx, (_UNPROFILED, 1))
                    if j == len(cidx):
                        continue            # no profiled heads of cls
                    dur = cidx[j][0]
                    if dur >= limit:
                        continue            # shortest of cls doesn't fit
                    k = bisect_left(cidx, (dur, 1))  # earliest-parked tie
                    e = cidx[k - 1]
                    key = (dur, -e[1], e[2])
                    if cand is None or key < cand:
                        cand = key
                if cand is None:
                    continue
                dur = cand[0]
                if dur > best_dur:
                    best_req, best_dur = lvl.fifo[cand[2]], dur
                break                       # this level claims the decision
            else:  # edf
                cand = None                 # min (-dur, deadline, seq)
                cand_uid = None
                for cls, cidx in lvl.cindex.items():
                    if not cidx:
                        continue
                    limit = idle_time / model.coeff(holder_class, cls)
                    i = bisect_left(cidx, (limit,))
                    if i == 0:
                        continue
                    dur = cidx[i - 1][0]
                    if dur <= _UNPROFILED:
                        continue            # only unprofiled heads fit
                    lo = bisect_left(cidx, (dur,))   # earliest-deadline tie
                    e = cidx[lo]
                    key = (-e[0], e[1], e[2])
                    if cand is None or key < cand:
                        cand, cand_uid = key, e[3]
                if cand is None:
                    continue
                dur = -cand[0]
                if dur > best_dur:
                    best_req, best_dur = lvl.fifo[cand_uid], dur
                break                       # this level claims the decision
        return best_req, best_dur

    # ------------------------------------------------------------ inspection
    def __getitem__(self, priority: int) -> Tuple[KernelRequest, ...]:
        """Level contents in FIFO order (read-only snapshot)."""
        return tuple(self._levels[priority].fifo.values())

    def peek_highest(self) -> Optional[KernelRequest]:
        """The request ``pop_highest`` would release, without removing it."""
        with self._lock:
            if self._any_nonfifo and self._profiled is not None:
                self.ensure_index(self._profiled)
            for lvl in self._levels:
                if lvl.fifo:
                    return self._pop_choice(lvl)
        return None

    def highest_nonempty(self) -> Optional[int]:
        with self._lock:
            for p, lvl in enumerate(self._levels):
                if lvl.fifo:
                    return p
        return None

    def __len__(self) -> int:
        return self._size

    def __iter__(self) -> Iterator[KernelRequest]:
        with self._lock:
            snapshot = [req for lvl in self._levels
                        for req in lvl.fifo.values()]
        return iter(snapshot)

    def lock(self):
        return self._lock
