"""Priority queues Q0..Q9 (paper Fig 7): the scheduler scans queues from
highest (Q0) to lowest (Q9); within a queue, requests keep FIFO order.

Indexed representation
----------------------
The paper's <5% overhead budget means each scheduling decision must cost
far less than a 0.1-2 ms kernel launch, at production queue depths. The
naive structure (one deque per level, linear scans everywhere) makes
``best_prio_fit`` O(total queued) per fill decision. Each level therefore
maintains three coupled views:

- ``fifo``     — OrderedDict uid -> request: park order; O(1) push, O(1)
  remove-by-request, O(1) oldest (``pop_highest``/``peek_highest``).
- ``streams``  — (task_key, instance) -> deque of that stream's parked
  requests in seq order. Only the *head* of a stream is eligible for gap
  filling (a CUDA stream's kernels must reach the device in issue order),
  so the fill decision only ever looks at one request per stream.
- ``index``    — bisect-sorted list of ``(predicted_duration, -push_seq,
  uid)`` over the level's stream heads. "Longest head that still fits the
  idle gap" is a predecessor search: O(log n) comparisons. Ties on
  duration resolve to the earliest-parked head (``-push_seq``), matching
  the reference scan's first-seen-wins behavior exactly.

Predicted durations come from a bound ``ProfiledData``; the binding is
lazy (first indexed decision) and keyed on ``ProfiledData.version`` so a
profile (re)load invalidates cached durations and triggers one O(n log n)
rebuild instead of serving stale predictions.

A request's priority must be fixed while parked (it is: priority is a
property of the owning task), so a stream never spans levels and
per-level stream heads are exactly the global stream heads.

``threadsafe=False`` elides the RLock (a no-op context manager) for
single-threaded drivers like the discrete-event simulator; the threaded
wall-clock engine keeps the real lock.
"""
from __future__ import annotations

import itertools
import threading
from bisect import bisect_left, insort
from collections import OrderedDict, deque
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.task import NUM_PRIORITIES, KernelRequest

#: sentinel: ``ProfiledData.predict_duration`` returns -1.0 for unprofiled
#: kernels; the reference scan's ``best > -1.0`` guard excludes exactly
#: those, and the indexed predecessor search must agree.
_UNPROFILED = -1.0


class _NullLock:
    """No-op reentrant context manager for single-threaded fast paths."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_LOCK = _NullLock()


class _Level:
    """One priority level's coupled FIFO / stream / duration-index views."""

    __slots__ = ("fifo", "seq", "streams", "index", "indexed")

    def __init__(self):
        self.fifo: "OrderedDict[int, KernelRequest]" = OrderedDict()
        self.seq: Dict[int, int] = {}              # uid -> push sequence
        self.streams: Dict[tuple, deque] = {}      # stream -> parked reqs
        self.index: List[Tuple[float, int, int]] = []
        self.indexed: Dict[int, Tuple[float, int, int]] = {}


def _stream_of(req: KernelRequest) -> tuple:
    return (req.task_key, req.task_instance)


class PriorityQueues:
    def __init__(self, levels: int = NUM_PRIORITIES, *,
                 profiled=None, threadsafe: bool = True):
        self.levels = levels
        self._levels: List[_Level] = [_Level() for _ in range(levels)]
        self._size = 0
        self._lock = threading.RLock() if threadsafe else _NULL_LOCK
        self._push_seq = itertools.count()
        self._profiled = profiled
        self._version = profiled.version if profiled is not None else -1

    # -------------------------------------------------------------- mutation
    def push(self, req: KernelRequest) -> None:
        with self._lock:
            lvl = self._levels[req.priority]
            seq = next(self._push_seq)
            lvl.fifo[req.uid] = req
            lvl.seq[req.uid] = seq
            stream = _stream_of(req)
            dq = lvl.streams.get(stream)
            if dq is None:
                dq = lvl.streams[stream] = deque()
            dq.append(req)
            if len(dq) == 1 and self._profiled is not None:
                self._index_head(lvl, req, seq)
            self._size += 1

    def remove(self, req: KernelRequest) -> None:
        with self._lock:
            self._remove(req)

    def pop_highest(self) -> Optional[KernelRequest]:
        """FIFO pop from the highest-priority non-empty queue. O(1)."""
        with self._lock:
            for lvl in self._levels:
                if lvl.fifo:
                    req = next(iter(lvl.fifo.values()))
                    self._remove(req)
                    return req
        return None

    def _remove(self, req: KernelRequest) -> None:
        lvl = self._levels[req.priority]
        if req.uid not in lvl.fifo:
            raise ValueError(f"{req!r} not queued")
        del lvl.fifo[req.uid]
        del lvl.seq[req.uid]
        stream = _stream_of(req)
        dq = lvl.streams[stream]
        if dq[0] is req:
            dq.popleft()
            self._unindex(lvl, req)
            if dq:                      # successor becomes the stream head
                head = dq[0]
                if self._profiled is not None:
                    self._index_head(lvl, head, lvl.seq[head.uid])
            else:
                del lvl.streams[stream]
        else:                           # mid-stream removal: rare, O(stream)
            dq.remove(req)
        self._size -= 1

    # -------------------------------------------------------- duration index
    def _index_head(self, lvl: _Level, req: KernelRequest, seq: int) -> None:
        dur = self._profiled.predict_duration(req.task_key, req.kernel_id)
        entry = (dur, -seq, req.uid)
        insort(lvl.index, entry)
        lvl.indexed[req.uid] = entry

    def _unindex(self, lvl: _Level, req: KernelRequest) -> None:
        entry = lvl.indexed.pop(req.uid, None)
        if entry is not None:
            i = bisect_left(lvl.index, entry)
            # entry uids are unique, so the slot is exact
            del lvl.index[i]

    def ensure_index(self, profiled) -> None:
        """Bind/refresh the duration index against ``profiled``.

        O(1) when already bound to this profile version; a full O(n log n)
        rebuild when the profile object or its version changed (profiles
        reload rarely; decisions happen constantly)."""
        if profiled is self._profiled and self._version == profiled.version:
            return
        with self._lock:
            self._profiled = profiled
            self._version = profiled.version
            for lvl in self._levels:
                entries = []
                for dq in lvl.streams.values():
                    head = dq[0]
                    dur = profiled.predict_duration(head.task_key,
                                                    head.kernel_id)
                    entries.append((dur, -lvl.seq[head.uid], head.uid))
                entries.sort()
                lvl.index = entries
                lvl.indexed = {e[2]: e for e in entries}

    def best_fit_under(self, idle_time: float
                       ) -> Tuple[Optional[KernelRequest], float]:
        """Longest stream-head with predicted duration strictly inside
        (best_so_far, idle_time), from the highest-priority level holding a
        positive fit. Starting the running best at -1.0 excludes unprofiled
        heads (the -1.0 sentinel), and descending past a level whose best
        fit is non-positive replicates the reference scan's
        ``if best_kernel_time > 0: break`` stop rule bit-for-bit.

        Predecessor search per level; at most ``levels`` bisects total.
        Does NOT dequeue. Call ``ensure_index`` first."""
        best_req: Optional[KernelRequest] = None
        best_dur = _UNPROFILED
        for lvl in self._levels:
            idx = lvl.index
            if not idx:
                continue
            i = bisect_left(idx, (idle_time,))
            if i == 0:
                continue                    # every head >= idle_time
            dur, _negseq, uid = idx[i - 1]
            if dur <= best_dur:
                continue                    # not strictly longer
            best_req, best_dur = lvl.fifo[uid], dur
            if best_dur > 0:
                break                       # fit found at this level
        return best_req, best_dur

    # ------------------------------------------------------------ inspection
    def __getitem__(self, priority: int) -> Tuple[KernelRequest, ...]:
        """Level contents in FIFO order (read-only snapshot)."""
        return tuple(self._levels[priority].fifo.values())

    def peek_highest(self) -> Optional[KernelRequest]:
        with self._lock:
            for lvl in self._levels:
                if lvl.fifo:
                    return next(iter(lvl.fifo.values()))
        return None

    def highest_nonempty(self) -> Optional[int]:
        with self._lock:
            for p, lvl in enumerate(self._levels):
                if lvl.fifo:
                    return p
        return None

    def __len__(self) -> int:
        return self._size

    def __iter__(self) -> Iterator[KernelRequest]:
        with self._lock:
            snapshot = [req for lvl in self._levels
                        for req in lvl.fifo.values()]
        return iter(snapshot)

    def lock(self):
        return self._lock
