"""FIKIT scheduler over a serial device — discrete-event simulator.

Models the paper's system (Figs 7, 8, 11, 12):

- Each *client* (one per task) issues kernel launches on its own host
  timeline. A synchronous client (``max_inflight=1``) issues kernel i+1
  only after observing kernel i's completion plus a host gap — this creates
  the inter-kernel device idle ("gap") FIKIT scavenges. An async client
  (``max_inflight=m>1``) issues launch i+1 a host-gap after launch i with
  up to m kernels in flight — the CUDA-stream behavior that lets a
  device-bound low-priority task flood the FIFO device queue and inflate a
  high-priority co-tenant's JCT in default sharing mode (Fig 2 "Sharing 1").
- Each *device* executes launched kernels serially in launch (FIFO) order.
  Kernels are non-preemptible. ``devices=K`` models a K-device node: one
  independent serial timeline per device.
- Modes (see ``repro.core.policy.Mode``): EXCLUSIVE, SHARING, FIKIT, and
  PREEMPT (kernel-boundary preemptive sharing).

ALL scheduling decisions — holder election, routing, gap open/close with
feedback, the bounded fill loop, release-on-task-done, overshoot — live in
``repro.core.policy.FikitPolicy``; device election and cross-device work
stealing live in ``repro.core.placement.PlacementLayer``, which owns one
policy per device (K=1 is a pinned-identical pass-through). This module is
a thin driver: it owns the event heap, the client issue model, and the
virtual device timelines, and hands every decision to the shared
placement/policy stack so the simulator and the wall-clock engine can
never diverge.

Determinism: the event heap is ordered by (time, seq); ties resolve by
insertion order, so simulations are exactly reproducible.
"""
from __future__ import annotations

import heapq
import itertools
import random as _random
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core import jobstore as _js
from repro.core.fikit import EPSILON
from repro.core.interference import COMPUTE_BOUND, InterferenceModel
from repro.core.jobstore import coerce_store, spec_to_obj
from repro.core.online import OnlineConfig, OnlineMeasurement
from repro.core.placement import DisciplineSpec, PlacementLayer
from repro.core.policy import Mode
from repro.core.profiler import ProfiledData, Profiler
from repro.core.task import KernelRequest, TaskSpec

__all__ = ["Mode", "KernelExec", "TaskResult", "SimReport", "SimScheduler",
           "OnlineConfig", "measure_task", "profile_tasks"]


@dataclass
class KernelExec:
    """One executed kernel interval on a device timeline."""
    task: int
    seq: int
    start: float
    end: float
    filler: bool = False
    device: int = 0


@dataclass
class TaskResult:
    arrival: float
    start: float = -1.0
    completion: float = -1.0

    @property
    def jct(self) -> float:
        return self.completion - self.arrival


@dataclass
class SimReport:
    results: List[TaskResult]
    timeline: List[KernelExec]
    fills: int = 0
    overshoot_time: float = 0.0   # filler time past actual gap end ("ovh 2")
    devices: int = 1
    steals: int = 0
    #: deadline-tagged tasks that completed after their deadline / that
    #: carried one at all (EDF instrumentation; 0/0 without deadlines)
    deadline_misses: int = 0
    deadlines_tagged: int = 0
    #: ``OnlineMeasurement.stats()`` snapshot (observation/commit/drift
    #: counters) when the run had the online loop enabled; None otherwise
    online_stats: Optional[dict] = None
    #: total simulator events processed (arrival/issue/kernel_end) — the
    #: numerator of the fleet benchmark's events/sec throughput metric
    events: int = 0
    #: per-device busy-time accumulators, kept even when the per-kernel
    #: ``timeline`` is not recorded (``SimScheduler(record_timeline=
    #: False)``) so utilization analytics survive fleet-scale runs
    busy: Optional[List[float]] = None

    def jct(self, i: int) -> float:
        return self.results[i].jct

    @property
    def deadline_miss_rate(self) -> float:
        """Fraction of deadline-tagged tasks that missed (0.0 if none)."""
        if self.deadlines_tagged == 0:
            return 0.0
        return self.deadline_misses / self.deadlines_tagged

    @property
    def makespan(self) -> float:
        return max((r.completion for r in self.results), default=0.0)

    def device_busy(self, device: Optional[int] = None) -> float:
        if not self.timeline and self.busy is not None:
            # timeline-off run: the accumulators are the only record
            return (sum(self.busy) if device is None
                    else self.busy[device])
        return sum(k.end - k.start for k in self.timeline
                   if device is None or k.device == device)

    def utilization(self) -> float:
        """Aggregate utilization: busy time over makespan x devices."""
        ms = self.makespan
        return self.device_busy() / (ms * self.devices) if ms > 0 else 0.0

    def per_device_utilization(self) -> List[float]:
        ms = self.makespan
        if ms <= 0:
            return [0.0] * self.devices
        return [self.device_busy(d) / ms for d in range(self.devices)]


class SimScheduler:
    def __init__(self, tasks: List[TaskSpec], mode: Mode,
                 profiled: Optional[ProfiledData] = None,
                 pipeline_depth: int = 2, feedback: bool = True,
                 epsilon: float = EPSILON,
                 measurement_overhead: float = 0.0,
                 jitter: float = 0.0, seed: int = 0,
                 trace: str = "list", reference: bool = False,
                 devices: int = 1,
                 discipline: DisciplineSpec = "least_loaded",
                 queue_discipline="fifo",
                 steal: bool = True,
                 online=None,
                 interference=None,
                 interference_env=None,
                 jobstore=None,
                 fault_plan=None,
                 job_ids=None,
                 seq_base=None,
                 reference_core: bool = False,
                 record_timeline: bool = True):
        """measurement_overhead: multiplier on kernel durations (the paper's
        20-80% measuring-stage slowdown), used to simulate the measurement
        phase. jitter: multiplicative gaussian noise on true durations/gaps
        (run-to-run variance the SK/SG averages + feedback must absorb).
        trace/reference forward to the per-device FikitPolicy (trace sink
        selection; the O(n) reference oracle for differential testing).
        devices/discipline/steal configure the PlacementLayer: K serial
        device timelines, device election per task, and idle-device work
        stealing (no-ops at devices=1). queue_discipline selects the
        per-level intra-device queue ordering ("fifo" default / "sjf" /
        "edf" — see repro.core.queues.QUEUE_DISCIPLINES); TaskSpec.deadline
        tags flow onto every kernel request for edf levels and the
        SimReport.deadline_misses counter. online (None / True /
        repro.core.online.OnlineConfig) enables the live SK/SG refinement
        loop: every simulated kernel completion feeds the
        OnlineMeasurement, epoch commits reload the shared profile
        mid-run, and SimReport.online_stats carries the counters; None
        (default) builds nothing and is decision-trace-identical to the
        pre-online simulator. interference (None / True / mapping /
        repro.core.interference.InterferenceModel) enables
        interference-aware gap filling: fill candidates are bounded by
        idle_time / coeff(holder_class, filler_class) and the gap is
        debited by the effective (scaled) duration; None or a disabled
        model keeps every decision bit-identical to interference-off.
        interference_env ({(holder_class, filler_class): slowdown})
        configures the SIMULATED PHYSICAL contention: a filler kernel
        sharing the device with a gap holder runs slowdown x longer,
        keyed by the GROUND-TRUTH classes from TraceKernel.kclass —
        independent of what the scheduler believes, so a wrong model
        visibly hurts JCT.

        reference_core=True keeps the original per-event loop (string-
        dispatched events, one method call per event) as the driver —
        the O(n)-style reference the fast-core differential suite
        (tests/test_sim_fastcore.py) pins the default core against. The
        default fast core processes the SAME events in the SAME order
        through the SAME placement/policy stack — only the event
        representation changes (integer-coded flat heap entries,
        slot-indexed per-task kernel records, hoisted feature flags) —
        so decision traces and timelines are bit-identical by
        construction AND by test. An attached jobstore or fault_plan
        automatically selects the reference core (the ops plane hooks
        live only there; both are I/O-bound anyway).
        record_timeline=False skips building the per-kernel
        ``KernelExec`` timeline (hundreds of MB at fleet scale) while
        keeping per-device busy-time accumulators, so
        ``SimReport.utilization``/``per_device_utilization`` still work.

        jobstore (None / path / repro.core.jobstore.JobStore) attaches
        the durable ops plane: submissions, per-kernel completion
        watermarks (written at each kernel boundary BEFORE the boundary
        is otherwise processed — the write-ahead contract crash recovery
        rides on), terminal states, and profile snapshots. The store
        only OBSERVES: decisions are bit-identical with or without one.
        fault_plan (repro.core.faults.FaultPlan) scripts lifecycle verbs
        and/or a process crash at global kernel-boundary indices; an
        inert plan is decision-trace-identical to None. job_ids/seq_base
        are the recovery inputs (see ``SimScheduler.recover``): the
        persistent store ids to keep recording under and each task's
        completion watermark, so a resumed task's completions land at
        their original stream indices."""
        self.tasks = tasks
        self.mode = mode
        self.profiled = profiled or ProfiledData()
        self.meas_ovh = measurement_overhead
        self.jitter = jitter
        self._rng = _random.Random(seed)

        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.devices = devices
        self.device_free = [0.0] * devices
        self.record_timeline = record_timeline
        self.timeline: List[KernelExec] = []
        self._busy = [0.0] * devices
        self.events = 0
        self.results = [TaskResult(arrival=t.arrival) for t in tasks]
        n = len(tasks)
        self._next_k = [0] * n          # next kernel index to issue
        self._done_k = [0] * n          # kernels completed
        self._issued = [0] * n
        self._pending_issue: List[Optional[int]] = [None] * n
        # ops plane: durable store + scripted faults + lifecycle verbs
        self.jobstore = coerce_store(jobstore)
        self.fault_plan = fault_plan
        self.job_ids: List[Optional[int]] = (
            list(job_ids) if job_ids is not None else [None] * n)
        self.seq_base: List[int] = (
            list(seq_base) if seq_base is not None else [0] * n)
        self.cancelled: set = set()
        self.paused_tasks: set = set()
        self._begun = [False] * n
        self._snap_commits = 0
        # the fast core has no ops-plane hooks: a durable store or a
        # scripted fault plan pins the run to the reference loop
        self.reference_core = bool(reference_core)
        self._use_fast = (not reference_core and self.jobstore is None
                          and fault_plan is None)
        self.interference = InterferenceModel.coerce(interference)
        if self.interference is not None and self.interference.enabled:
            # expose on the shared profile so checkpointing can persist
            # the (possibly online-refined) coefficient table
            self.profiled.interference = self.interference
        self._ienv = dict(interference_env) if interference_env else None
        self._true_class = {}
        if self._ienv is not None:
            for ti, t in enumerate(tasks):
                for k in t.kernels:
                    self._true_class[(ti, k.kid)] = \
                        k.kclass or COMPUTE_BOUND
        cfg = OnlineConfig.coerce(online)
        self.online = (OnlineMeasurement(self.profiled, cfg,
                                         clock=lambda: self.now,
                                         interference=self.interference)
                       if cfg is not None else None)
        # single-threaded discrete-event driver: elide the queue lock
        self.placement = PlacementLayer(devices, mode, self.profiled,
                                        discipline=discipline, steal=steal,
                                        queue_discipline=queue_discipline,
                                        pipeline_depth=pipeline_depth,
                                        feedback=feedback, epsilon=epsilon,
                                        clock=lambda: self.now,
                                        launch=self._device_launch,
                                        threadsafe=False, trace=trace,
                                        reference=reference,
                                        online=self.online,
                                        interference=self.interference)
        # single-device alias: the decision core the differential suite
        # diffs against a bare FikitPolicy (placement K=1 is pass-through)
        self.policy = self.placement.policies[0]
        self.queues = self.policy.queues

    # ----------------------------------------------------------------- noise
    def _noisy(self, x: float) -> float:
        if self.jitter <= 0:
            return x
        return x * max(0.05, 1.0 + self._rng.gauss(0.0, self.jitter))

    # ------------------------------------------------------------- event API
    def _push(self, time: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self._heap, (time, next(self._seq), kind, payload))

    def run(self) -> SimReport:
        if self._use_fast:
            self._run_fast_loop()
        else:
            self._run_reference_loop()
        return self._report()

    def _run_reference_loop(self) -> None:
        """The original per-event loop: one string-dispatched method call
        per event. Survives as the fast core's differential oracle and as
        the only core with ops-plane hooks (jobstore writes, fault-plan
        boundaries)."""
        if self.jobstore is not None:
            # write-ahead the whole workload before the clock starts: a
            # crash BEFORE a task's arrival event must not lose the task
            # (it recovers as ``submitted``); arrival advances the row to
            # ``running`` via the same upsert
            for i, t in enumerate(self.tasks):
                state = (_js.CANCELLED if i in self.cancelled
                         else _js.SUBMITTED)
                self.job_ids[i] = self.jobstore.record_submit(
                    self.job_ids[i], t.key, t.priority,
                    n_kernels=self.seq_base[i] + len(t.kernels),
                    spec=spec_to_obj(t), deadline=t.deadline,
                    state=state, at=self.now)
        for i, t in enumerate(self.tasks):
            self._push(t.arrival, "arrival", (i,))
        events = 0
        while self._heap:
            self.now, _, kind, payload = heapq.heappop(self._heap)
            events += 1
            getattr(self, "_on_" + kind)(*payload)
        self.events = events

    def _report(self) -> SimReport:
        online_stats = None
        if self.online is not None and self.online.config.enabled:
            self.online.commit()       # flush the partial final epoch
            online_stats = self.online.stats()
        if self.jobstore is not None:
            # final checkpoint: latest (possibly online-refined) SK/SG +
            # fold the WAL so a subsequent cold open reads one file
            self.jobstore.snapshot_profiles(self.profiled, at=self.now)
            self.jobstore.checkpoint()
        tagged = [(t, r) for t, r in zip(self.tasks, self.results)
                  if t.deadline is not None]
        return SimReport(self.results, self.timeline,
                         fills=self.placement.fill_count,
                         overshoot_time=self.placement.overshoot_time,
                         devices=self.devices,
                         steals=self.placement.steal_count,
                         deadline_misses=sum(1 for t, r in tagged
                                             if r.completion > t.deadline),
                         deadlines_tagged=len(tagged),
                         online_stats=online_stats,
                         events=self.events,
                         busy=list(self._busy))

    # ------------------------------------------------------------- fast core
    #: integer event codes of the fast core's flat heap entries
    #: ``(time, seq, code, task, ...)`` — ordering semantics identical to
    #: the reference core's ``(time, seq, kind, payload)`` entries (ties
    #: resolve by insertion order via the shared seq counter)
    _EV_ARRIVAL, _EV_ISSUE, _EV_KERNEL_END = 0, 1, 2

    def _run_fast_loop(self) -> None:
        """The fleet-scale event core: the same client/device event model
        as ``_run_reference_loop``, restructured for throughput —
        integer-coded flat heap tuples (no nested payload allocation, no
        string dispatch), slot-indexed per-task kernel records (kid/
        duration/gap lists replace per-event dataclass attribute chains),
        locally-bound hot callables, and feature flags (jitter) hoisted
        out of the loop. Every event is processed in the same order with
        the same placement/policy calls, so decision traces, timelines,
        results, and RNG draw sequences are bit-identical to the
        reference core — pinned by ``tests/test_sim_fastcore.py``."""
        tasks = self.tasks
        placement = self.placement
        p_task_begin = placement.task_begin
        p_task_end = placement.task_end
        p_kernel_end = placement.kernel_end
        p_fill_complete = placement.fill_complete
        p_submit = placement.submit
        results = self.results
        issued = self._issued
        done_k = self._done_k
        next_k = self._next_k
        pending = self._pending_issue
        cancelled = self.cancelled
        begun = self._begun
        heap = self._heap
        push = heapq.heappush
        pop = heapq.heappop
        tick = self._seq.__next__
        jit = self.jitter > 0
        noisy = self._noisy
        _KR = KernelRequest

        # slot-indexed task/kernel records: one flat list per field,
        # indexed by (task, kernel) — the hot loop never walks a
        # TaskSpec/TraceKernel attribute chain
        nk: List[int] = []
        kkid: List[list] = []
        kdur: List[list] = []
        kgap: List[list] = []
        keys: List = []
        prios: List[int] = []
        maxin: List[int] = []
        dls: List = []
        arrs: List[float] = []
        for t in tasks:
            ks = t.kernels
            nk.append(len(ks))
            kkid.append([k.kid for k in ks])
            kdur.append([k.duration for k in ks])
            kgap.append([k.gap_after for k in ks])
            keys.append(t.key)
            prios.append(t.priority)
            maxin.append(t.max_inflight)
            dls.append(t.deadline)
            arrs.append(t.arrival)

        def emit_kernel_end(ti, ki, filler, device, start, end):
            push(heap, (end, tick(), 2, ti, ki, filler, device, start, end))

        self._emit_kernel_end = emit_kernel_end

        def issue(ti, ki):
            issued[ti] += 1
            next_k[ti] = ki + 1
            now = self.now
            req = _KR(task_key=keys[ti], kernel_id=kkid[ti][ki],
                      priority=prios[ti], task_instance=ti, seq_index=ki,
                      submit_time=now, payload=kdur[ti][ki],
                      deadline=dls[ti])
            # async clients schedule the next host-side issue now
            if maxin[ti] > 1 and ki + 1 < nk[ti]:
                g = kgap[ti][ki]
                push(heap, (now + (noisy(g) if jit else g),
                            tick(), 1, ti, ki + 1))
            p_submit(req)

        def try_issue(ti, ki):
            if ti in cancelled or ki >= nk[ti]:
                return
            if issued[ti] - done_k[ti] >= maxin[ti]:
                pending[ti] = ki          # wait for a flight slot
                return
            issue(ti, ki)

        for i in range(len(tasks)):
            push(heap, (arrs[i], tick(), 0, i))
        events = 0
        while heap:
            ev = pop(heap)
            self.now = ev[0]
            code = ev[2]
            ti = ev[3]
            events += 1
            if code == 2:                              # kernel_end
                ki = ev[4]
                done_k[ti] = ki + 1
                if ev[5]:                              # filler completion
                    p_fill_complete(ev[6])
                kid = kkid[ti][ki]
                if ti in cancelled:
                    # a cancelled task's in-flight kernel ran to
                    # completion (non-preemptible); observe, issue nothing
                    p_kernel_end(ti, kid, last=True,
                                 actual_gap=kgap[ti][ki],
                                 start=ev[7], end=ev[8])
                    continue
                last = ki == nk[ti] - 1
                if last:
                    results[ti].completion = self.now
                    for nxt in p_task_end(ti):         # EXCLUSIVE admission
                        try_issue(nxt, 0)
                elif maxin[ti] == 1:
                    # synchronous client: consume result, issue next
                    g = kgap[ti][ki]
                    push(heap, (self.now + (noisy(g) if jit else g),
                                tick(), 1, ti, ki + 1))
                else:
                    pi = pending[ti]
                    if pi is not None:
                        pending[ti] = None
                        issue(ti, pi)                  # flight slot freed
                p_kernel_end(ti, kid, last=last,
                             actual_gap=kgap[ti][ki],
                             start=ev[7], end=ev[8])
            elif code == 1:                            # host issue
                try_issue(ti, ev[4])
            else:                                      # arrival
                if ti in cancelled:
                    continue
                begun[ti] = True
                if p_task_begin(ti, keys[ti], prios[ti],
                                arrival=arrs[ti]):
                    try_issue(ti, 0)
        self.events = events

    # --------------------------------------------------------------- clients
    def _on_arrival(self, ti: int) -> None:
        if ti in self.cancelled:       # cancelled before it ever arrived
            return
        task = self.tasks[ti]
        self._begun[ti] = True
        if self.jobstore is not None:
            # upsert: a recovery re-submission keeps the original row
            # (full spec, kernel count, completions); only state advances
            self.job_ids[ti] = self.jobstore.record_submit(
                self.job_ids[ti], task.key, task.priority,
                n_kernels=self.seq_base[ti] + len(task.kernels),
                spec=spec_to_obj(task), deadline=task.deadline,
                at=self.now)
        if self.placement.task_begin(ti, task.key, task.priority,
                                     arrival=self.results[ti].arrival):
            self._on_issue(ti, 0)

    def _on_issue(self, ti: int, ki: int) -> None:
        """Host of task ti is ready to issue kernel ki."""
        if ti in self.cancelled:
            return
        task = self.tasks[ti]
        if ki >= len(task.kernels):
            return
        if self._issued[ti] - self._done_k[ti] >= task.max_inflight:
            self._pending_issue[ti] = ki          # wait for a flight slot
            return
        self._issue(ti, ki)

    def _issue(self, ti: int, ki: int) -> None:
        task = self.tasks[ti]
        self._issued[ti] += 1
        self._next_k[ti] = ki + 1
        req = KernelRequest(task_key=task.key,
                            kernel_id=task.kernels[ki].kid,
                            priority=task.priority, task_instance=ti,
                            seq_index=ki, submit_time=self.now,
                            payload=task.kernels[ki].duration,
                            deadline=task.deadline)
        # async clients schedule the next host-side issue now
        if task.max_inflight > 1 and ki + 1 < len(task.kernels):
            self._push(self.now + self._noisy(task.kernels[ki].gap_after),
                       "issue", (ti, ki + 1))
        self.placement.submit(req)

    # ---------------------------------------------------------------- device
    def _device_launch(self, device: int, req: KernelRequest,
                       filler: bool) -> None:
        """Placement launch hook: put the request on ``device``'s serial
        timeline."""
        dur = self._noisy(float(req.payload)) * (1.0 + self.meas_ovh)
        if filler and self._ienv is not None:
            # physical contention: a filler co-running against the gap
            # holder is slowed by the GROUND-TRUTH class-pair factor,
            # regardless of what the scheduler's model predicted
            gk = self.placement.policies[device].gap_kinfo
            if gk is not None:
                h = self._true_class.get(gk, COMPUTE_BOUND)
                f = self._true_class.get(
                    (req.task_instance, req.kernel_id), COMPUTE_BOUND)
                dur *= self._ienv.get((h, f), 1.0)
        start = max(self.now, self.device_free[device])
        end = start + dur
        self.device_free[device] = end
        self._busy[device] += dur
        ti = req.task_instance
        if self.results[ti].start < 0:
            self.results[ti].start = start
        if self.record_timeline:
            self.timeline.append(KernelExec(ti, req.seq_index, start, end,
                                            filler=filler, device=device))
        self._emit_kernel_end(ti, req.seq_index, filler, device, start, end)

    def _emit_kernel_end(self, ti: int, ki: int, filler: bool, device: int,
                         start: float, end: float) -> None:
        """Schedule the completion event for a launched kernel. The fast
        core shadows this with its flat-tuple emitter at loop start; the
        ordering key (time, seq) is identical either way."""
        self._push(end, "kernel_end", (ti, ki, filler, device, start, end))

    def _on_kernel_end(self, ti: int, ki: int, filler: bool, device: int,
                       start: float, end: float) -> None:
        task = self.tasks[ti]
        if self.jobstore is not None:
            # WRITE-AHEAD: the completion record is this boundary's
            # commit point — durable before ANY scheduling side-effect,
            # so a crash anywhere below loses nothing and recovery
            # re-submits exactly the un-recorded suffix
            self.jobstore.record_completion(self.job_ids[ti],
                                            self.seq_base[ti] + ki,
                                            at=self.now)
        self._done_k[ti] = ki + 1
        if filler:
            self.placement.fill_complete(device)
        if ti in self.cancelled:
            # a cancelled task's in-flight kernel ran to completion
            # (kernels are non-preemptible); observe it, issue nothing
            self.placement.kernel_end(ti, task.kernels[ki].kid, last=True,
                                      actual_gap=task.kernels[ki].gap_after,
                                      start=start, end=end)
            self._fault_boundary()
            return
        last = ki == len(task.kernels) - 1
        if last:
            self.results[ti].completion = self.now
            for nxt in self.placement.task_end(ti):  # EXCLUSIVE admission
                self._on_issue(nxt, 0)
        elif task.max_inflight == 1:
            # synchronous client: host consumes result, then issues next
            self._push(self.now + self._noisy(task.kernels[ki].gap_after),
                       "issue", (ti, ki + 1))
        elif self._pending_issue[ti] is not None:
            nxt = self._pending_issue[ti]
            self._pending_issue[ti] = None
            self._issue(ti, nxt)                   # flight slot freed
        self.placement.kernel_end(ti, task.kernels[ki].kid, last=last,
                                  actual_gap=task.kernels[ki].gap_after,
                                  start=start, end=end)
        if self.jobstore is not None:
            if last:
                self.jobstore.record_state(self.job_ids[ti], _js.DONE,
                                           at=self.now)
            if (self.online is not None
                    and self.online.commits != self._snap_commits):
                # an online epoch committed refined SK/SG this boundary:
                # checkpoint so recovery resumes with what was learned
                self._snap_commits = self.online.commits
                self.jobstore.snapshot_profiles(self.profiled, at=self.now)
        self._fault_boundary()

    # -------------------------------------------------------- ops plane
    def _fault_boundary(self) -> None:
        """Consult the fault plan at a kernel boundary — the only place
        faults are injected (kernels are non-preemptible). Scripted
        verbs apply BEFORE a scripted crash at the same boundary, so a
        cancel-then-crash persists the cancel."""
        if self.fault_plan is None:
            return
        crash, verbs = self.fault_plan.at_boundary()
        for v in verbs:
            verb, args = v[0], v[1:]
            if verb == "cancel":
                self.cancel(*args)
            elif verb == "pause":
                self.pause(*args)
            elif verb == "resume":
                self.resume(*args)
            else:
                raise ValueError(f"unknown fault-plan verb {v!r}")
        if crash:
            self.fault_plan.crash()

    def cancel(self, ti: int) -> List[KernelRequest]:
        """Cancel task ``ti``: purge its queued requests (in-flight
        kernels finish — non-preemptible), retire it, record the
        terminal state. Returns the purged requests."""
        if ti in self.cancelled:
            return []
        if self._begun[ti] and self._done_k[ti] >= len(self.tasks[ti].kernels):
            return []                  # raced completion: already DONE
        self.cancelled.add(ti)
        self.paused_tasks.discard(ti)
        self._pending_issue[ti] = None
        purged: List[KernelRequest] = []
        if self._begun[ti]:
            purged, admitted = self.placement.cancel(ti)
            for nxt in admitted:       # EXCLUSIVE: next waiter admitted
                self._on_issue(nxt, 0)
        if self.jobstore is not None and self.job_ids[ti] is not None:
            self.jobstore.record_state(self.job_ids[ti], _js.CANCELLED,
                                       at=self.now)
        return purged

    def pause(self, ti: int) -> bool:
        """Pause task ``ti`` (defers to its next kernel boundary when
        kernels are in flight — returns False then, True when the pause
        took effect immediately). The client keeps issuing; its requests
        buffer with the detached backlog until ``resume``."""
        if ti in self.paused_tasks:
            return True
        if ti in self.cancelled or not self._begun[ti]:
            raise ValueError(f"cannot pause task {ti} "
                             f"(cancelled or not yet arrived)")
        landed = self.placement.pause(ti)
        self.paused_tasks.add(ti)
        if self.jobstore is not None and self.job_ids[ti] is not None:
            self.jobstore.record_state(self.job_ids[ti], _js.PAUSED,
                                       at=self.now)
        return landed

    def resume(self, ti: int, device: Optional[int] = None) -> int:
        """Re-admit a paused task (on ``device``, or wherever the
        placement discipline elects). Returns the hosting device."""
        if ti not in self.paused_tasks:
            raise ValueError(f"task {ti} is not paused")
        d = self.placement.resume(ti, device)
        self.paused_tasks.discard(ti)
        if self.jobstore is not None and self.job_ids[ti] is not None:
            self.jobstore.record_state(self.job_ids[ti], _js.RUNNING,
                                       at=self.now)
        return d

    @classmethod
    def recover(cls, jobstore, mode: Mode, *, include_paused: bool = False,
                cold_start: bool = False, **kwargs) -> "SimScheduler":
        """Rebuild a simulator from a store's incomplete jobs: each
        job's REMAINING kernel suffix re-submits in stream order under
        its original job id and completion watermark (so recovered
        completions land at their original stream indices), and the
        latest profile snapshot — online-learned SK/SG included —
        reloads unless ``profiled=`` overrides it. Paused jobs stay
        paused in the store across a restart unless ``include_paused``.
        """
        store = coerce_store(jobstore)
        specs, ids, bases = store.recovery_plan(
            include_paused=include_paused)
        profiled = kwargs.pop("profiled", None)
        if profiled is None:
            profiled = store.load_profiles(cold_start=cold_start)
        return cls(specs, mode, profiled=profiled, jobstore=store,
                   job_ids=ids, seq_base=bases, **kwargs)


# ---------------------------------------------------------------------------
# Measurement phase (paper Fig 3/6): run a task solo T times, record device
# timeline, emit SK/SG statistics. Durations are what the device measured;
# the JCT overhead of measuring (20-80%) applies to the run's wall time.
# ---------------------------------------------------------------------------
def measure_task(spec: TaskSpec, T: int = 10, jitter: float = 0.0,
                 measurement_overhead: float = 0.5, seed: int = 0,
                 ) -> Tuple["Profiler", List[float]]:
    """Returns (profiler with T solo runs recorded, per-run measured JCTs)."""
    prof = Profiler(spec.key)
    jcts = []
    for t in range(T):
        solo = TaskSpec(spec.key, spec.priority, spec.kernels, arrival=0.0,
                        max_inflight=spec.max_inflight)
        sim = SimScheduler([solo], Mode.EXCLUSIVE, jitter=jitter,
                           seed=seed * 10_007 + t,
                           measurement_overhead=measurement_overhead)
        rep = sim.run()
        jcts.append(rep.jct(0))
        prof.start_run()
        tl = sorted(rep.timeline, key=lambda k: k.start)
        for i, k in enumerate(tl):
            kid = spec.kernels[k.seq].kid
            # the device measured the kernel under measurement overhead;
            # report the de-rated (true) duration like cudaEvent timing
            prof.record(kid, (k.end - k.start) / (1.0 + measurement_overhead),
                        kclass=spec.kernels[k.seq].kclass)
            if i < len(tl) - 1:
                prof.record_gap(max(0.0, tl[i + 1].start - k.end))
        prof.end_run()
    return prof, jcts


def profile_tasks(specs: List[TaskSpec], T: int = 10, jitter: float = 0.0,
                  measurement_overhead: float = 0.5, seed: int = 0,
                  ) -> ProfiledData:
    data = ProfiledData()
    for i, spec in enumerate(specs):
        prof, _ = measure_task(spec, T=T, jitter=jitter,
                               measurement_overhead=measurement_overhead,
                               seed=seed + i)
        data.load(prof.statistics())
    return data
