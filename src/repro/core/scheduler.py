"""FIKIT scheduler over a serial device — discrete-event simulator.

Models the paper's system (Figs 7, 8, 11, 12):

- Each *client* (one per task) issues kernel launches on its own host
  timeline. A synchronous client (``max_inflight=1``) issues kernel i+1
  only after observing kernel i's completion plus a host gap — this creates
  the inter-kernel device idle ("gap") FIKIT scavenges. An async client
  (``max_inflight=m>1``) issues launch i+1 a host-gap after launch i with
  up to m kernels in flight — the CUDA-stream behavior that lets a
  device-bound low-priority task flood the FIFO device queue and inflate a
  high-priority co-tenant's JCT in default sharing mode (Fig 2 "Sharing 1").
- The *device* executes launched kernels serially in launch (FIFO) order.
  Kernels are non-preemptible.
- Modes:
    EXCLUSIVE — tasks serialized in arrival order (paper "A,B Exclusive").
    SHARING   — every issue launches immediately; kernels from different
                tasks interleave FIFO (paper "default GPU sharing").
    FIKIT     — priority queues + gap filling + feedback: the highest-
                priority active task ("holder") launches directly; lower-
                priority issues are queued (Q0-Q9); on each holder kernel
                completion the predicted gap SG[kid] is filled via
                BestPrioFit; the holder's next actual issue closes the gap
                early (real-time feedback, Fig 12). At most
                ``pipeline_depth`` fillers sit in the device queue at once —
                fillers already queued when the gap closes early are the
                paper's "overhead 2".

Determinism: the event heap is ordered by (time, seq); ties resolve by
insertion order, so simulations are exactly reproducible.
"""
from __future__ import annotations

import enum
import heapq
import itertools
import random as _random
from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.fikit import EPSILON, best_prio_fit
from repro.core.profiler import ProfiledData, Profiler
from repro.core.queues import PriorityQueues
from repro.core.task import KernelRequest, TaskSpec


class Mode(enum.Enum):
    EXCLUSIVE = "exclusive"
    SHARING = "sharing"
    FIKIT = "fikit"


@dataclass
class KernelExec:
    """One executed kernel interval on the device timeline."""
    task: int
    seq: int
    start: float
    end: float
    filler: bool = False


@dataclass
class TaskResult:
    arrival: float
    start: float = -1.0
    completion: float = -1.0

    @property
    def jct(self) -> float:
        return self.completion - self.arrival


@dataclass
class SimReport:
    results: List[TaskResult]
    timeline: List[KernelExec]
    fills: int = 0
    overshoot_time: float = 0.0   # filler time past actual gap end ("ovh 2")

    def jct(self, i: int) -> float:
        return self.results[i].jct

    @property
    def makespan(self) -> float:
        return max((r.completion for r in self.results), default=0.0)

    def device_busy(self) -> float:
        return sum(k.end - k.start for k in self.timeline)

    def utilization(self) -> float:
        ms = self.makespan
        return self.device_busy() / ms if ms > 0 else 0.0


class SimScheduler:
    def __init__(self, tasks: List[TaskSpec], mode: Mode,
                 profiled: Optional[ProfiledData] = None,
                 pipeline_depth: int = 2, feedback: bool = True,
                 epsilon: float = EPSILON,
                 measurement_overhead: float = 0.0,
                 jitter: float = 0.0, seed: int = 0):
        """measurement_overhead: multiplier on kernel durations (the paper's
        20-80% measuring-stage slowdown), used to simulate the measurement
        phase. jitter: multiplicative gaussian noise on true durations/gaps
        (run-to-run variance the SK/SG averages + feedback must absorb)."""
        self.tasks = tasks
        self.mode = mode
        self.profiled = profiled or ProfiledData()
        self.pipeline_depth = max(1, pipeline_depth)
        self.feedback = feedback
        self.epsilon = epsilon
        self.meas_ovh = measurement_overhead
        self.jitter = jitter
        self._rng = _random.Random(seed)

        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.device_free = 0.0
        self.timeline: List[KernelExec] = []
        self.queues = PriorityQueues()
        self.results = [TaskResult(arrival=t.arrival) for t in tasks]
        n = len(tasks)
        self._next_k = [0] * n          # next kernel index to issue
        self._done_k = [0] * n          # kernels completed
        self._issued = [0] * n
        self._pending_issue: List[Optional[int]] = [None] * n
        self._active: set = set()
        self._excl_queue: List[int] = []
        self._excl_running: Optional[int] = None
        # FIKIT gap state
        self._gap_open = False
        self._gap_remaining = 0.0
        self._gap_end_actual: Optional[float] = None
        self._fills_in_flight = 0
        self._fill_count = 0
        self._overshoot = 0.0

    # ----------------------------------------------------------------- noise
    def _noisy(self, x: float) -> float:
        if self.jitter <= 0:
            return x
        return x * max(0.05, 1.0 + self._rng.gauss(0.0, self.jitter))

    # ------------------------------------------------------------- event API
    def _push(self, time: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self._heap, (time, next(self._seq), kind, payload))

    def run(self) -> SimReport:
        for i, t in enumerate(self.tasks):
            self._push(t.arrival, "arrival", (i,))
        while self._heap:
            self.now, _, kind, payload = heapq.heappop(self._heap)
            getattr(self, "_on_" + kind)(*payload)
        return SimReport(self.results, self.timeline, fills=self._fill_count,
                         overshoot_time=self._overshoot)

    # --------------------------------------------------------------- clients
    def _on_arrival(self, ti: int) -> None:
        self._active.add(ti)
        if self.mode is Mode.EXCLUSIVE:
            if self._excl_running is None:
                self._excl_running = ti
                self._on_issue(ti, 0)
            else:
                self._excl_queue.append(ti)
        else:
            self._on_issue(ti, 0)

    def _on_issue(self, ti: int, ki: int) -> None:
        """Host of task ti is ready to issue kernel ki."""
        task = self.tasks[ti]
        if ki >= len(task.kernels):
            return
        if self._issued[ti] - self._done_k[ti] >= task.max_inflight:
            self._pending_issue[ti] = ki          # wait for a flight slot
            return
        self._issue(ti, ki)

    def _issue(self, ti: int, ki: int) -> None:
        task = self.tasks[ti]
        self._issued[ti] += 1
        self._next_k[ti] = ki + 1
        req = KernelRequest(task_key=task.key,
                            kernel_id=task.kernels[ki].kid,
                            priority=task.priority, task_instance=ti,
                            seq_index=ki, submit_time=self.now,
                            payload=task.kernels[ki].duration)
        # async clients schedule the next host-side issue now
        if task.max_inflight > 1 and ki + 1 < len(task.kernels):
            self._push(self.now + self._noisy(task.kernels[ki].gap_after),
                       "issue", (ti, ki + 1))
        self._route(req)

    def _route(self, req: KernelRequest) -> None:
        ti = req.task_instance
        if self.mode is not Mode.FIKIT:
            self._launch(req)
            return
        holder = self._holder()
        task = self.tasks[ti]
        if holder == ti:
            if self._gap_open:                     # real-time feedback
                self._gap_open = False
                self._gap_remaining = 0.0
            self._launch(req)
        elif holder is not None and task.priority == self.tasks[holder].priority:
            self._launch(req)                      # equal prio: FIFO (case C)
        else:
            self.queues.push(req)
            self._try_fill()                       # Fig 7: scan on enqueue

    # ---------------------------------------------------------------- device
    def _launch(self, req: KernelRequest, filler: bool = False) -> None:
        dur = self._noisy(float(req.payload)) * (1.0 + self.meas_ovh)
        start = max(self.now, self.device_free)
        end = start + dur
        self.device_free = end
        ti = req.task_instance
        if self.results[ti].start < 0:
            self.results[ti].start = start
        self.timeline.append(KernelExec(ti, req.seq_index, start, end,
                                        filler=filler))
        self._push(end, "kernel_end", (ti, req.seq_index, filler))

    def _on_kernel_end(self, ti: int, ki: int, filler: bool) -> None:
        task = self.tasks[ti]
        self._done_k[ti] = ki + 1
        if filler:
            self._fills_in_flight -= 1
            if (self._gap_end_actual is not None
                    and self.now > self._gap_end_actual):
                self._overshoot += self.now - self._gap_end_actual
        last = ki == len(task.kernels) - 1
        if last:
            self.results[ti].completion = self.now
            self._active.discard(ti)
            self._on_task_done(ti)
        elif task.max_inflight == 1:
            # synchronous client: host consumes result, then issues next
            self._push(self.now + self._noisy(task.kernels[ki].gap_after),
                       "issue", (ti, ki + 1))
        elif self._pending_issue[ti] is not None:
            nxt = self._pending_issue[ti]
            self._pending_issue[ti] = None
            self._issue(ti, nxt)                   # flight slot freed
        if self.mode is Mode.FIKIT:
            holder = self._holder()
            if holder == ti and not last:
                predicted = self.profiled.predict_gap(task.key,
                                                      task.kernels[ki].kid)
                if predicted > self.epsilon:       # skip small gaps
                    self._gap_open = True
                    self._gap_remaining = predicted
                    self._gap_end_actual = (
                        self.now + task.kernels[ki].gap_after
                        if self.feedback else None)
            self._try_fill()

    def _on_task_done(self, ti: int) -> None:
        if self.mode is Mode.EXCLUSIVE:
            self._excl_running = None
            if self._excl_queue:
                nxt = self._excl_queue.pop(0)
                self._excl_running = nxt
                self._on_issue(nxt, 0)
        elif self.mode is Mode.FIKIT:
            self._gap_open = False
            self._gap_remaining = 0.0
            self._release_new_holder()

    # ------------------------------------------------------------ FIKIT bits
    def _holder(self) -> Optional[int]:
        """Highest-priority active task (ties: earliest arrival, then id)."""
        best = None
        for ti in self._active:
            if best is None:
                best = ti
                continue
            a, b = self.tasks[ti], self.tasks[best]
            if (a.priority, self.results[ti].arrival, ti) < \
                    (b.priority, self.results[best].arrival, best):
                best = ti
        return best

    def _release_new_holder(self) -> None:
        holder = self._holder()
        if holder is None:
            req = self.queues.pop_highest()        # drain leftovers FIFO
            while req is not None:
                self._launch(req)
                req = self.queues.pop_highest()
            return
        with self.queues.lock():
            for req in list(self.queues):
                if req.task_instance == holder or (
                        self.tasks[req.task_instance].priority
                        == self.tasks[holder].priority):
                    self.queues.remove(req)
                    self._launch(req)

    def _try_fill(self) -> None:
        """Fill an open gap (Algorithm 1, incremental with feedback and a
        bounded device-queue lookahead)."""
        if self.mode is not Mode.FIKIT or not self._gap_open:
            return
        while (self._fills_in_flight < self.pipeline_depth
               and self._gap_remaining > 0.0):
            req, fill_time = best_prio_fit(self.queues, self._gap_remaining,
                                           self.profiled)
            if fill_time == -1:
                break
            self._fills_in_flight += 1
            self._fill_count += 1
            self._gap_remaining -= fill_time
            self._launch(req, filler=True)


# ---------------------------------------------------------------------------
# Measurement phase (paper Fig 3/6): run a task solo T times, record device
# timeline, emit SK/SG statistics. Durations are what the device measured;
# the JCT overhead of measuring (20-80%) applies to the run's wall time.
# ---------------------------------------------------------------------------
def measure_task(spec: TaskSpec, T: int = 10, jitter: float = 0.0,
                 measurement_overhead: float = 0.5, seed: int = 0,
                 ) -> Tuple["Profiler", List[float]]:
    """Returns (profiler with T solo runs recorded, per-run measured JCTs)."""
    prof = Profiler(spec.key)
    jcts = []
    for t in range(T):
        solo = TaskSpec(spec.key, spec.priority, spec.kernels, arrival=0.0,
                        max_inflight=spec.max_inflight)
        sim = SimScheduler([solo], Mode.EXCLUSIVE, jitter=jitter,
                           seed=seed * 10_007 + t,
                           measurement_overhead=measurement_overhead)
        rep = sim.run()
        jcts.append(rep.jct(0))
        prof.start_run()
        tl = sorted(rep.timeline, key=lambda k: k.start)
        for i, k in enumerate(tl):
            kid = spec.kernels[k.seq].kid
            # the device measured the kernel under measurement overhead;
            # report the de-rated (true) duration like cudaEvent timing
            prof.record(kid, (k.end - k.start) / (1.0 + measurement_overhead))
            if i < len(tl) - 1:
                prof.record_gap(max(0.0, tl[i + 1].start - k.end))
        prof.end_run()
    return prof, jcts


def profile_tasks(specs: List[TaskSpec], T: int = 10, jitter: float = 0.0,
                  measurement_overhead: float = 0.5, seed: int = 0,
                  ) -> ProfiledData:
    data = ProfiledData()
    for i, spec in enumerate(specs):
        prof, _ = measure_task(spec, T=T, jitter=jitter,
                               measurement_overhead=measurement_overhead,
                               seed=seed + i)
        data.load(prof.statistics())
    return data
