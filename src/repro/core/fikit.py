"""Algorithm 1 (FIKIT Procedure) and Algorithm 2 (BestPrioFit) — the
paper's pseudocode (Figs 9 and 10), with BestPrioFit served from the
indexed priority queues in O(log n) per decision.

Semantics preserved exactly (and enforced by the differential tests in
``tests/test_policy_differential.py`` against ``best_prio_fit_scan``):
- BestPrioFit scans priorities 0..9; at the FIRST priority level containing
  any fitting kernel it selects the kernel with the LONGEST predicted
  duration that still fits the remaining idle time
  (``bestKernelTime < predictedKernelTime < idleTime``), dequeues it and
  returns it. Lower priority levels are not examined once a fit is found.
  Ties on predicted duration resolve to the earliest-parked request, as in
  the scan's first-seen-wins FIFO walk.
- FIKIT looks up the predicted gap from profiled SG when idleTime == -1,
  skips gaps <= EPSILON (paper: 0.1 ms — a kernel launch costs 0.1-2 ms),
  then repeatedly calls BestPrioFit, launching every selected kernel and
  decrementing the remaining idle time, until nothing fits.

One deviation from the paper's pseudocode (both implementations): within a
single task instance (one CUDA stream) only the OLDEST queued kernel is
eligible. A stream's kernels execute in issue order, so selecting kernel
i+1 as a filler while kernel i is still parked would reorder the stream —
and let a task retire with orphaned requests stuck in the queues.

Queue disciplines: when a ``PriorityQueues`` level is configured ``sjf``
or ``edf`` (see ``repro.core.queues``), the fill selection at that level
changes — SJF picks the SHORTEST profiled fitting head, EDF keeps the
longest-fit criterion but breaks predicted-duration ties to the earliest
deadline. Both ``best_prio_fit`` (indexed) and ``best_prio_fit_scan``
(O(n) oracle) implement every discipline; the default all-``fifo``
configuration is the paper's Algorithm 2, bit-identical to the
pre-discipline implementation.
"""
from __future__ import annotations

import math

from typing import Callable, List, Optional, Tuple

from repro.core.profiler import ProfiledData
from repro.core.queues import PriorityQueues
from repro.core.task import KernelRequest, TaskKey
from repro.core.kernel_id import KernelID

EPSILON = 1.0e-4  # 0.1 ms, paper §3.2 line 6-8 commentary


def best_prio_fit(queues: PriorityQueues, idle_time: float,
                  profiled: ProfiledData, *,
                  holder_class: Optional[str] = None,
                  interference=None,
                  ) -> Tuple[Optional[KernelRequest], float]:
    """Algorithm 2: Sharing Stage Idling Gap Filling Policy.

    Indexed fast path: first non-empty level -> a handful of bisects in
    that level's head index (predecessor search for FIFO/EDF levels,
    successor search for SJF levels). O(levels * log n) per decision
    instead of O(total queued); dequeue of the selected request is
    O(log n) index maintenance.

    ``holder_class`` (with an enabled interference model bound to the
    queues at construction) switches the selection to the
    interference-aware per-class search: a candidate of class ``c`` fits
    only while ``predicted < idle_time / coeff(holder_class, c)``. The
    returned duration stays the RAW prediction; the caller debits the gap
    by the coefficient-scaled effective duration. ``interference`` is
    accepted for signature parity with the scan oracle (the indexed side
    uses the model bound to ``queues``; callers pass the same object to
    both). With ``holder_class=None`` (the default, and always when
    interference is off) the selection is bit-identical to the
    pre-interference implementation.

    Oracle contract: ``best_prio_fit_scan`` is the O(n) reference with
    IDENTICAL selection semantics for every queue discipline and either
    interference setting — same request, same returned duration, for any
    queue state. The randomized differential suite in
    ``tests/test_policy_differential.py`` pins the two trace-identical;
    extend that suite whenever either side changes.
    """
    with queues.lock():
        queues.ensure_index(profiled)
        req, dur = queues.best_fit_under(idle_time,
                                         holder_class=holder_class)
        if req is not None:
            queues.remove(req)
    return req, dur


def best_prio_fit_scan(queues: PriorityQueues, idle_time: float,
                       profiled: ProfiledData, *,
                       holder_class: Optional[str] = None,
                       interference=None,
                       ) -> Tuple[Optional[KernelRequest], float]:
    """Reference oracle: the O(total queued) linear scan.

    The FIFO branch is the original implementation kept verbatim
    (first-seen-wins FIFO walk, ``best > 0`` level-stop rule); the SJF and
    EDF branches define those disciplines' selection semantics the same
    way — by a plain scan over the level's FIFO snapshot, no index. The
    differential tests assert the indexed fast path makes bit-identical
    decisions against this function; never used on the hot path.

    Interference-aware selection (``holder_class`` + an enabled
    ``interference`` model) only tightens each head's fit bound from
    ``idle_time`` to ``idle_time / coeff(holder_class, head_class)``; the
    selection and tie rules are untouched, and with it off ``limit`` is
    exactly ``idle_time``, keeping the scan character-for-character the
    original comparisons."""
    iron = (interference is not None and interference.enabled
            and holder_class is not None)
    best_kernel_time = -1.0
    best_kernel_req: Optional[KernelRequest] = None
    with queues.lock():
        seen_streams = set()
        for priority in range(queues.levels):          # highest -> lowest
            discipline = queues.discipline_of(priority)
            if discipline == "fifo":
                for kernel_req in queues[priority]:    # FIFO within a level
                    stream = (kernel_req.task_key, kernel_req.task_instance)
                    if stream in seen_streams:
                        continue                       # not head-of-stream
                    seen_streams.add(stream)
                    task_key = kernel_req.task_key
                    kernel_id = kernel_req.kernel_id
                    predicted = profiled.predict_duration(task_key,
                                                          kernel_id)
                    limit = idle_time
                    if iron:
                        limit = idle_time / interference.coeff(
                            holder_class,
                            profiled.predict_class(task_key, kernel_id))
                    if best_kernel_time < predicted < limit:
                        best_kernel_time = predicted
                        best_kernel_req = kernel_req
                if best_kernel_time > 0:
                    break      # longest fit found at this priority level
                continue
            # SJF/EDF: the first level holding any profiled fitting head
            # claims the decision; its candidate replaces a carried best
            # only if strictly longer (the same strictly-better rule the
            # FIFO branch applies across levels).
            cand_req = None
            cand_time = -1.0
            cand_dl = math.inf
            for kernel_req in queues[priority]:        # FIFO walk: seq asc
                stream = (kernel_req.task_key, kernel_req.task_instance)
                if stream in seen_streams:
                    continue                           # not head-of-stream
                seen_streams.add(stream)
                predicted = profiled.predict_duration(kernel_req.task_key,
                                                      kernel_req.kernel_id)
                limit = idle_time
                if iron:
                    limit = idle_time / interference.coeff(
                        holder_class,
                        profiled.predict_class(kernel_req.task_key,
                                               kernel_req.kernel_id))
                if not (-1.0 < predicted < limit):
                    continue                           # unprofiled / no fit
                if discipline == "sjf":
                    # shortest fitting; first-seen-wins keeps FIFO ties
                    if cand_req is None or predicted < cand_time:
                        cand_req, cand_time = kernel_req, predicted
                else:  # edf: longest fitting, deadline tie-break
                    dl = (kernel_req.deadline
                          if kernel_req.deadline is not None else math.inf)
                    if cand_req is None or predicted > cand_time or \
                            (predicted == cand_time and dl < cand_dl):
                        cand_req, cand_time, cand_dl = \
                            kernel_req, predicted, dl
            if cand_req is not None:
                if cand_time > best_kernel_time:
                    best_kernel_req = cand_req
                    best_kernel_time = cand_time
                break                       # this level claims the decision
        if best_kernel_req is not None:
            queues.remove(best_kernel_req)
    return best_kernel_req, best_kernel_time


def fikit_procedure(queues: PriorityQueues, task_key: TaskKey,
                    kernel_id: KernelID, idle_time: float,
                    profiled: ProfiledData,
                    launch: Callable[[KernelRequest], None],
                    epsilon: float = EPSILON,
                    remaining_gap: Optional[Callable[[], float]] = None,
                    ) -> List[KernelRequest]:
    """Algorithm 1: FIKIT Procedure.

    ``launch`` sends the selected kernel request to the GPU device queue.
    ``remaining_gap`` is the real-time feedback hook (Fig 12): when given,
    it returns the currently-known remaining idle time (0 once the next
    high-priority kernel has actually arrived); the fill loop re-reads it
    before each selection so prediction error does not propagate.

    Returns the list of launched filler requests.
    """
    launched: List[KernelRequest] = []
    if idle_time == -1:
        idle_time = profiled.predict_gap(task_key, kernel_id)
    if idle_time <= epsilon:                      # skip small gaps
        return launched
    while idle_time > 0.0:
        if remaining_gap is not None:
            idle_time = min(idle_time, remaining_gap())
            if idle_time <= 0.0:
                break                             # early stop (feedback)
        fill_req, fill_time = best_prio_fit(queues, idle_time, profiled)
        if fill_time == -1:
            break
        launch(fill_req)
        launched.append(fill_req)
        idle_time -= fill_time
    return launched
