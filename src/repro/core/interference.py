"""Kernel resource classes + the interference coefficient model.

FIKIT's BestPrioFit assumes a filler occupies the holder's idle gap for
free, but concurrent kernels slow each other down in ways that depend on
what resource each is bound by (cf. Tally's slowdown characterization and
the Gilman/Walls concurrency survey): a memory-bound filler inside a
memory-bound holder's gap contends for bandwidth and costs the holder
real time, eroding exactly the high-priority speedup the paper claims.

This module provides the two ingredients the scheduler needs:

- **Resource classes.** Every kernel is classified ``compute``-bound or
  ``memory``-bound by its roofline arithmetic intensity (FLOPs per byte
  accessed) against a per-architecture ridge point (peak FLOP/s divided
  by HBM bandwidth — the intensity where the roofline's two ceilings
  meet). ``classify_intensity`` is the single classification rule; the
  HLO cost layer (``repro.launch.hlo_cost``) and the roofline benchmark
  both delegate to it, and simulator traces carry a ground-truth class
  on ``TraceKernel.kclass``. The class rides the kernel's profile
  (``TaskProfile.kclass`` -> ``ProfiledData.predict_class``) so the
  scheduler reads it with the same one-probe lookup it uses for SK.
  A kernel with no recorded class defaults to compute-bound — the
  conservative pre-classification behavior, pinned by test.

- **Interference coefficients.** ``InterferenceModel`` maps a
  ``(holder_class, filler_class)`` pair to the predicted slowdown factor
  the filler imposes while sharing the device with the holder's next
  kernel's working set (>= 1.0; 1.0 = free). The fill decision divides
  the idle gap by the pair's coefficient — a candidate fits only if its
  predicted duration times the coefficient still fits the gap — and the
  fill loop debits the gap by the same effective duration. Coefficients
  are refined live by ``repro.core.online.OnlineMeasurement`` from
  observed-vs-predicted duration drift of matched fillers, committed in
  the same epochs as SK/SG (EMA, floor-clamped at 1.0).

The standing contract: with the model OFF (``interference=None`` on the
engines, or ``enabled=False``) every decision is bit-identical to the
pre-interference implementation — pinned by the randomized differential
suites in ``tests/test_policy_differential.py``.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Dict, Optional, Tuple

#: Resource-class labels (kept as plain strings: they round-trip through
#: profile JSON and appear in bench payloads).
COMPUTE_BOUND = "compute"
MEMORY_BOUND = "memory"
RESOURCE_CLASSES: Tuple[str, ...] = (COMPUTE_BOUND, MEMORY_BOUND)

ClassPair = Tuple[str, str]

#: Seed coefficients for a model built without measurements, shaped by
#: the concurrency literature: same-resource pairs contend hardest
#: (memory/memory worst — bandwidth is the scarcest shared resource),
#: cross-resource pairs overlap well (a compute-bound filler barely
#: slows a memory-bound holder).
DEFAULT_COEFFS: Dict[ClassPair, float] = {
    (MEMORY_BOUND, MEMORY_BOUND): 1.55,
    (COMPUTE_BOUND, COMPUTE_BOUND): 1.15,
    (COMPUTE_BOUND, MEMORY_BOUND): 1.25,
    (MEMORY_BOUND, COMPUTE_BOUND): 1.05,
}


def classify_intensity(flops: float, bytes_accessed: float,
                       ridge: float) -> str:
    """Roofline classification: compute-bound iff the arithmetic
    intensity (FLOPs per byte accessed) reaches the ridge point.

    ``bytes_accessed <= 0`` (no traffic recorded) classifies
    compute-bound — the conservative default, matching the unclassified
    fallback everywhere else."""
    if bytes_accessed <= 0:
        return COMPUTE_BOUND
    return (COMPUTE_BOUND if flops / bytes_accessed >= ridge
            else MEMORY_BOUND)


class InterferenceModel:
    """Per-class-pair slowdown coefficients for gap-fill scoring.

    ``coeff(holder_class, filler_class)`` is the factor by which the
    filler's device occupancy is predicted to stretch while the holder's
    gap is open; unknown pairs predict 1.0 (no interference). ``update``
    folds one epoch's observed batch-mean slowdown into a pair via EMA,
    clamped at the 1.0 floor (co-location is never modeled as a
    speedup — a ratio below 1.0 is measurement noise).

    ``enabled=False`` constructs the model but keeps every scoring seam
    on its plain path — the wired-but-off configuration the differential
    suite pins bit-identical to no model at all.
    """

    def __init__(self, coeffs: Optional[Mapping] = None, *,
                 enabled: bool = True):
        if coeffs is None:
            self._coeffs: Dict[ClassPair, float] = dict(DEFAULT_COEFFS)
        else:
            self._coeffs = {(str(k[0]), str(k[1])): float(v)
                            for k, v in coeffs.items()}
        self.enabled = enabled
        self.updates = 0

    def coeff(self, holder_class: str, filler_class: str) -> float:
        return self._coeffs.get((holder_class, filler_class), 1.0)

    def update(self, pair: ClassPair, batch: float, alpha: float) -> None:
        """EMA-fold one epoch's batch-mean observed slowdown into
        ``pair``, floor-clamped at 1.0."""
        old = self._coeffs.get(pair, 1.0)
        self._coeffs[pair] = max(1.0, (1.0 - alpha) * old + alpha * batch)
        self.updates += 1

    def snapshot(self) -> Dict[ClassPair, float]:
        """Copy of the current coefficient table (for persistence and
        bench payloads)."""
        return dict(self._coeffs)

    @staticmethod
    def coerce(spec) -> Optional["InterferenceModel"]:
        """Normalize the engines' ``interference=`` argument:
        None/False -> None (no model), True -> ``DEFAULT_COEFFS``,
        a model -> itself, a mapping -> a model over those coeffs."""
        if spec is None or spec is False:
            return None
        if spec is True:
            return InterferenceModel()
        if isinstance(spec, InterferenceModel):
            return spec
        if isinstance(spec, Mapping):
            return InterferenceModel(spec)
        raise TypeError(f"interference= expects None/bool/Mapping/"
                        f"InterferenceModel, got {spec!r}")
