"""Engine-agnostic FIKIT policy core — ONE scheduling state machine.

The paper's scheduling contribution (priority queues, holder election,
SG-gap prediction, BestPrioFit filling with real-time feedback) used to be
implemented twice: once in the discrete-event ``SimScheduler`` and once in
the threaded ``WallClockEngine``. ``FikitPolicy`` extracts the shared state
machine so a scheduling decision can never drift between the two; both
engines are now thin drivers over this class.

Responsibilities owned by the policy (and ONLY by the policy):

- holder election — the highest-priority active task, ties broken by
  (arrival, instance id); the election result is CACHED and revalidated
  only on ``task_begin``/``task_end`` (the only events that can change
  it), so the per-submit/per-kernel-end ``holder()`` probe is O(1);
- request routing — holder-direct launch, equal-priority FIFO sharing
  (paper case C), or park in the priority queues Q0..Q9;
- gap open/close with real-time feedback — a holder kernel's completion
  opens the predicted SG[kid] gap (skipping gaps <= epsilon); the holder's
  next actual submit closes it early (Fig 12), bounding prediction-error
  propagation;
- the bounded ``pipeline_depth`` BestPrioFit fill loop — at most
  ``pipeline_depth`` fillers sit in the device queue at once;
- release-on-task-done — when the holder retires, queued requests of the
  new holder (and its equal-priority peers) are released; with no active
  task the queues drain FIFO;
- overshoot accounting — filler time past the actual gap end is the
  paper's "overhead 2";
- EXCLUSIVE admission — tasks serialized in begin order.

Engine interface (dependency-injected, so the policy never touches a
thread, an event heap, or a device):

- ``clock()``   -> float      current time (sim: virtual now; wall: perf_counter)
- ``launch(req, filler)``     put a request on the serial device queue

Modes
-----
EXCLUSIVE — tasks serialized in arrival order; admission gated in
            ``task_begin``/``task_end``.
SHARING   — every submit launches immediately (default GPU sharing).
FIKIT     — priority queues + SG-gap filling + feedback (the paper).
PREEMPT   — kernel-boundary preemptive sharing (the paper's preemptive
            baseline, Figs 19/20; cf. arXiv 2401.16529): while any
            strictly-higher-priority task is active, lower-priority
            submits are parked in the priority queues and released only
            when no higher-priority task remains active. No gap filling —
            the device is reserved for the high-priority tier, so
            low-priority work advances only between high-priority tasks.
            Kernels stay non-preemptible; preemption happens at kernel
            launch boundaries (a running kernel always finishes).

Decision trace
--------------
Every decision appends one tuple to ``self.trace``:

    ("begin",  instance)            task became active
    ("defer",  instance)            EXCLUSIVE admission parked the task
    ("admit",  instance)            EXCLUSIVE admission released the task
    ("end",    instance)            task retired
    ("holder", instance | None)     holder transition (after begin/end)
    ("launch", instance, seq)       direct launch (holder / sharing / FIFO)
    ("queue",  instance, seq)       parked in the priority queues
    ("fill",   instance, seq)       BestPrioFit gap fill launch
    ("release", instance, seq)      released on holder retirement
    ("drain",  instance, seq)       FIFO drain with no active task
    ("gap_open",  instance, predicted)
    ("gap_close", instance)
    ("detach", instance)            task migrated OUT (placement steal)
    ("attach", instance)            task migrated IN  (placement steal)
    ("cancel", instance)            task cancelled (ops-plane verb);
                                    always followed by the ("end", ...)
                                    retirement events

The ``detach``/``attach`` pair is the multi-device placement layer's
migration seam (``repro.core.placement.PlacementLayer``): a fully-parked
task leaves one device's policy and joins another's. Neither event can
occur on a single-device system, so a K=1 placement trace is identical to
a bare policy trace — the property the placement differential tests pin.

The trace is what the differential tests compare between engines: identical
scenario -> identical trace, by construction and by test.

The trace destination is a pluggable sink (``trace=`` ctor arg):

    "list" (default) — ``ListTrace``, an unbounded list; what tests diff.
    "ring"           — ``RingTrace``, a bounded ring buffer keeping the
                       most recent ``DEFAULT_RING`` entries (long-running
                       serving with bounded memory); an int selects a
                       custom capacity.
    "off"            — ``NullTrace``; tracing is skipped entirely (the
                       append AND the tuple construction), so production
                       mode pays nothing per decision.
    any object with ``.append``   — custom sink. ``enabled`` is read ONCE
                       at policy construction: a sink carrying
                       ``enabled = False`` before the policy is built
                       suppresses tuple construction; flipping it later
                       has no effect.
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.fikit import EPSILON, best_prio_fit, best_prio_fit_scan
from repro.core.kernel_id import KernelID
from repro.core.profiler import ProfiledData
from repro.core.queues import PriorityQueues, QueueDisciplineSpec
from repro.core.task import KernelRequest, TaskKey


class Mode(enum.Enum):
    EXCLUSIVE = "exclusive"
    SHARING = "sharing"
    FIKIT = "fikit"
    PREEMPT = "preempt"


#: Modes that route through the priority queues.
QUEUED_MODES = (Mode.FIKIT, Mode.PREEMPT)

#: Default capacity of a ``trace="ring"`` sink.
DEFAULT_RING = 4096


class ListTrace(list):
    """Unbounded in-memory decision trace (the default; what tests diff)."""
    enabled = True


class RingTrace(deque):
    """Bounded ring buffer: keeps the most recent ``maxlen`` decisions."""
    enabled = True


class NullTrace:
    """Disabled trace: every decision costs nothing (no tuple is built)."""
    enabled = False

    def append(self, item) -> None:  # pragma: no cover - never called hot
        pass

    def __len__(self) -> int:
        return 0

    def __iter__(self):
        return iter(())


TraceSpec = Union[str, int, ListTrace, RingTrace, NullTrace]


def make_trace_sink(spec: TraceSpec = "list"):
    if spec == "list" or spec is None:
        return ListTrace()
    if spec == "off":
        return NullTrace()
    if spec == "ring":
        return RingTrace(maxlen=DEFAULT_RING)
    if isinstance(spec, int):
        return RingTrace(maxlen=spec)
    if hasattr(spec, "append"):
        return spec
    raise ValueError(f"unknown trace sink spec: {spec!r}")


@dataclass
class ActiveTask:
    """Policy-side record of a running task instance."""
    instance: int
    key: TaskKey
    priority: int
    arrival: float


class FikitPolicy:
    """The FIKIT scheduling state machine, engine-agnostic.

    Drivers call, in event order:

    - ``task_begin(instance, key, priority)`` when a task starts; the
      return value says whether the task may issue now (EXCLUSIVE gates
      admission; every other mode admits immediately).
    - ``submit(req)`` for every kernel request the client issues; the
      policy either launches it (via the injected ``launch`` hook) or
      parks it in the priority queues. Returns True iff launched.
    - ``fill_complete()`` when a *filler* kernel finishes on the device
      (frees a pipeline-depth slot, accrues overshoot).
    - ``kernel_end(instance, kernel_id, ...)`` when any kernel finishes
      (opens the holder's predicted gap, runs the fill loop).
    - ``task_end(instance)`` when a task retires; returns the instances
      newly admitted by EXCLUSIVE serialization (empty otherwise).

    ``discipline`` selects the per-level queue discipline
    (``repro.core.queues.QUEUE_DISCIPLINES``: ``"fifo"`` — the paper's
    pinned default, ``"sjf"``, ``"edf"`` — or a per-level mapping/
    sequence). It governs how parked requests are ordered WITHIN a
    priority level (drain pops and gap-fill selection); cross-level
    priority order, holder election, and release semantics are untouched.

    ``threadsafe=False`` elides the priority-queue RLock for
    single-threaded drivers (the simulator); the threaded wall-clock
    engine keeps it. ``reference=True`` switches the fast paths back to
    their O(n) reference implementations (linear-scan BestPrioFit,
    scan-selected discipline pops, re-elected holder on every probe) —
    the oracle the differential tests compare the indexed/cached path
    against.

    ``online`` optionally attaches an ``repro.core.online.
    OnlineMeasurement``: the policy then reports gap prediction error
    (predicted SG vs the driver-known actual gap) into its drift
    counters at the exact point the Fig-12 feedback operates. The policy
    NEVER makes a different decision because of it — duration/gap
    refinement reaches decisions only through ``profiled`` version
    bumps, so ``online=None`` (the default) is decision-trace-identical
    to the pre-online implementation.

    ``interference`` optionally attaches an enabled
    ``repro.core.interference.InterferenceModel``: gap-fill candidates
    are then scored by predicted HOLDER SLOWDOWN — a candidate of
    resource class ``c`` under a holder-gap kernel of class ``h`` fits
    only while its predicted duration stays under
    ``gap_remaining / coeff(h, c)``, and each fill debits the gap by the
    coefficient-scaled effective duration. With ``interference=None``
    (the pinned default) or a disabled model every decision is
    bit-identical to the pre-interference implementation.
    """

    def __init__(self, mode: Mode,
                 profiled: Optional[ProfiledData] = None, *,
                 pipeline_depth: int = 2, feedback: bool = True,
                 epsilon: float = EPSILON,
                 clock: Callable[[], float] = lambda: 0.0,
                 launch: Callable[[KernelRequest, bool], None] = None,
                 threadsafe: bool = True,
                 trace: TraceSpec = "list",
                 discipline: QueueDisciplineSpec = "fifo",
                 reference: bool = False,
                 online=None,
                 interference=None):
        if launch is None:
            raise TypeError("FikitPolicy requires a launch hook")
        self.mode = mode
        self.online = online
        self.interference = interference
        self._interference_on = (interference is not None
                                 and getattr(interference, "enabled",
                                             False))
        self.profiled = profiled or ProfiledData()
        self.pipeline_depth = max(1, pipeline_depth)
        self.feedback = feedback
        self.epsilon = epsilon
        self._clock = clock
        self._launch_hook = launch
        self.reference = reference
        self.discipline = discipline
        self._fit = best_prio_fit_scan if reference else best_prio_fit

        self.queues = PriorityQueues(profiled=self.profiled,
                                     threadsafe=threadsafe,
                                     discipline_by_level=discipline,
                                     reference=reference,
                                     interference=interference)
        self.active: Dict[int, ActiveTask] = {}
        self.trace = make_trace_sink(trace)
        self._trace_on = getattr(self.trace, "enabled", True)
        # EXCLUSIVE admission state
        self._excl_running: Optional[int] = None
        self._excl_waiting: List[int] = []
        # gap state
        self.gap_open = False
        self.gap_remaining = 0.0
        self.gap_end_actual: Optional[float] = None
        #: (instance, kernel_id) whose completion opened the current gap —
        #: pure bookkeeping (never traced, never read by decisions unless
        #: interference scoring is on); the simulator's physical
        #: interference environment reads it to slow concurrent fillers.
        self.gap_kinfo: Optional[Tuple[int, KernelID]] = None
        self._gap_class: Optional[str] = None
        self.fills_in_flight = 0
        self.fill_count = 0
        self.overshoot_time = 0.0
        self.spurious_fill_completions = 0
        self._holder: Optional[int] = None       # cached election result
        self._last_holder: Optional[int] = None  # last traced transition

    # ------------------------------------------------------------- lifecycle
    def task_begin(self, instance: int, key: TaskKey, priority: int,
                   arrival: Optional[float] = None) -> bool:
        """Register an active task. Returns True if it may issue now."""
        if arrival is None:
            arrival = self._clock()
        at = ActiveTask(instance, key, priority, arrival)
        self.active[instance] = at
        self._consider_holder(at)
        if self._trace_on:
            self.trace.append(("begin", instance))
        admitted = True
        if self.mode is Mode.EXCLUSIVE:
            if self._excl_running is None:
                self._excl_running = instance
            else:
                self._excl_waiting.append(instance)
                if self._trace_on:
                    self.trace.append(("defer", instance))
                admitted = False
        self._note_holder()
        return admitted

    def task_end(self, instance: int) -> List[int]:
        """Retire a task. Returns instances newly admitted (EXCLUSIVE)."""
        self.active.pop(instance, None)
        if instance == self._holder:             # invalidate cache: re-elect
            self._holder = self._elect_holder()
        if self._trace_on:
            self.trace.append(("end", instance))
        admitted: List[int] = []
        if self.mode is Mode.EXCLUSIVE:
            if self._excl_running == instance:
                self._excl_running = None
                if self._excl_waiting:
                    nxt = self._excl_waiting.pop(0)
                    self._excl_running = nxt
                    if self._trace_on:
                        self.trace.append(("admit", nxt))
                    admitted.append(nxt)
        elif self.mode in QUEUED_MODES:
            self.gap_open = False
            self.gap_remaining = 0.0
            self.gap_kinfo = None
            self._gap_class = None
            self._release_new_holder()
        self._note_holder()
        return admitted

    # ------------------------------------------------------------- migration
    def detach_task(self, instance: int,
                    reqs: Optional[List[KernelRequest]] = None,
                    ) -> Tuple[ActiveTask, List[KernelRequest]]:
        """Remove ``instance`` and its parked requests WITHOUT retirement
        semantics: no release of the next holder's queue, no gap reset —
        nothing ended, the task is merely leaving for another device.

        ``reqs`` is the task's parked requests when the caller already
        tracks them (the placement layer does, keeping the steal at
        O(stream log n) indexed removes); omitted, they are collected by a
        scan over the queues. Requests come back in stream (seq) order.

        The placement layer only migrates fully-parked tasks (zero kernels
        in flight), so the detached task can never be this policy's holder:
        a holder's submits launch directly and its backlog is released the
        moment it is elected, hence a task with parked requests is always
        strictly below the holder."""
        at = self.active.pop(instance)
        if reqs is None:
            reqs = [r for r in self.queues if r.task_instance == instance]
        reqs = sorted(reqs, key=lambda r: r.seq_index)
        with self.queues.lock():
            for r in reqs:
                self.queues.remove(r)
        if instance == self._holder:           # defensive: re-elect
            self._holder = self._elect_holder()
        if self._trace_on:
            self.trace.append(("detach", instance))
        self._note_holder()
        return at, reqs

    # ------------------------------------------------------------ lifecycle
    def cancel_task(self, instance: int,
                    reqs: Optional[List[KernelRequest]] = None,
                    ) -> Tuple[List[KernelRequest], List[int]]:
        """Cancel ``instance`` at a kernel boundary: purge its parked
        requests from the priority queues (never a launched kernel —
        kernels are non-preemptible, so anything already on the device
        runs to completion), then retire it with full ``task_end``
        semantics: holder re-election, release of the new holder's
        backlog, EXCLUSIVE admission of the next waiter.

        ``reqs`` is the task's parked requests when the caller already
        tracks them (the placement layer does); omitted, they are
        collected by a queue scan. Returns ``(purged, admitted)`` — the
        purged requests in stream order (so callers can fail their
        futures / account conservation) and the instances newly admitted
        by EXCLUSIVE serialization."""
        if reqs is None:
            reqs = [r for r in self.queues if r.task_instance == instance]
        reqs = sorted(reqs, key=lambda r: r.seq_index)
        with self.queues.lock():
            for r in reqs:
                self.queues.remove(r)
        if self.mode is Mode.EXCLUSIVE and instance in self._excl_waiting:
            # a deferred task can be cancelled before it was ever admitted
            self._excl_waiting.remove(instance)
        if self._trace_on:
            self.trace.append(("cancel", instance))
        admitted = self.task_end(instance)
        return reqs, admitted

    def pause_task(self, instance: int,
                   reqs: Optional[List[KernelRequest]] = None,
                   ) -> Tuple[ActiveTask, List[KernelRequest]]:
        """``detach_task`` with holder-release semantics. A placement
        steal only ever detaches a fully-parked non-holder, but a pause
        may remove the CURRENT holder (a holder between kernels holds no
        device slot) — in that case the open gap dies with it and the
        next holder's backlog releases exactly as on retirement, so the
        device never deadlocks waiting on a paused task's submits."""
        was_holder = self.holder() == instance
        at, reqs = self.detach_task(instance, reqs)
        if was_holder and self.mode in QUEUED_MODES:
            self.gap_open = False
            self.gap_remaining = 0.0
            self.gap_kinfo = None
            self._gap_class = None
            self._release_new_holder()
        return at, reqs

    def attach_task(self, at: ActiveTask) -> None:
        """Adopt a task migrated from another device's policy, preserving
        its original arrival so holder election stays (priority, arrival,
        instance)-consistent. The caller re-submits the detached requests
        through ``submit`` afterwards so they route under THIS policy's
        holder state."""
        self.active[at.instance] = at
        self._consider_holder(at)
        if self._trace_on:
            self.trace.append(("attach", at.instance))
        self._note_holder()

    # --------------------------------------------------------------- routing
    def _consider_holder(self, at: ActiveTask) -> None:
        """Incremental holder cache update: the newcomer takes over iff it
        beats the incumbent in (priority, arrival, instance) order."""
        cur = self.active.get(self._holder) if self._holder is not None \
            else None
        if cur is None or (at.priority, at.arrival, at.instance) < \
                (cur.priority, cur.arrival, cur.instance):
            self._holder = at.instance

    def _elect_holder(self) -> Optional[int]:
        """Full election: highest-priority active task (ties: earliest
        arrival, then id). O(active); runs only on begin/end."""
        best: Optional[ActiveTask] = None
        for at in self.active.values():
            if best is None or (at.priority, at.arrival, at.instance) < \
                    (best.priority, best.arrival, best.instance):
                best = at
        return best.instance if best is not None else None

    def holder(self) -> Optional[int]:
        """Current holder — cached; O(1) on the submit/kernel_end path."""
        if self.reference:
            return self._elect_holder()
        return self._holder

    def submit(self, req: KernelRequest) -> bool:
        """Route one kernel request. Returns True iff it launched."""
        if self.mode not in QUEUED_MODES:
            self._launch(req)
            return True
        holder = self.holder()
        if holder is None or holder == req.task_instance:
            if self.gap_open and holder == req.task_instance:
                self._close_gap(holder)            # real-time feedback
            self._launch(req)
            return True
        if (self.active[req.task_instance].priority
                == self.active[holder].priority):
            self._launch(req)                      # equal prio: FIFO (case C)
            return True
        self.queues.push(req)
        if self._trace_on:
            self.trace.append(("queue", req.task_instance, req.seq_index))
        self.try_fill()                            # Fig 7: scan on enqueue
        return False

    # ------------------------------------------------------------ completion
    def fill_complete(self) -> None:
        """A filler kernel finished: free its slot, account overshoot.

        A spurious/double completion callback (an engine bug, or a device
        thread racing a retry) must not drive ``fills_in_flight`` negative
        — that would widen the pipeline-depth bound for the rest of the
        run. Clamp at zero and count the event instead."""
        if self.fills_in_flight <= 0:
            # the clamp below keeps this invariant; assert documents it
            assert self.fills_in_flight == 0, \
                "fills_in_flight must never go negative"
            self.spurious_fill_completions += 1
            return
        self.fills_in_flight -= 1
        now = self._clock()
        if self.gap_end_actual is not None and now > self.gap_end_actual:
            self.overshoot_time += now - self.gap_end_actual

    def kernel_end(self, instance: int, kernel_id: KernelID, *,
                   last: bool = False,
                   actual_gap: Optional[float] = None) -> None:
        """A kernel of ``instance`` finished on the device.

        Call ``fill_complete()`` first when the finished kernel was a
        filler. ``actual_gap`` is the true host gap following this kernel
        when the driver knows it (the simulator does); it anchors overshoot
        accounting. Wall-clock drivers pass None — the gap's actual end is
        then pinned when the holder's next submit closes it.
        """
        if self.mode is not Mode.FIKIT:
            return
        if self.holder() == instance and not last:
            at = self.active[instance]
            predicted = self.profiled.predict_gap(at.key, kernel_id)
            if (self.online is not None and actual_gap is not None
                    and predicted > self.epsilon):
                # Fig-12 drift accounting: the driver knows the true gap
                # the predicted SG is about to stand in for
                self.online.observe_gap_error(predicted, actual_gap)
            if predicted > self.epsilon:           # skip small gaps
                self.gap_open = True
                self.gap_remaining = predicted
                self.gap_kinfo = (instance, kernel_id)
                if self._interference_on:
                    self._gap_class = self.profiled.predict_class(
                        at.key, kernel_id)
                self.gap_end_actual = (
                    self._clock() + actual_gap
                    if self.feedback and actual_gap is not None else None)
                if self._trace_on:
                    self.trace.append(("gap_open", instance, predicted))
        self.try_fill()

    # ------------------------------------------------------------ gap + fill
    def _close_gap(self, holder: int) -> None:
        self.gap_open = False
        self.gap_remaining = 0.0
        self.gap_kinfo = None
        self._gap_class = None
        if self.feedback and self.gap_end_actual is None:
            # wall-clock feedback: the holder's submit IS the gap's end
            self.gap_end_actual = self._clock()
        if self._trace_on:
            self.trace.append(("gap_close", holder))

    def try_fill(self) -> None:
        """Fill an open gap (Algorithm 1, incremental with feedback and a
        bounded device-queue lookahead). PREEMPT never fills."""
        if self.mode is not Mode.FIKIT or not self.gap_open:
            return
        while (self.fills_in_flight < self.pipeline_depth
               and self.gap_remaining > 0.0):
            req, fill_time = self._fit(
                self.queues, self.gap_remaining, self.profiled,
                holder_class=self._gap_class,
                interference=(self.interference if self._interference_on
                              else None))
            if fill_time == -1:
                break
            self.fills_in_flight += 1
            self.fill_count += 1
            eff = fill_time
            if self._interference_on and self._gap_class is not None:
                fclass = self.profiled.predict_class(req.task_key,
                                                     req.kernel_id)
                eff = fill_time * self.interference.coeff(self._gap_class,
                                                          fclass)
                if self.online is not None:
                    # tag the launch so the observed duration can be
                    # matched back to its (holder, filler) class pair
                    self.online.note_fill_pair(req.task_instance,
                                               req.kernel_id,
                                               self._gap_class, fclass)
            self.gap_remaining -= eff
            self._launch(req, filler=True, tag="fill")

    def _release_new_holder(self) -> None:
        holder = self.holder()
        if holder is None:
            # drain leftovers: priority-major, per-level discipline order
            req = self.queues.pop_highest()
            while req is not None:
                self._launch(req, tag="drain")
                req = self.queues.pop_highest()
            return
        hp = self.active[holder].priority
        with self.queues.lock():
            for req in list(self.queues):
                at = self.active.get(req.task_instance)
                if req.task_instance == holder or \
                        (at is not None and at.priority == hp):
                    self.queues.remove(req)
                    self._launch(req, tag="release")

    # -------------------------------------------------------------- plumbing
    def _launch(self, req: KernelRequest, filler: bool = False,
                tag: str = "launch") -> None:
        if self._trace_on:
            self.trace.append((tag, req.task_instance, req.seq_index))
        self._launch_hook(req, filler)

    def _note_holder(self) -> None:
        h = self.holder()
        if h != self._last_holder:
            self._last_holder = h
            if self._trace_on:
                self.trace.append(("holder", h))

    # ---------------------------------------------------------------- stats
    @property
    def queued(self) -> int:
        return len(self.queues)
