"""Wall-clock FIKIT engine: real threads, real JAX program execution.

Roles map 1:1 to the paper's deployment (§3.2):
- ``HookClient``   (repro.core.client) — intercepts a service's segment
  dispatches, forwards KernelRequests to the scheduler (paper: LD_PRELOAD
  hook + UDP; here: in-process call + thread-safe queues).
- ``WallClockEngine`` — the FIKIT scheduler process: priority queues,
  BestPrioFit gap filling with real-time feedback, and the serial device
  executor thread (the TPU/GPU analog: one program at a time, FIFO).

The device thread is the ONLY thread that touches the accelerator — it pops
launched requests in FIFO order and runs their payload callables (jitted JAX
segments, block_until_ready inside). Everything the simulator models is
real here: device busy intervals, queue waits, fill overshoot.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.fikit import EPSILON, best_prio_fit
from repro.core.profiler import ProfiledData
from repro.core.queues import PriorityQueues
from repro.core.scheduler import Mode
from repro.core.task import KernelRequest, TaskKey


@dataclass
class ExecRecord:
    req: KernelRequest
    start: float
    end: float
    filler: bool = False


@dataclass
class ActiveTask:
    instance: int
    key: TaskKey
    priority: int
    arrival: float
    done: threading.Event = field(default_factory=threading.Event)


class WallClockEngine:
    def __init__(self, mode: Mode = Mode.FIKIT,
                 profiled: Optional[ProfiledData] = None,
                 pipeline_depth: int = 2, feedback: bool = True,
                 epsilon: float = EPSILON):
        self.mode = mode
        self.profiled = profiled or ProfiledData()
        self.pipeline_depth = max(1, pipeline_depth)
        self.feedback = feedback
        self.epsilon = epsilon

        self._lock = threading.RLock()
        self._queues = PriorityQueues()
        self._device_q: "queue.Queue" = queue.Queue()
        self._records: List[ExecRecord] = []
        self._active: Dict[int, ActiveTask] = {}
        self._futures: Dict[int, Future] = {}      # req.uid -> Future
        self._excl_cond = threading.Condition(self._lock)
        self._excl_running: Optional[int] = None
        self._excl_waiters: List[int] = []
        # FIKIT gap state (guarded by _lock)
        self._gap_open = False
        self._gap_remaining = 0.0
        self._gap_opened_at = 0.0
        self._fills_in_flight = 0
        self.fill_count = 0
        self._stop = False
        self._thread = threading.Thread(target=self._device_loop,
                                        daemon=True, name="fikit-device")
        self._started = False

    # ---------------------------------------------------------------- device
    def start(self) -> "WallClockEngine":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop = True
        self._device_q.put(None)
        if self._started:
            self._thread.join(timeout=5)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def _device_loop(self) -> None:
        while True:
            item = self._device_q.get()
            if item is None or self._stop:
                break
            req, fut, filler = item
            t0 = time.perf_counter()
            try:
                out = req.payload()
                t1 = time.perf_counter()
                fut.set_result((out, t0, t1))
            except BaseException as e:  # pragma: no cover
                t1 = time.perf_counter()
                fut.set_exception(e)
            with self._lock:
                self._records.append(ExecRecord(req, t0, t1, filler))
            self._on_kernel_end(req, filler)

    # ----------------------------------------------------------- task control
    def task_begin(self, instance: int, key: TaskKey, priority: int) -> None:
        with self._lock:
            at = ActiveTask(instance, key, priority, time.perf_counter())
            self._active[instance] = at
            if self.mode is Mode.EXCLUSIVE:
                while self._excl_running is not None:
                    self._excl_cond.wait()
                self._excl_running = instance

    def task_end(self, instance: int) -> None:
        with self._lock:
            self._active.pop(instance, None)
            if self.mode is Mode.EXCLUSIVE and self._excl_running == instance:
                self._excl_running = None
                self._excl_cond.notify_all()
            elif self.mode is Mode.FIKIT:
                self._gap_open = False
                self._gap_remaining = 0.0
                self._release_new_holder()

    # --------------------------------------------------------------- routing
    def submit(self, req: KernelRequest) -> Future:
        """Hook-client -> scheduler message. Returns a Future of
        (output, start, end)."""
        fut: Future = Future()
        req.submit_time = time.perf_counter()
        with self._lock:
            self._futures[req.uid] = fut
            if self.mode is not Mode.FIKIT:
                self._launch(req, fut)
                return fut
            holder = self._holder()
            if holder is None or holder == req.task_instance:
                if self._gap_open:                 # feedback: gap over
                    self._gap_open = False
                    self._gap_remaining = 0.0
                self._launch(req, fut)
            elif (self._active[req.task_instance].priority
                  == self._active[holder].priority):
                self._launch(req, fut)             # equal prio: FIFO
            else:
                self._queues.push(req)
                self._try_fill()
        return fut

    def _launch(self, req: KernelRequest, fut: Optional[Future] = None,
                filler: bool = False) -> None:
        fut = fut if fut is not None else self._futures[req.uid]
        self._device_q.put((req, fut, filler))

    # ------------------------------------------------------------- scheduler
    def _holder(self) -> Optional[int]:
        best = None
        for inst, at in self._active.items():
            if best is None or (at.priority, at.arrival, inst) < \
                    (self._active[best].priority, self._active[best].arrival,
                     best):
                best = inst
        return best

    def _release_new_holder(self) -> None:
        holder = self._holder()
        if holder is None:
            req = self._queues.pop_highest()
            while req is not None:
                self._launch(req)
                req = self._queues.pop_highest()
            return
        hp = self._active[holder].priority
        for req in list(self._queues):
            if req.task_instance == holder or \
                    self._active[req.task_instance].priority == hp:
                self._queues.remove(req)
                self._launch(req)

    def _on_kernel_end(self, req: KernelRequest, filler: bool) -> None:
        with self._lock:
            if filler:
                self._fills_in_flight -= 1
            if self.mode is not Mode.FIKIT:
                return
            holder = self._holder()
            if holder == req.task_instance and not filler:
                predicted = self.profiled.predict_gap(req.task_key,
                                                      req.kernel_id)
                if predicted > self.epsilon:
                    self._gap_open = True
                    self._gap_remaining = predicted
                    self._gap_opened_at = time.perf_counter()
            self._try_fill()

    def _try_fill(self) -> None:
        if self.mode is not Mode.FIKIT or not self._gap_open:
            return
        while (self._fills_in_flight < self.pipeline_depth
               and self._gap_remaining > 0.0):
            req, fill_time = best_prio_fit(self._queues, self._gap_remaining,
                                           self.profiled)
            if fill_time == -1:
                break
            self._fills_in_flight += 1
            self.fill_count += 1
            self._gap_remaining -= fill_time
            self._launch(req, filler=True)

    # ------------------------------------------------------------------ info
    def records(self) -> List[ExecRecord]:
        with self._lock:
            return list(self._records)

    def device_busy_time(self) -> float:
        return sum(r.end - r.start for r in self.records())
