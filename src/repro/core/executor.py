"""Wall-clock FIKIT engine: real threads, real JAX program execution.

Roles map 1:1 to the paper's deployment (§3.2):
- ``HookClient``   (repro.core.client) — intercepts a service's segment
  dispatches, forwards KernelRequests to the scheduler (paper: LD_PRELOAD
  hook + UDP; here: in-process call + thread-safe queues).
- ``WallClockEngine`` — the FIKIT scheduler process: the serial device
  executor thread (the TPU/GPU analog: one program at a time, FIFO) plus
  the thread-safe shell around the shared scheduling core.

ALL scheduling decisions — holder election, routing, gap open/close with
real-time feedback, the bounded BestPrioFit fill loop, release-on-task-done,
overshoot accounting, PREEMPT parking — live in
``repro.core.policy.FikitPolicy``, the same state machine that drives the
discrete-event simulator; device election and cross-device work stealing
live in ``repro.core.placement.PlacementLayer`` (K=1 is a pass-through).
This engine only adds what the simulator fakes: real threads, a lock,
Futures, and ``time.perf_counter``.

Each device thread pops launched requests in FIFO order and runs their
payload callables (jitted JAX segments, block_until_ready inside).
``devices=K`` starts K device threads over K serial queues, one per
placement device. Everything the simulator models is real here: device
busy intervals, queue waits, fill overshoot.

CAVEAT for K > 1: a "device" is a serial executor THREAD. Payloads are
not pinned to distinct JAX devices, so on a single-accelerator host the K
serial queues share one piece of hardware and wall-clock multi-device
numbers measure scheduling behavior (routing, parking, stealing), not
hardware scaling — use the discrete-event simulator
(``SimScheduler(devices=K)``, ``benchmarks/bench_placement.py``) for
scaling claims. On a multi-device host, pin each payload to
``jax.devices()[d]`` (e.g. ``jax.device_put``/``jit(device=...)``) to
make thread d's queue correspond to real hardware d.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.fikit import EPSILON
from repro.core.interference import InterferenceModel
from repro.core.online import OnlineConfig, OnlineMeasurement
from repro.core.placement import DisciplineSpec, PlacementLayer
from repro.core.policy import Mode
from repro.core.profiler import ProfiledData
from repro.core.task import KernelRequest, TaskKey


@dataclass
class ExecRecord:
    req: KernelRequest
    start: float
    end: float
    filler: bool = False
    device: int = 0


class JobCancelled(RuntimeError):
    """Set on the Future of every request purged by an ops-plane cancel
    (and returned for submits arriving after the cancel), so a client
    blocked on ``fut.result()`` unblocks with a typed error instead of
    hanging forever."""


class WallClockEngine:
    def __init__(self, mode: Mode = Mode.FIKIT,
                 profiled: Optional[ProfiledData] = None,
                 pipeline_depth: int = 2, feedback: bool = True,
                 epsilon: float = EPSILON, trace: str = "list",
                 devices: int = 1,
                 discipline: DisciplineSpec = "least_loaded",
                 queue_discipline="fifo",
                 steal: bool = True,
                 online=None,
                 interference=None,
                 on_kernel_complete=None):
        """queue_discipline selects the per-level intra-device queue
        ordering ("fifo" default / "sjf" / "edf"); request deadlines for
        edf levels are absolute ``time.perf_counter`` seconds (the
        engine's clock), which ``HookClient.run(deadline=...)`` derives
        from a caller-relative budget.

        online (None / True / repro.core.online.OnlineConfig) enables the
        live SK/SG refinement loop: each device thread's perf_counter
        brackets feed the OnlineMeasurement (under the engine lock, like
        every other placement entry point), epoch commits reload the
        shared profile mid-serving, and ``stop()`` flushes the partial
        final epoch. ``online_stats()`` exposes the counters.

        interference (None / True / mapping /
        repro.core.interference.InterferenceModel) enables
        interference-aware gap filling (see ``SimScheduler``); None or a
        disabled model keeps decisions bit-identical to
        interference-off.

        on_kernel_complete (callable ``fn(req, start, end)`` or None) is
        the ops plane's write-ahead seam: called by the device thread
        under the engine lock the moment a kernel finishes, BEFORE any
        scheduling side-effect of the completion, so a durable record
        (``repro.core.jobstore``) commits ahead of the boundary's
        processing. Exceptions from the hook propagate (a store that
        cannot record must not be silently dropped)."""
        self.mode = mode
        self.profiled = profiled or ProfiledData()
        self.devices = devices
        self.interference = InterferenceModel.coerce(interference)
        if self.interference is not None and self.interference.enabled:
            self.profiled.interference = self.interference
        cfg = OnlineConfig.coerce(online)
        self.online = (OnlineMeasurement(self.profiled, cfg,
                                         clock=time.perf_counter,
                                         interference=self.interference)
                       if cfg is not None else None)

        self._lock = threading.RLock()
        # threaded driver: keep the queue lock; trace="off"/"ring" bounds
        # the per-decision trace cost for long-running serving. The engine
        # lock serializes every placement/policy entry point, exactly as it
        # did for the bare single-device policy.
        self.placement = PlacementLayer(devices, mode, self.profiled,
                                        discipline=discipline, steal=steal,
                                        queue_discipline=queue_discipline,
                                        pipeline_depth=pipeline_depth,
                                        feedback=feedback, epsilon=epsilon,
                                        clock=time.perf_counter,
                                        launch=self._device_launch,
                                        threadsafe=True, trace=trace,
                                        online=self.online,
                                        interference=self.interference)
        # single-device alias kept for callers that inspect decision state
        self.policy = self.placement.policies[0]
        self._device_qs: List["queue.Queue"] = [queue.Queue()
                                               for _ in range(devices)]
        self._records: List[ExecRecord] = []
        self._futures: Dict[int, Future] = {}      # req.uid -> Future
        self._done_cbs: Dict[int, object] = {}     # req.uid -> on_complete
        self._admit_cond = threading.Condition(self._lock)
        self._admitted: set = set()
        self._stop = False
        self._threads = [
            threading.Thread(target=self._device_loop, args=(d,),
                             daemon=True, name=f"fikit-device-{d}")
            for d in range(devices)]
        self._started = False
        self._stopped = False
        self._draining = False
        self._cancelled_insts: set = set()
        self._on_kernel_complete = on_kernel_complete

    # ---------------------------------------------------------------- device
    def start(self) -> "WallClockEngine":
        if self._stopped:
            raise RuntimeError("WallClockEngine cannot restart after "
                               "stop(); build a fresh engine")
        if not self._started:
            self._started = True
            for t in self._threads:
                t.start()
        return self

    def stop(self) -> None:
        """Stop the device threads and flush the final online epoch.
        Idempotent: a second stop() is a no-op (in particular the online
        flush commits exactly once)."""
        if self._stopped:
            return
        self._stopped = True
        self._stop = True
        for q in self._device_qs:
            q.put(None)
        if self._started:
            for t in self._threads:
                t.join(timeout=5)
        if self.online is not None:
            with self._lock:
                self.online.commit()   # flush the partial final epoch

    def _check_running(self, what: str) -> None:
        """Fail fast — a submit into a never-started or stopped engine
        would otherwise hang its client forever on an unserved queue."""
        if not self._started:
            raise RuntimeError(f"{what} before WallClockEngine.start() — "
                               f"no device thread is serving the queue")
        if self._stopped:
            raise RuntimeError(f"{what} after WallClockEngine.stop() — "
                               f"the device threads have exited")

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def _device_loop(self, device: int) -> None:
        dq = self._device_qs[device]
        while True:
            item = dq.get()
            if item is None or self._stop:
                break
            req, fut, filler = item
            t0 = time.perf_counter()
            out = err = None
            try:
                out = req.payload()
                t1 = time.perf_counter()
                fut.set_result((out, t0, t1))
            except BaseException as e:  # pragma: no cover
                t1 = time.perf_counter()
                err = e
                fut.set_exception(e)
            with self._lock:
                if self._on_kernel_complete is not None:
                    # write-ahead: the durable record commits BEFORE the
                    # boundary's scheduling side-effects
                    self._on_kernel_complete(req, t0, t1)
                self._futures.pop(req.uid, None)   # resolved: stop pinning it
                self._records.append(ExecRecord(req, t0, t1, filler, device))
                if filler:
                    self.placement.fill_complete(device)
                self.placement.kernel_end(req.task_instance, req.kernel_id,
                                          start=t0, end=t1)
                cb = self._done_cbs.pop(req.uid, None)
            if cb is not None:
                # completion callback AFTER the boundary's scheduling
                # side-effects, OUTSIDE the lock: the callee may submit
                # the stream's next request or retire the task without
                # parking a thread on the Future (admission-plane seam)
                cb(req, out, t0, t1, err)

    # ----------------------------------------------------------- task control
    def task_begin(self, instance: int, key: TaskKey, priority: int) -> None:
        self._check_running(f"task_begin({instance})")
        if self._draining:
            raise RuntimeError("WallClockEngine is draining — "
                               "not admitting new tasks")
        with self._lock:
            if self.placement.task_begin(instance, key, priority):
                return
            # EXCLUSIVE: the policy parked us; wait for admission in the
            # policy's FIFO begin order.
            while instance not in self._admitted:
                self._admit_cond.wait()
            self._admitted.discard(instance)

    def task_end(self, instance: int) -> None:
        with self._lock:
            self._cancelled_insts.discard(instance)
            admitted = self.placement.task_end(instance)
            if admitted:
                self._admitted.update(admitted)
                self._admit_cond.notify_all()

    # --------------------------------------------------------------- routing
    def submit(self, req: KernelRequest, on_complete=None) -> Future:
        """Hook-client -> scheduler message. Returns a Future of
        (output, start, end).

        ``on_complete`` (``fn(req, out, start, end, err)`` or None) is
        the non-blocking completion seam: the device thread calls it
        AFTER the kernel's ``kernel_end`` scheduling side-effects, with
        no engine lock held, so the callee can chain the stream's next
        submit (or ``task_end``) without a thread ever parking on the
        Future. A request purged by an ops-plane ``cancel`` (or
        submitted after one) gets its callback invoked with
        ``err=JobCancelled`` instead."""
        self._check_running(f"submit({req.task_instance}:{req.seq_index})")
        fut: Future = Future()
        req.submit_time = time.perf_counter()
        cancelled = None
        with self._lock:
            if req.task_instance in self._cancelled_insts:
                # the task was cancelled under this client's feet:
                # fail fast instead of queueing work that can never run
                cancelled = JobCancelled(
                    f"task {req.task_instance} was cancelled")
                fut.set_exception(cancelled)
            else:
                self._futures[req.uid] = fut
                if on_complete is not None:
                    self._done_cbs[req.uid] = on_complete
                self.placement.submit(req)
        if cancelled is not None and on_complete is not None:
            on_complete(req, None, None, None, cancelled)
        return fut

    # ------------------------------------------------------- lifecycle verbs
    def cancel(self, instance: int) -> int:
        """Cancel a task: purge its queued requests (their Futures fail
        with ``JobCancelled`` so blocked clients unblock), let in-flight
        kernels finish. Returns the number of purged requests."""
        cbs = []
        with self._lock:
            purged, admitted = self.placement.cancel(instance)
            self._cancelled_insts.add(instance)
            for r in purged:
                err = JobCancelled(
                    f"task {instance} cancelled: kernel "
                    f"{r.seq_index} purged before launch")
                fut = self._futures.pop(r.uid, None)
                if fut is not None:
                    fut.set_exception(err)
                cb = self._done_cbs.pop(r.uid, None)
                if cb is not None:
                    cbs.append((cb, r, err))
            if admitted:                       # EXCLUSIVE: next waiter
                self._admitted.update(admitted)
                self._admit_cond.notify_all()
        for cb, r, err in cbs:   # outside the lock, like every completion
            cb(r, None, None, None, err)
        return len(purged)

    def pause(self, instance: int) -> bool:
        """Pause a task at its next kernel boundary (True if it took
        effect immediately). Its clients' pending Futures stay unresolved
        — a blocked client simply waits out the pause."""
        with self._lock:
            return self.placement.pause(instance)

    def resume(self, instance: int, device: Optional[int] = None) -> int:
        """Re-admit a paused task (see ``PlacementLayer.resume``)."""
        with self._lock:
            return self.placement.resume(instance, device)

    def drain(self, timeout: float = 10.0) -> bool:
        """Stop admitting new tasks, wait for every live (non-paused)
        task to finish its in-flight and queued work, then flush the
        online epoch. Returns True when fully drained within
        ``timeout`` seconds; the engine is still running either way
        (call ``stop()`` to shut it down)."""
        self._draining = True
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                live = len(self.placement._device_of)
            if live == 0 or time.monotonic() >= deadline:
                break
            time.sleep(0.005)
        if self.online is not None:
            with self._lock:
                self.online.commit()
        return live == 0

    def _device_launch(self, device: int, req: KernelRequest,
                       filler: bool) -> None:
        """Placement launch hook: push onto ``device``'s serial queue.

        Always called with ``_lock`` held (every placement entry point
        is)."""
        fut = self._futures.get(req.uid)
        if fut is None:                            # pragma: no cover
            fut = self._futures[req.uid] = Future()
        self._device_qs[device].put((req, fut, filler))

    # ------------------------------------------------------------------ info
    @property
    def fill_count(self) -> int:
        return self.placement.fill_count

    @property
    def overshoot_time(self) -> float:
        return self.placement.overshoot_time

    @property
    def steal_count(self) -> int:
        return self.placement.steal_count

    def online_stats(self) -> Optional[dict]:
        """Online measurement counters (None when the loop is off or
        wired-but-disabled)."""
        if self.online is None or not self.online.config.enabled:
            return None
        with self._lock:
            return self.online.stats()

    def records(self) -> List[ExecRecord]:
        with self._lock:
            return list(self._records)

    def device_busy_time(self, device: Optional[int] = None) -> float:
        return sum(r.end - r.start for r in self.records()
                   if device is None or r.device == device)
