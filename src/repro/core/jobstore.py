"""Durable ops plane: a write-ahead job store for the serving path.

FIKIT's scheduling state (priority queues, holder, gaps, online-learned
SK/SG) lives in process memory; before this module existed a crash lost
every queued request and every learned profile, and an operator had no way
to cancel, pause, or drain a task. ``JobStore`` is the durable record the
engines write THROUGH so a killed process can restart and resume:

- **jobs** — one row per submitted task instance: its ``TaskKey``,
  priority, deadline, total kernel count, an optional serialized kernel
  spec (the simulator's replayable trace; wall-clock payloads are
  callables and re-run from the service definition instead), and a
  lifecycle state (``submitted → running → done`` with ``paused`` /
  ``cancelled`` branches).
- **completions** — one row per finished kernel ``(job, seq)``. This is
  the write-ahead commit point of a kernel boundary: the row is durable
  BEFORE any scheduling side-effect of the completion, so a crash at any
  boundary loses nothing and recovery re-submits exactly the suffix
  ``seq >= watermark``. The primary key makes a duplicated completion a
  structural error (``DuplicateCompletion``), and the contiguity check
  makes a stream-order violation one too (``StreamOrderViolation``) —
  the conservation proof the kill-and-restart sweep rides on.
- **profiles** — the latest snapshot of the (possibly online-refined)
  ``ProfiledData``, in ``repro.core.profile_store`` JSON form including
  EMA counters and interference coefficients, so recovery resumes
  scheduling with the learned SK/SG intact.
- **controls** — a queue of operator verbs (``cancel``/``pause``/
  ``resume``/``drain``) written by the CLI (``repro.launch.serve``) and
  consumed by a live serving process sharing the store file.

Backends: a file path opens SQLite in WAL mode with per-statement
durability (autocommit); ``JobStore.memory()`` opens ``:memory:`` — same
schema and API, nothing touches disk — for tests and for engines that
want conservation checking without persistence. All methods are
thread-safe (one internal lock; SQLite connection shared).

The standing contract: a store attached to an engine only OBSERVES —
recording submissions and completions never changes a scheduling
decision, pinned by randomized store-attached-vs-absent differential
cases in ``tests/test_recovery.py``.
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.profile_store import (_kid_from_json, _kid_to_json,
                                      profiles_from_obj, profiles_to_obj)
from repro.core.profiler import ProfiledData
from repro.core.task import TaskKey, TaskSpec, TraceKernel

# ---------------------------------------------------------------- lifecycle
#: job lifecycle states
SUBMITTED = "submitted"
RUNNING = "running"
PAUSED = "paused"
CANCELLED = "cancelled"
DONE = "done"
STATES = (SUBMITTED, RUNNING, PAUSED, CANCELLED, DONE)
#: states a job can never leave
TERMINAL_STATES = (CANCELLED, DONE)
#: operator verbs accepted by the control queue
CONTROL_VERBS = ("cancel", "pause", "resume", "drain")

_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
    job_id       INTEGER PRIMARY KEY,
    process      TEXT NOT NULL,
    args         TEXT NOT NULL,
    priority     INTEGER NOT NULL,
    n_kernels    INTEGER NOT NULL,
    deadline     REAL,
    spec         TEXT,
    state        TEXT NOT NULL,
    submitted_at REAL,
    updated_at   REAL
);
CREATE TABLE IF NOT EXISTS completions (
    job_id       INTEGER NOT NULL,
    seq          INTEGER NOT NULL,
    completed_at REAL,
    PRIMARY KEY (job_id, seq)
) WITHOUT ROWID;
CREATE TABLE IF NOT EXISTS profiles (
    id         INTEGER PRIMARY KEY CHECK (id = 1),
    payload    TEXT NOT NULL,
    updated_at REAL
);
CREATE TABLE IF NOT EXISTS controls (
    ctl_id     INTEGER PRIMARY KEY AUTOINCREMENT,
    verb       TEXT NOT NULL,
    job_id     INTEGER,
    arg        TEXT,
    consumed   INTEGER NOT NULL DEFAULT 0,
    created_at REAL
);
CREATE TABLE IF NOT EXISTS meta (
    k TEXT PRIMARY KEY,
    v TEXT
);
"""

SCHEMA_VERSION = "1"


class JobStoreError(RuntimeError):
    """Base class for job-store integrity errors."""


class UnknownJob(JobStoreError):
    pass


class DuplicateCompletion(JobStoreError):
    """The same (job, seq) kernel completion was recorded twice — a
    request would have executed twice after recovery."""


class StreamOrderViolation(JobStoreError):
    """A completion arrived out of stream order — kernel ``seq`` finished
    before ``seq - 1`` did, which the serial-device + stream-order
    invariants make impossible unless an engine is broken."""


@dataclass
class JobRecord:
    """One job row, hydrated (``completed`` is the stream watermark: the
    number of contiguously completed kernels)."""
    job_id: int
    key: TaskKey
    priority: int
    n_kernels: int
    completed: int
    state: str
    deadline: Optional[float] = None
    spec: Optional[dict] = None
    submitted_at: float = 0.0

    @property
    def remaining(self) -> int:
        return self.n_kernels - self.completed

    @property
    def incomplete(self) -> bool:
        return self.state not in TERMINAL_STATES


# ------------------------------------------------------- spec serialization
def spec_to_obj(spec: TaskSpec) -> dict:
    """Serialize a simulator ``TaskSpec``'s replayable parts (kernel
    trace, client model). Key/priority/deadline live in job columns."""
    return {
        "kernels": [[_kid_to_json(k.kid), k.duration, k.gap_after, k.kclass]
                    for k in spec.kernels],
        "max_inflight": spec.max_inflight,
        "arrival": spec.arrival,
    }


def spec_from_record(rec: JobRecord) -> TaskSpec:
    """Rebuild the REMAINING TaskSpec for an incomplete job: the kernel
    suffix from the completion watermark on, arriving immediately. The
    caller pairs it with ``rec.completed`` as the seq base so recovered
    completions keep their original stream indices."""
    if rec.spec is None:
        raise JobStoreError(f"job {rec.job_id} has no replayable spec "
                            f"(wall-clock jobs re-run from the service)")
    kernels = [TraceKernel(_kid_from_json(kj), dur, gap, kclass=kc)
               for kj, dur, gap, kc in rec.spec["kernels"]]
    return TaskSpec(rec.key, rec.priority, kernels[rec.completed:],
                    arrival=0.0, max_inflight=rec.spec["max_inflight"],
                    deadline=rec.deadline)


class JobStore:
    """SQLite-backed write-ahead record of jobs, completions, learned
    profiles, and operator control requests. See module docstring."""

    def __init__(self, path: str = ":memory:"):
        self.path = path
        self._lock = threading.RLock()
        # autocommit (isolation_level=None): every INSERT is its own
        # durable transaction — the write-ahead property the recovery
        # sweep depends on
        self._db = sqlite3.connect(path, isolation_level=None,
                                   check_same_thread=False)
        self._db.executescript(_SCHEMA)
        if path != ":memory:":
            # WAL keeps concurrent CLI readers (status verb) from
            # blocking the serving process's boundary writes
            self._db.execute("PRAGMA journal_mode=WAL")
            self._db.execute("PRAGMA synchronous=NORMAL")
        self._db.execute(
            "INSERT OR IGNORE INTO meta (k, v) VALUES ('schema', ?)",
            (SCHEMA_VERSION,))

    @classmethod
    def memory(cls) -> "JobStore":
        """In-memory backend: same schema/API, no disk, no durability —
        for tests and conservation-checking without persistence."""
        return cls(":memory:")

    def close(self) -> None:
        with self._lock:
            self._db.close()

    def __enter__(self) -> "JobStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- writes
    def record_submit(self, job_id: Optional[int], key: TaskKey,
                      priority: int, *, n_kernels: int,
                      spec: Optional[dict] = None,
                      deadline: Optional[float] = None,
                      state: str = RUNNING,
                      at: Optional[float] = None) -> int:
        """Record a job submission; returns its id. ``job_id=None``
        allocates the next id. An existing row (a recovery re-submission)
        is NOT overwritten — its original spec, kernel count, and
        completions survive; only its state advances to ``state``."""
        now = time.time() if at is None else at
        with self._lock:
            if job_id is not None:
                cur = self._db.execute(
                    "SELECT 1 FROM jobs WHERE job_id = ?", (job_id,))
                if cur.fetchone() is not None:
                    self._db.execute(
                        "UPDATE jobs SET state = ?, updated_at = ? "
                        "WHERE job_id = ?", (state, now, job_id))
                    return job_id
            cur = self._db.execute(
                "INSERT INTO jobs (job_id, process, args, priority, "
                "n_kernels, deadline, spec, state, submitted_at, "
                "updated_at) VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?, ?)",
                (job_id, key.process, json.dumps(list(key.args)), priority,
                 n_kernels, deadline,
                 None if spec is None else json.dumps(spec),
                 state, now, now))
            return job_id if job_id is not None else cur.lastrowid

    def record_state(self, job_id: int, state: str,
                     at: Optional[float] = None) -> None:
        if state not in STATES:
            raise ValueError(f"unknown job state {state!r} "
                             f"(known: {list(STATES)})")
        now = time.time() if at is None else at
        with self._lock:
            cur = self._db.execute(
                "UPDATE jobs SET state = ?, updated_at = ? "
                "WHERE job_id = ?", (state, now, job_id))
            if cur.rowcount == 0:
                raise UnknownJob(f"job {job_id} not in store")

    def record_completion(self, job_id: int, seq: int,
                          at: Optional[float] = None) -> int:
        """Durably record kernel ``seq`` of ``job_id`` as completed; the
        write-ahead commit of a kernel boundary. Enforces stream
        contiguity (``seq`` must be the current watermark) and raises
        ``DuplicateCompletion`` / ``StreamOrderViolation`` otherwise.
        Returns the new watermark."""
        now = time.time() if at is None else at
        with self._lock:
            wm = self._watermark(job_id)
            if seq < wm:
                raise DuplicateCompletion(
                    f"job {job_id} kernel {seq} already recorded "
                    f"(watermark {wm}) — a request would run twice")
            if seq > wm:
                raise StreamOrderViolation(
                    f"job {job_id} kernel {seq} completed before "
                    f"kernel {wm} — stream order broken")
            try:
                self._db.execute(
                    "INSERT INTO completions (job_id, seq, completed_at) "
                    "VALUES (?, ?, ?)", (job_id, seq, now))
            except sqlite3.IntegrityError as e:  # pragma: no cover
                raise DuplicateCompletion(
                    f"job {job_id} kernel {seq} already recorded") from e
            return wm + 1

    def reset_completions(self, job_id: int) -> None:
        """Forget a job's completions (wall-clock recovery re-runs an
        incomplete invocation from scratch — request-level at-least-once;
        the simulator's kernel-exact path never needs this)."""
        with self._lock:
            self._db.execute("DELETE FROM completions WHERE job_id = ?",
                             (job_id,))

    # --------------------------------------------------------------- reads
    def _watermark(self, job_id: int) -> int:
        row = self._db.execute(
            "SELECT MAX(seq) FROM completions WHERE job_id = ?",
            (job_id,)).fetchone()
        return 0 if row[0] is None else row[0] + 1

    def _hydrate(self, row) -> JobRecord:
        (job_id, process, args, priority, n_kernels, deadline, spec,
         state, submitted_at) = row
        return JobRecord(
            job_id=job_id,
            key=TaskKey(process, tuple(json.loads(args))),
            priority=priority, n_kernels=n_kernels,
            completed=self._watermark(job_id), state=state,
            deadline=deadline,
            spec=None if spec is None else json.loads(spec),
            submitted_at=submitted_at or 0.0)

    _JOB_COLS = ("job_id, process, args, priority, n_kernels, deadline, "
                 "spec, state, submitted_at")

    def job(self, job_id: int) -> JobRecord:
        with self._lock:
            row = self._db.execute(
                f"SELECT {self._JOB_COLS} FROM jobs WHERE job_id = ?",
                (job_id,)).fetchone()
            if row is None:
                raise UnknownJob(f"job {job_id} not in store")
            return self._hydrate(row)

    def jobs(self, states: Optional[Sequence[str]] = None
             ) -> List[JobRecord]:
        with self._lock:
            rows = self._db.execute(
                f"SELECT {self._JOB_COLS} FROM jobs "
                f"ORDER BY job_id").fetchall()
            recs = [self._hydrate(r) for r in rows]
        if states is not None:
            recs = [r for r in recs if r.state in states]
        return recs

    def incomplete_jobs(self, include_paused: bool = False
                        ) -> List[JobRecord]:
        """Jobs a restart must resume: not done, not cancelled. Paused
        jobs stay paused across a restart (an explicit ``resume`` verb
        re-admits them) unless ``include_paused``."""
        skip = set(TERMINAL_STATES)
        if not include_paused:
            skip.add(PAUSED)
        return [r for r in self.jobs() if r.state not in skip]

    def completions(self, job_id: int) -> List[int]:
        with self._lock:
            rows = self._db.execute(
                "SELECT seq FROM completions WHERE job_id = ? "
                "ORDER BY seq", (job_id,)).fetchall()
        return [r[0] for r in rows]

    def watermark(self, job_id: int) -> int:
        with self._lock:
            return self._watermark(job_id)

    # ------------------------------------------------------------ recovery
    def recovery_plan(self, include_paused: bool = False
                      ) -> Tuple[List[TaskSpec], List[int], List[int]]:
        """Build the simulator recovery inputs: the remaining ``TaskSpec``
        suffix per incomplete job, the job ids to keep recording under,
        and the per-job seq bases (completion watermarks). Jobs without a
        replayable spec (wall-clock invocations) are skipped — the serving
        layer re-runs those from the service definition."""
        specs, ids, bases = [], [], []
        for rec in self.incomplete_jobs(include_paused=include_paused):
            if rec.spec is None or rec.remaining <= 0:
                continue
            specs.append(spec_from_record(rec))
            ids.append(rec.job_id)
            bases.append(rec.completed)
        return specs, ids, bases

    # ------------------------------------------------------------ profiles
    def snapshot_profiles(self, data: ProfiledData,
                          at: Optional[float] = None) -> None:
        """Checkpoint the (possibly online-refined) profile state. One
        snapshot row, overwritten — the store keeps the LATEST learned
        SK/SG, which is what recovery resumes with."""
        now = time.time() if at is None else at
        payload = json.dumps(profiles_to_obj(data))
        with self._lock:
            self._db.execute(
                "INSERT INTO profiles (id, payload, updated_at) "
                "VALUES (1, ?, ?) ON CONFLICT (id) DO UPDATE SET "
                "payload = excluded.payload, "
                "updated_at = excluded.updated_at", (payload, now))

    def load_profiles(self, cold_start: bool = False
                      ) -> Optional[ProfiledData]:
        """The latest profile snapshot, or None if never checkpointed."""
        with self._lock:
            row = self._db.execute(
                "SELECT payload FROM profiles WHERE id = 1").fetchone()
        if row is None:
            return None
        return profiles_from_obj(json.loads(row[0]), cold_start=cold_start)

    # ------------------------------------------------------------ controls
    def request_control(self, verb: str, job_id: Optional[int] = None,
                        arg: Optional[str] = None,
                        at: Optional[float] = None) -> int:
        """Enqueue an operator verb for the serving process sharing this
        store (the CLI's side of the ops plane)."""
        if verb not in CONTROL_VERBS:
            raise ValueError(f"unknown control verb {verb!r} "
                             f"(known: {list(CONTROL_VERBS)})")
        now = time.time() if at is None else at
        with self._lock:
            cur = self._db.execute(
                "INSERT INTO controls (verb, job_id, arg, created_at) "
                "VALUES (?, ?, ?, ?)", (verb, job_id, arg, now))
            return cur.lastrowid

    def pop_controls(self) -> List[Tuple[str, Optional[int], Optional[str]]]:
        """Consume all pending control requests in submission order."""
        with self._lock:
            rows = self._db.execute(
                "SELECT ctl_id, verb, job_id, arg FROM controls "
                "WHERE consumed = 0 ORDER BY ctl_id").fetchall()
            if rows:
                self._db.execute(
                    "UPDATE controls SET consumed = 1 WHERE ctl_id <= ? "
                    "AND consumed = 0", (rows[-1][0],))
        return [(v, j, a) for _, v, j, a in rows]

    # ---------------------------------------------------------- durability
    def checkpoint(self) -> None:
        """Fold the WAL into the main database file (drain/shutdown)."""
        with self._lock:
            if self.path != ":memory:":
                self._db.execute("PRAGMA wal_checkpoint(TRUNCATE)")


def coerce_store(spec) -> Optional[JobStore]:
    """Normalize an engine's ``jobstore=`` argument: None -> None, a path
    string -> opened file store, a ``JobStore`` -> itself."""
    if spec is None:
        return None
    if isinstance(spec, JobStore):
        return spec
    if isinstance(spec, (str, os.PathLike)):
        return JobStore(os.fspath(spec))
    raise TypeError(f"jobstore= expects None/path/JobStore, got {spec!r}")
