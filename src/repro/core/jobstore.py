"""Durable ops plane: a write-ahead job store for the serving path.

FIKIT's scheduling state (priority queues, holder, gaps, online-learned
SK/SG) lives in process memory; before this module existed a crash lost
every queued request and every learned profile, and an operator had no way
to cancel, pause, or drain a task. ``JobStore`` is the durable record the
engines write THROUGH so a killed process can restart and resume:

- **jobs** — one row per submitted task instance: its ``TaskKey``,
  priority, deadline, total kernel count, an optional serialized kernel
  spec (the simulator's replayable trace; wall-clock payloads are
  callables and re-run from the service definition instead), and a
  lifecycle state (``submitted → running → done`` with ``paused`` /
  ``cancelled`` branches).
- **completions** — one row per finished kernel ``(job, seq)``. This is
  the write-ahead commit point of a kernel boundary: the row is durable
  BEFORE any scheduling side-effect of the completion, so a crash at any
  boundary loses nothing and recovery re-submits exactly the suffix
  ``seq >= watermark``. The primary key makes a duplicated completion a
  structural error (``DuplicateCompletion``), and the contiguity check
  makes a stream-order violation one too (``StreamOrderViolation``) —
  the conservation proof the kill-and-restart sweep rides on.
- **profiles** — the latest snapshot of the (possibly online-refined)
  ``ProfiledData``, in ``repro.core.profile_store`` JSON form including
  EMA counters and interference coefficients, so recovery resumes
  scheduling with the learned SK/SG intact.
- **controls** — a queue of operator verbs (``cancel``/``pause``/
  ``resume``/``drain``) written by the CLI (``repro.launch.serve``) and
  consumed by a live serving process sharing the store file.
- **workers** — one row per registered engine worker process (see
  ``repro.serving.workers``): heartbeat timestamp, lifecycle state, and
  drained-work counters, the raw material of the ``serve workers
  status`` fleet view.

**Leases** (the multi-process serving contract): a worker claims
``submitted`` jobs by atomically stamping ``owner`` + ``lease_expires``
inside one ``BEGIN IMMEDIATE`` transaction (``claim_jobs``), renews the
lease while executing (``renew_leases``, the heartbeat), and any
surviving worker may ``reap_expired`` a lease whose deadline passed —
the job returns to ``submitted`` with its completion watermark intact,
so the next claimant re-runs exactly the remaining kernel suffix (the
same ``spec_from_record`` suffix logic crash recovery uses). The
``completions`` primary key keeps reclamation honest: a duplicated
kernel after a botched reclaim is a structural
``DuplicateCompletion``, not silent double work.

Backends: a file path opens SQLite in WAL mode with per-statement
durability (autocommit); ``JobStore.memory()`` opens ``:memory:`` — same
schema and API, nothing touches disk — for tests and for engines that
want conservation checking without persistence. All methods are
thread-safe (one internal lock; SQLite connection shared); file stores
are additionally safe to share across processes (WAL + SQLite's
busy-wait, which is how N workers drain one queue).

The standing contract: a store attached to an engine only OBSERVES —
recording submissions and completions never changes a scheduling
decision, pinned by randomized store-attached-vs-absent differential
cases in ``tests/test_recovery.py``.

Write-order contract (relied on by every recovery/reclaim path):

1. ``record_submit`` happens BEFORE the submitting clock starts — a
   crash before a late arrival cannot lose the job (submit-ahead);
2. ``record_completion`` is durable BEFORE any scheduling side-effect
   of that kernel boundary (write-ahead) — a crash at boundary ``b``
   leaves exactly ``b + 1`` rows and recovery re-submits the suffix;
3. terminal ``record_state`` (``done``/``cancelled``) comes LAST and
   also releases any lease, so a job can never be simultaneously
   finished and claimable.
"""
from __future__ import annotations

import json
import os
import sqlite3
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.profile_store import (_kid_from_json, _kid_to_json,
                                      profiles_from_obj, profiles_to_obj)
from repro.core.profiler import ProfiledData
from repro.core.task import TaskKey, TaskSpec, TraceKernel

# ---------------------------------------------------------------- lifecycle
#: job lifecycle states
SUBMITTED = "submitted"
RUNNING = "running"
PAUSED = "paused"
CANCELLED = "cancelled"
DONE = "done"
STATES = (SUBMITTED, RUNNING, PAUSED, CANCELLED, DONE)
#: states a job can never leave
TERMINAL_STATES = (CANCELLED, DONE)
#: operator verbs accepted by the control queue
CONTROL_VERBS = ("cancel", "pause", "resume", "drain")

_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
    job_id       INTEGER PRIMARY KEY,
    process      TEXT NOT NULL,
    args         TEXT NOT NULL,
    priority     INTEGER NOT NULL,
    n_kernels    INTEGER NOT NULL,
    deadline     REAL,
    spec         TEXT,
    state        TEXT NOT NULL,
    submitted_at REAL,
    updated_at   REAL,
    qos          TEXT,
    owner        TEXT,
    lease_expires REAL,
    reclaims     INTEGER NOT NULL DEFAULT 0
);
CREATE TABLE IF NOT EXISTS workers (
    worker_id      TEXT PRIMARY KEY,
    state          TEXT NOT NULL,
    started_at     REAL,
    last_heartbeat REAL,
    jobs_done      INTEGER NOT NULL DEFAULT 0,
    kernels_done   INTEGER NOT NULL DEFAULT 0,
    steals         INTEGER NOT NULL DEFAULT 0,
    reaped         INTEGER NOT NULL DEFAULT 0,
    batches        INTEGER NOT NULL DEFAULT 0
);
CREATE TABLE IF NOT EXISTS completions (
    job_id       INTEGER NOT NULL,
    seq          INTEGER NOT NULL,
    completed_at REAL,
    PRIMARY KEY (job_id, seq)
) WITHOUT ROWID;
CREATE TABLE IF NOT EXISTS profiles (
    id         INTEGER PRIMARY KEY CHECK (id = 1),
    payload    TEXT NOT NULL,
    updated_at REAL
);
CREATE TABLE IF NOT EXISTS controls (
    ctl_id     INTEGER PRIMARY KEY AUTOINCREMENT,
    verb       TEXT NOT NULL,
    job_id     INTEGER,
    arg        TEXT,
    consumed   INTEGER NOT NULL DEFAULT 0,
    created_at REAL
);
CREATE TABLE IF NOT EXISTS meta (
    k TEXT PRIMARY KEY,
    v TEXT
);
"""

SCHEMA_VERSION = "1"


class JobStoreError(RuntimeError):
    """Base class for job-store integrity errors."""


class UnknownJob(JobStoreError):
    pass


class DuplicateCompletion(JobStoreError):
    """The same (job, seq) kernel completion was recorded twice — a
    request would have executed twice after recovery."""


class StreamOrderViolation(JobStoreError):
    """A completion arrived out of stream order — kernel ``seq`` finished
    before ``seq - 1`` did, which the serial-device + stream-order
    invariants make impossible unless an engine is broken."""


@dataclass
class JobRecord:
    """One job row, hydrated (``completed`` is the stream watermark: the
    number of contiguously completed kernels).

    ``qos`` is the shard key stamped at submit time (a QoS class or
    service name — see ``repro.serving.workers``); ``owner`` /
    ``lease_expires`` describe the live lease when a worker holds the
    job; ``reclaims`` counts how many times an expired lease was reaped
    (the per-job share of fleet lease churn)."""
    job_id: int
    key: TaskKey
    priority: int
    n_kernels: int
    completed: int
    state: str
    deadline: Optional[float] = None
    spec: Optional[dict] = None
    submitted_at: float = 0.0
    updated_at: float = 0.0
    qos: Optional[str] = None
    owner: Optional[str] = None
    lease_expires: Optional[float] = None
    reclaims: int = 0

    @property
    def remaining(self) -> int:
        """Kernels not yet completed (``n_kernels`` minus watermark)."""
        return self.n_kernels - self.completed

    @property
    def incomplete(self) -> bool:
        """True while the job can still make progress (not terminal)."""
        return self.state not in TERMINAL_STATES


# ------------------------------------------------------- spec serialization
def spec_to_obj(spec: TaskSpec) -> dict:
    """Serialize a simulator ``TaskSpec``'s replayable parts (kernel
    trace, client model). Key/priority/deadline live in job columns."""
    return {
        "kernels": [[_kid_to_json(k.kid), k.duration, k.gap_after, k.kclass]
                    for k in spec.kernels],
        "max_inflight": spec.max_inflight,
        "arrival": spec.arrival,
    }


def spec_from_record(rec: JobRecord) -> TaskSpec:
    """Rebuild the REMAINING TaskSpec for an incomplete job: the kernel
    suffix from the completion watermark on, arriving immediately. The
    caller pairs it with ``rec.completed`` as the seq base so recovered
    completions keep their original stream indices."""
    if rec.spec is None:
        raise JobStoreError(f"job {rec.job_id} has no replayable spec "
                            f"(wall-clock jobs re-run from the service)")
    kernels = [TraceKernel(_kid_from_json(kj), dur, gap, kclass=kc)
               for kj, dur, gap, kc in rec.spec["kernels"]]
    return TaskSpec(rec.key, rec.priority, kernels[rec.completed:],
                    arrival=0.0, max_inflight=rec.spec["max_inflight"],
                    deadline=rec.deadline)


class JobStore:
    """SQLite-backed write-ahead record of jobs, completions, learned
    profiles, and operator control requests. See module docstring."""

    def __init__(self, path: str = ":memory:"):
        self.path = path
        self._lock = threading.RLock()
        # autocommit (isolation_level=None): every INSERT is its own
        # durable transaction — the write-ahead property the recovery
        # sweep depends on
        self._db = sqlite3.connect(path, isolation_level=None,
                                   check_same_thread=False)
        self._db.executescript(_SCHEMA)
        self._migrate()
        if path != ":memory:":
            # WAL keeps concurrent CLI readers (status verb) from
            # blocking the serving process's boundary writes
            self._db.execute("PRAGMA journal_mode=WAL")
            self._db.execute("PRAGMA synchronous=NORMAL")
        self._db.execute(
            "INSERT OR IGNORE INTO meta (k, v) VALUES ('schema', ?)",
            (SCHEMA_VERSION,))

    def _migrate(self) -> None:
        """Bring a store created by an older schema up to date (``CREATE
        TABLE IF NOT EXISTS`` never adds columns to an existing file)."""
        have = {row[1] for row in
                self._db.execute("PRAGMA table_info(jobs)").fetchall()}
        for col, decl in (("qos", "TEXT"), ("owner", "TEXT"),
                          ("lease_expires", "REAL"),
                          ("reclaims", "INTEGER NOT NULL DEFAULT 0")):
            if col not in have:
                self._db.execute(f"ALTER TABLE jobs ADD COLUMN {col} {decl}")

    @classmethod
    def memory(cls) -> "JobStore":
        """In-memory backend: same schema/API, no disk, no durability —
        for tests and conservation-checking without persistence."""
        return cls(":memory:")

    def close(self) -> None:
        """Close the underlying SQLite connection."""
        with self._lock:
            self._db.close()

    def __enter__(self) -> "JobStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- writes
    def record_submit(self, job_id: Optional[int], key: TaskKey,
                      priority: int, *, n_kernels: int,
                      spec: Optional[dict] = None,
                      deadline: Optional[float] = None,
                      state: str = RUNNING,
                      qos: Optional[str] = None,
                      at: Optional[float] = None) -> int:
        """Record a job submission; returns its id. ``job_id=None``
        allocates the next id. An existing row (a recovery re-submission)
        is NOT overwritten — its original spec, kernel count, and
        completions survive; only its state advances to ``state``.
        ``qos`` stamps the shard key worker fleets route claims by
        (``state=SUBMITTED`` puts the job on the claimable queue rather
        than marking it already running)."""
        now = time.time() if at is None else at
        with self._lock:
            if job_id is not None:
                cur = self._db.execute(
                    "SELECT 1 FROM jobs WHERE job_id = ?", (job_id,))
                if cur.fetchone() is not None:
                    self._db.execute(
                        "UPDATE jobs SET state = ?, updated_at = ? "
                        "WHERE job_id = ?", (state, now, job_id))
                    return job_id
            cur = self._db.execute(
                "INSERT INTO jobs (job_id, process, args, priority, "
                "n_kernels, deadline, spec, state, submitted_at, "
                "updated_at, qos) VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?, ?, ?)",
                (job_id, key.process, json.dumps(list(key.args)), priority,
                 n_kernels, deadline,
                 None if spec is None else json.dumps(spec),
                 state, now, now, qos))
            return job_id if job_id is not None else cur.lastrowid

    def record_state(self, job_id: int, state: str,
                     at: Optional[float] = None) -> None:
        """Advance a job's lifecycle state. A terminal state (``done``/
        ``cancelled``) also releases any lease — a finished job can
        never be simultaneously claimable."""
        if state not in STATES:
            raise ValueError(f"unknown job state {state!r} "
                             f"(known: {list(STATES)})")
        now = time.time() if at is None else at
        release = (", owner = NULL, lease_expires = NULL"
                   if state in TERMINAL_STATES else "")
        with self._lock:
            cur = self._db.execute(
                f"UPDATE jobs SET state = ?, updated_at = ?{release} "
                f"WHERE job_id = ?", (state, now, job_id))
            if cur.rowcount == 0:
                raise UnknownJob(f"job {job_id} not in store")

    def record_completion(self, job_id: int, seq: int,
                          at: Optional[float] = None) -> int:
        """Durably record kernel ``seq`` of ``job_id`` as completed; the
        write-ahead commit of a kernel boundary. Enforces stream
        contiguity (``seq`` must be the current watermark) and raises
        ``DuplicateCompletion`` / ``StreamOrderViolation`` otherwise.
        Returns the new watermark."""
        now = time.time() if at is None else at
        with self._lock:
            wm = self._watermark(job_id)
            if seq < wm:
                raise DuplicateCompletion(
                    f"job {job_id} kernel {seq} already recorded "
                    f"(watermark {wm}) — a request would run twice")
            if seq > wm:
                raise StreamOrderViolation(
                    f"job {job_id} kernel {seq} completed before "
                    f"kernel {wm} — stream order broken")
            try:
                self._db.execute(
                    "INSERT INTO completions (job_id, seq, completed_at) "
                    "VALUES (?, ?, ?)", (job_id, seq, now))
            except sqlite3.IntegrityError as e:  # pragma: no cover
                raise DuplicateCompletion(
                    f"job {job_id} kernel {seq} already recorded") from e
            return wm + 1

    def reset_completions(self, job_id: int) -> None:
        """Forget a job's completions (wall-clock recovery re-runs an
        incomplete invocation from scratch — request-level at-least-once;
        the simulator's kernel-exact path never needs this)."""
        with self._lock:
            self._db.execute("DELETE FROM completions WHERE job_id = ?",
                             (job_id,))

    # --------------------------------------------------------------- reads
    def _watermark(self, job_id: int) -> int:
        row = self._db.execute(
            "SELECT MAX(seq) FROM completions WHERE job_id = ?",
            (job_id,)).fetchone()
        return 0 if row[0] is None else row[0] + 1

    def _hydrate(self, row) -> JobRecord:
        (job_id, process, args, priority, n_kernels, deadline, spec,
         state, submitted_at, updated_at, qos, owner, lease_expires,
         reclaims) = row
        return JobRecord(
            job_id=job_id,
            key=TaskKey(process, tuple(json.loads(args))),
            priority=priority, n_kernels=n_kernels,
            completed=self._watermark(job_id), state=state,
            deadline=deadline,
            spec=None if spec is None else json.loads(spec),
            submitted_at=submitted_at or 0.0,
            updated_at=updated_at or 0.0,
            qos=qos, owner=owner, lease_expires=lease_expires,
            reclaims=reclaims or 0)

    _JOB_COLS = ("job_id, process, args, priority, n_kernels, deadline, "
                 "spec, state, submitted_at, updated_at, qos, owner, "
                 "lease_expires, reclaims")

    def _select_jobs(self, ids: Sequence[int]) -> List[JobRecord]:
        if not ids:
            return []
        marks = ",".join("?" * len(ids))
        rows = self._db.execute(
            f"SELECT {self._JOB_COLS} FROM jobs "
            f"WHERE job_id IN ({marks}) ORDER BY priority, job_id",
            tuple(ids)).fetchall()
        return [self._hydrate(r) for r in rows]

    def job(self, job_id: int) -> JobRecord:
        with self._lock:
            row = self._db.execute(
                f"SELECT {self._JOB_COLS} FROM jobs WHERE job_id = ?",
                (job_id,)).fetchone()
            if row is None:
                raise UnknownJob(f"job {job_id} not in store")
            return self._hydrate(row)

    def jobs(self, states: Optional[Sequence[str]] = None
             ) -> List[JobRecord]:
        with self._lock:
            rows = self._db.execute(
                f"SELECT {self._JOB_COLS} FROM jobs "
                f"ORDER BY job_id").fetchall()
            recs = [self._hydrate(r) for r in rows]
        if states is not None:
            recs = [r for r in recs if r.state in states]
        return recs

    def incomplete_jobs(self, include_paused: bool = False
                        ) -> List[JobRecord]:
        """Jobs a restart must resume: not done, not cancelled. Paused
        jobs stay paused across a restart (an explicit ``resume`` verb
        re-admits them) unless ``include_paused``."""
        skip = set(TERMINAL_STATES)
        if not include_paused:
            skip.add(PAUSED)
        return [r for r in self.jobs() if r.state not in skip]

    def completions(self, job_id: int) -> List[int]:
        with self._lock:
            rows = self._db.execute(
                "SELECT seq FROM completions WHERE job_id = ? "
                "ORDER BY seq", (job_id,)).fetchall()
        return [r[0] for r in rows]

    def watermark(self, job_id: int) -> int:
        with self._lock:
            return self._watermark(job_id)

    # ------------------------------------------------------------ recovery
    def recovery_plan(self, include_paused: bool = False
                      ) -> Tuple[List[TaskSpec], List[int], List[int]]:
        """Build the simulator recovery inputs: the remaining ``TaskSpec``
        suffix per incomplete job, the job ids to keep recording under,
        and the per-job seq bases (completion watermarks). Jobs without a
        replayable spec (wall-clock invocations) are skipped — the serving
        layer re-runs those from the service definition."""
        specs, ids, bases = [], [], []
        for rec in self.incomplete_jobs(include_paused=include_paused):
            if rec.spec is None or rec.remaining <= 0:
                continue
            specs.append(spec_from_record(rec))
            ids.append(rec.job_id)
            bases.append(rec.completed)
        return specs, ids, bases

    # -------------------------------------------------------------- leases
    def claim_jobs(self, worker: str, *, limit: int = 1,
                   lease_s: float = 5.0,
                   shards: Optional[Sequence[str]] = None,
                   now: Optional[float] = None) -> List[JobRecord]:
        """Atomically claim up to ``limit`` replayable ``submitted`` jobs
        for ``worker``: stamp ``owner`` + ``lease_expires`` and advance
        them to ``running`` inside one ``BEGIN IMMEDIATE`` transaction,
        so two workers sharing the store file can never claim the same
        job. Selection is strict-priority (then submission order) —
        gold-class work is always claimed before bronze. ``shards``
        restricts the claim to jobs whose ``qos`` shard key is in the
        sequence (None = any shard, the work-stealing fallback).

        A row whose lease is still live is NOT claimable even while its
        state reads ``submitted`` — the owning worker's simulator
        write-ahead parks claimed jobs in ``submitted`` until their
        arrival event fires, and only lease expiry (not that transient)
        may hand work to another worker.

        Returns the claimed rows, hydrated; an empty list when nothing
        matched."""
        if limit < 1:
            raise ValueError(f"claim limit must be >= 1, got {limit}")
        t = time.time() if now is None else now
        where = ("state = ? AND spec IS NOT NULL "
                 "AND (owner IS NULL OR lease_expires < ?)")
        params: list = [SUBMITTED, t]
        if shards is not None:
            shards = list(shards)
            if not shards:
                return []
            where += (" AND qos IN ("
                      + ",".join("?" * len(shards)) + ")")
            params += shards
        with self._lock:
            self._db.execute("BEGIN IMMEDIATE")
            try:
                rows = self._db.execute(
                    f"SELECT {self._JOB_COLS} FROM jobs WHERE {where} "
                    f"ORDER BY priority, job_id LIMIT ?",
                    (*params, limit)).fetchall()
                ids = [r[0] for r in rows]
                if ids:
                    # claiming over a stale owner IS a reclaim (the
                    # crash-before-arrival window leaves rows submitted
                    # with an expired lease; no reap pass sees them)
                    marks = ",".join("?" * len(ids))
                    self._db.execute(
                        f"UPDATE jobs SET reclaims = reclaims + (CASE "
                        f"WHEN owner IS NOT NULL AND owner != ? THEN 1 "
                        f"ELSE 0 END), owner = ?, lease_expires = ?, "
                        f"state = ?, updated_at = ? "
                        f"WHERE job_id IN ({marks})",
                        (worker, worker, t + lease_s, RUNNING, t, *ids))
                self._db.execute("COMMIT")
            except BaseException:
                self._db.execute("ROLLBACK")
                raise
            return self._select_jobs(ids)

    def renew_leases(self, worker: str, lease_s: float = 5.0,
                     now: Optional[float] = None) -> int:
        """Heartbeat: extend every lease ``worker`` currently holds (and
        refresh its worker-table heartbeat). Returns how many leases
        were renewed — 0 tells a worker its leases were reaped out from
        under it (it should stop writing and re-claim)."""
        t = time.time() if now is None else now
        with self._lock:
            cur = self._db.execute(
                "UPDATE jobs SET lease_expires = ? "
                "WHERE owner = ? AND state = ?",
                (t + lease_s, worker, RUNNING))
            self._db.execute(
                "UPDATE workers SET last_heartbeat = ? WHERE worker_id = ?",
                (t, worker))
            return cur.rowcount

    def reap_expired(self, by: Optional[str] = None,
                     now: Optional[float] = None) -> List[JobRecord]:
        """Reclaim every job whose lease expired: back to ``submitted``
        with the lease cleared and ``reclaims`` bumped, so a surviving
        worker's next ``claim_jobs`` re-runs exactly the remaining
        kernel suffix (completions — the watermark — are untouched).
        ``by`` credits the reap to a worker's fleet-status counters.
        Returns the reclaimed rows (post-reap state)."""
        t = time.time() if now is None else now
        with self._lock:
            self._db.execute("BEGIN IMMEDIATE")
            try:
                rows = self._db.execute(
                    f"SELECT {self._JOB_COLS} FROM jobs "
                    f"WHERE state = ? AND owner IS NOT NULL "
                    f"AND lease_expires < ?", (RUNNING, t)).fetchall()
                ids = [r[0] for r in rows]
                if ids:
                    marks = ",".join("?" * len(ids))
                    self._db.execute(
                        f"UPDATE jobs SET state = ?, owner = NULL, "
                        f"lease_expires = NULL, reclaims = reclaims + 1, "
                        f"updated_at = ? WHERE job_id IN ({marks})",
                        (SUBMITTED, t, *ids))
                    if by is not None:
                        self._db.execute(
                            "UPDATE workers SET reaped = reaped + ? "
                            "WHERE worker_id = ?", (len(ids), by))
                self._db.execute("COMMIT")
            except BaseException:
                self._db.execute("ROLLBACK")
                raise
            return self._select_jobs(ids)

    def pending_jobs(self, shards: Optional[Sequence[str]] = None,
                     now: Optional[float] = None) -> int:
        """How many replayable jobs are claimable right now (``submitted``
        state, no live lease), optionally restricted to ``shards`` — the
        backpressure probe and the drain-on-empty check. Matches the
        ``claim_jobs`` predicate exactly."""
        t = time.time() if now is None else now
        where = ("state = ? AND spec IS NOT NULL "
                 "AND (owner IS NULL OR lease_expires < ?)")
        params: list = [SUBMITTED, t]
        if shards is not None:
            shards = list(shards)
            if not shards:
                return 0
            where += " AND qos IN (" + ",".join("?" * len(shards)) + ")"
            params += shards
        with self._lock:
            row = self._db.execute(
                f"SELECT COUNT(*) FROM jobs WHERE {where}",
                params).fetchone()
        return row[0]

    def leased_jobs(self) -> int:
        """How many non-terminal jobs are currently held under a worker
        lease (live or expired — an expired lease still means a reap or
        re-claim is owed, so a draining sibling must not exit yet)."""
        with self._lock:
            row = self._db.execute(
                "SELECT COUNT(*) FROM jobs WHERE owner IS NOT NULL "
                "AND state NOT IN (?, ?)", TERMINAL_STATES).fetchone()
        return row[0]

    def lease_churn(self) -> int:
        """Total lease reclaims across all jobs (fleet churn metric)."""
        with self._lock:
            row = self._db.execute(
                "SELECT COALESCE(SUM(reclaims), 0) FROM jobs").fetchone()
        return row[0]

    def shards(self) -> List[str]:
        """Distinct shard keys stamped on stored jobs (sorted), for a
        supervisor partitioning shards across workers."""
        with self._lock:
            rows = self._db.execute(
                "SELECT DISTINCT qos FROM jobs "
                "WHERE qos IS NOT NULL ORDER BY qos").fetchall()
        return [r[0] for r in rows]

    # ------------------------------------------------------------- workers
    def register_worker(self, worker: str, state: str = "running",
                        now: Optional[float] = None) -> None:
        """Create (or reset) a worker's fleet-status row."""
        t = time.time() if now is None else now
        with self._lock:
            self._db.execute(
                "INSERT INTO workers (worker_id, state, started_at, "
                "last_heartbeat) VALUES (?, ?, ?, ?) "
                "ON CONFLICT (worker_id) DO UPDATE SET state = excluded."
                "state, started_at = excluded.started_at, last_heartbeat "
                "= excluded.last_heartbeat, jobs_done = 0, kernels_done "
                "= 0, steals = 0, reaped = 0, batches = 0",
                (worker, state, t, t))

    def worker_update(self, worker: str, state: Optional[str] = None,
                      jobs_done: int = 0, kernels_done: int = 0,
                      steals: int = 0, batches: int = 0,
                      now: Optional[float] = None) -> None:
        """Accumulate a worker's drained-work counters (deltas) and
        optionally advance its lifecycle state."""
        t = time.time() if now is None else now
        with self._lock:
            self._db.execute(
                "UPDATE workers SET jobs_done = jobs_done + ?, "
                "kernels_done = kernels_done + ?, steals = steals + ?, "
                "batches = batches + ?, last_heartbeat = ? "
                "WHERE worker_id = ?",
                (jobs_done, kernels_done, steals, batches, t, worker))
            if state is not None:
                self._db.execute(
                    "UPDATE workers SET state = ? WHERE worker_id = ?",
                    (state, worker))

    def workers(self) -> List[dict]:
        """All registered workers' fleet-status rows, as dicts."""
        cols = ("worker_id", "state", "started_at", "last_heartbeat",
                "jobs_done", "kernels_done", "steals", "reaped", "batches")
        with self._lock:
            rows = self._db.execute(
                f"SELECT {', '.join(cols)} FROM workers "
                f"ORDER BY worker_id").fetchall()
        return [dict(zip(cols, r)) for r in rows]

    # --------------------------------------------------------------- flags
    def set_flag(self, key: str, value: str) -> None:
        """Set a cross-process coordination flag (e.g. the supervisor's
        ``workers_go`` start gate or the ``workers_stop`` drain signal).
        Flags live in the meta table under a ``flag:`` namespace."""
        with self._lock:
            self._db.execute(
                "INSERT INTO meta (k, v) VALUES (?, ?) "
                "ON CONFLICT (k) DO UPDATE SET v = excluded.v",
                (f"flag:{key}", value))

    def flag(self, key: str) -> Optional[str]:
        """Read a coordination flag; None when never set/cleared."""
        with self._lock:
            row = self._db.execute(
                "SELECT v FROM meta WHERE k = ?",
                (f"flag:{key}",)).fetchone()
        return None if row is None else row[0]

    def clear_flag(self, key: str) -> None:
        """Delete a coordination flag."""
        with self._lock:
            self._db.execute("DELETE FROM meta WHERE k = ?",
                             (f"flag:{key}",))

    # ------------------------------------------------------------ profiles
    def snapshot_profiles(self, data: ProfiledData,
                          at: Optional[float] = None) -> None:
        """Checkpoint the (possibly online-refined) profile state. One
        snapshot row, overwritten — the store keeps the LATEST learned
        SK/SG, which is what recovery resumes with."""
        now = time.time() if at is None else at
        payload = json.dumps(profiles_to_obj(data))
        with self._lock:
            self._db.execute(
                "INSERT INTO profiles (id, payload, updated_at) "
                "VALUES (1, ?, ?) ON CONFLICT (id) DO UPDATE SET "
                "payload = excluded.payload, "
                "updated_at = excluded.updated_at", (payload, now))

    def load_profiles(self, cold_start: bool = False
                      ) -> Optional[ProfiledData]:
        """The latest profile snapshot, or None if never checkpointed."""
        with self._lock:
            row = self._db.execute(
                "SELECT payload FROM profiles WHERE id = 1").fetchone()
        if row is None:
            return None
        return profiles_from_obj(json.loads(row[0]), cold_start=cold_start)

    # ------------------------------------------------------------ controls
    def request_control(self, verb: str, job_id: Optional[int] = None,
                        arg: Optional[str] = None,
                        at: Optional[float] = None) -> int:
        """Enqueue an operator verb for the serving process sharing this
        store (the CLI's side of the ops plane)."""
        if verb not in CONTROL_VERBS:
            raise ValueError(f"unknown control verb {verb!r} "
                             f"(known: {list(CONTROL_VERBS)})")
        now = time.time() if at is None else at
        with self._lock:
            cur = self._db.execute(
                "INSERT INTO controls (verb, job_id, arg, created_at) "
                "VALUES (?, ?, ?, ?)", (verb, job_id, arg, now))
            return cur.lastrowid

    def pop_controls(self) -> List[Tuple[str, Optional[int], Optional[str]]]:
        """Consume all pending control requests in submission order."""
        with self._lock:
            rows = self._db.execute(
                "SELECT ctl_id, verb, job_id, arg FROM controls "
                "WHERE consumed = 0 ORDER BY ctl_id").fetchall()
            if rows:
                self._db.execute(
                    "UPDATE controls SET consumed = 1 WHERE ctl_id <= ? "
                    "AND consumed = 0", (rows[-1][0],))
        return [(v, j, a) for _, v, j, a in rows]

    # ---------------------------------------------------------- durability
    def checkpoint(self) -> None:
        """Fold the WAL into the main database file (drain/shutdown)."""
        with self._lock:
            if self.path != ":memory:":
                self._db.execute("PRAGMA wal_checkpoint(TRUNCATE)")


def coerce_store(spec) -> Optional[JobStore]:
    """Normalize an engine's ``jobstore=`` argument: None -> None, a path
    string -> opened file store, a ``JobStore`` — or any object exposing
    the store write interface, like a worker's pacing proxy -> itself."""
    if spec is None:
        return None
    if isinstance(spec, JobStore):
        return spec
    if isinstance(spec, (str, os.PathLike)):
        return JobStore(os.fspath(spec))
    if (hasattr(spec, "record_submit")
            and hasattr(spec, "record_completion")):
        return spec
    raise TypeError(f"jobstore= expects None/path/JobStore, got {spec!r}")
