"""Config system: model architecture configs, input shapes, registry.

Every assigned architecture is expressed as a ``ModelConfig``; reduced
variants (for CPU smoke tests and FIKIT policy benchmarks) are derived via
``ModelConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"
VLM = "vlm"

FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Defaults suit a dense decoder LM."""

    name: str
    family: str = DENSE
    source: str = ""                 # citation: paper / model card

    # Transformer backbone
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    norm_eps: float = 1e-5
    qk_norm: bool = False            # per-head RMSNorm on q/k (qwen3)
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # fraction of head_dim rotated (stablelm: 0.25)
    tie_embeddings: bool = False

    # Attention variants
    sliding_window: Optional[int] = None     # SWA (mistral/danube)
    attention_chunk: Optional[int] = None    # chunked local attention (llama4 iRoPE)
    chunk_pattern: int = 0                   # every Nth layer is full attention (llama4: 4)

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden; 0 -> d_ff
    capacity_factor: float = 1.25

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0              # 0 -> head_dim

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # Hybrid (recurrentgemma / griffin)
    block_pattern: Tuple[str, ...] = ()      # e.g. ("rec", "rec", "attn")
    lru_width: int = 0               # 0 -> d_model
    local_window: int = 0            # local attention window for "attn" blocks

    # Encoder-decoder (seamless-m4t)
    num_encoder_layers: int = 0
    num_decoder_layers: int = 0
    encoder_frames: int = 1024       # stub audio frontend: frames fed to encoder

    # VLM (llava-next): stub vision frontend supplies patch embeddings
    num_patches: int = 0             # anyres patch count prepended to text

    # numerics
    dtype: str = "bfloat16"          # params/activations
    remat: bool = True               # activation checkpointing for train

    # ----------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim or self.resolved_head_dim

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def subquadratic(self) -> bool:
        """True if decode state is O(1) or O(window) -> long_500k runs."""
        if self.family == SSM:
            return True
        if self.family == HYBRID:
            return True
        if self.sliding_window is not None or self.attention_chunk is not None:
            return True
        return False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=4,
            num_kv_heads=min(max(self.num_kv_heads, 1), 4) if self.num_kv_heads > 1 else 1,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            remat=False,
        )
        if self.family == MOE:
            kw.update(
                num_experts=min(self.num_experts, 4),
                num_shared_experts=min(self.num_shared_experts, 1),
                top_k=min(self.top_k, 2),
                moe_d_ff=min(self.resolved_moe_d_ff, 256),
            )
        if self.use_mla:
            kw.update(kv_lora_rank=64, q_lora_rank=0, rope_head_dim=32,
                      head_dim=64, v_head_dim=64)
        if self.family == SSM:
            kw.update(ssm_state=32, ssm_headdim=32, ssm_chunk=32)
        if self.family == HYBRID:
            kw.update(block_pattern=("rec", "attn"), lru_width=256,
                      local_window=min(self.local_window or 128, 128),
                      num_layers=2)
        if self.family == ENCDEC:
            kw.update(num_encoder_layers=2, num_decoder_layers=2,
                      encoder_frames=32)
        if self.family == VLM:
            kw.update(num_patches=16)
        if self.sliding_window is not None:
            kw.update(sliding_window=min(self.sliding_window, 64))
        if self.attention_chunk is not None:
            kw.update(attention_chunk=min(self.attention_chunk, 64))
        if self.chunk_pattern:
            kw.update(chunk_pattern=2)   # 2 layers: (chunked, full)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry (populated by repro.configs)
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")


def list_configs() -> list:
    if not _REGISTRY:
        import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
