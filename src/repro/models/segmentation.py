"""Segmentation: split a model's forward pass into dispatchable "kernels"
(program segments) for the FIKIT scheduler.

A service's inference = [embed] + [layer]*L + [head]. The layer segment is
ONE jitted program reused for every layer (layer params are an argument), so
all L dispatches share a KernelID — exactly the paper's observation that a
task repeatedly calls kernels with the same ID (Fig 5), and the reason SK
averaging + runtime feedback exist.

Host work (tokenize / sample / detokenize) runs client-side between
segments — the genuine origin of inter-kernel device idle gaps.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import DENSE, ENCDEC, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.core.client import Segment
from repro.models import mamba2, moe, rglru, transformer as tfm, vlm as vlm_m
from repro.models.layers import rms_norm


def _sync(x):
    jax.block_until_ready(x)
    return x


def _sleep_work(seconds: float) -> Optional[Callable]:
    if seconds <= 0:
        return None

    def work(state):
        time.sleep(seconds)
        return state
    return work


class SegmentedService:
    """A reduced-scale model packaged as FIKIT-schedulable segments.

    host_gap: host think-time injected after each layer segment (models
    the CPU-side work real serving stacks do between dispatches).
    """

    def __init__(self, cfg: ModelConfig, params, batch: int, seq: int,
                 host_gap: float = 0.0, tail_gap: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.seq = seq
        self.host_gap = host_gap
        self.tail_gap = tail_gap
        self._build()

    # ------------------------------------------------------------- builders
    def _build(self):
        cfg = self.cfg
        if cfg.family in (DENSE, VLM, MOE, SSM):
            self._build_decoder_lm()
        elif cfg.family == HYBRID:
            self._build_hybrid()
        elif cfg.family == ENCDEC:
            self._build_encdec()
        else:  # pragma: no cover
            raise ValueError(cfg.family)

    def _positions(self, S):
        return jnp.arange(S, dtype=jnp.int32)

    def _build_decoder_lm(self):
        cfg, params = self.cfg, self.params

        @jax.jit
        def embed(tokens):
            if cfg.family == VLM:
                patches = vlm_m.stub_patches(cfg, tokens.shape[0])
                return _sync(tfm.embed_tokens(params, tokens, cfg, patches))
            return _sync(tfm.embed_tokens(params, tokens, cfg))

        if cfg.family == MOE:
            def _layer(lp, x, i):
                pat = cfg.chunk_pattern or 1
                is_full = bool(cfg.chunk_pattern) and (i + 1) % pat == 0
                window, chunk = ((cfg.sliding_window, None) if is_full
                                 else (cfg.sliding_window,
                                       cfg.attention_chunk))
                y, _aux = moe.layer_apply(lp, x, self._positions(x.shape[1]),
                                          cfg, window=window, chunk=chunk)
                return y
            layer = jax.jit(_layer, static_argnums=(2,))
        elif cfg.family == SSM:
            layer = jax.jit(lambda lp, x, i: mamba2.layer_apply(lp, x, cfg),
                            static_argnums=(2,))
        else:
            def _layer(lp, x, i):
                return tfm.layer_apply(lp, x, self._positions(x.shape[1]),
                                       cfg, window=cfg.sliding_window,
                                       chunk=cfg.attention_chunk)
            layer = jax.jit(_layer, static_argnums=(2,))

        @jax.jit
        def head(x):
            return _sync(tfm.unembed(params, x, cfg))

        L = cfg.num_layers
        lps = [jax.tree.map(lambda a, i=i: a[i], params["layers"])
               for i in range(L)]
        segs = [Segment(f"{cfg.name}/embed", lambda t: embed(t))]
        for i in range(L):
            segs.append(Segment(
                f"{cfg.name}/layer",
                partial(self._run_layer, layer, lps[i], i),
                host_work=_sleep_work(self.host_gap)))
        segs.append(Segment(f"{cfg.name}/head", lambda x: head(x),
                            host_work=self._sample_work()))
        self.segments = segs

    @staticmethod
    def _run_layer(layer, lp, i, x):
        return _sync(layer(lp, x, i))

    def _build_hybrid(self):
        cfg, params = self.cfg, self.params
        kinds = rglru.block_kinds(cfg)

        @jax.jit
        def embed(tokens):
            return _sync(tfm.embed_tokens(params, tokens, cfg))

        def rec_block(lp, x):
            x = rglru._rec_apply(lp, x, cfg)
            return rglru._mlp_res(lp, x, cfg)

        def attn_block(lp, x):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            x = x + tfm.attn_apply_full(lp["attn"], h,
                                        self._positions(x.shape[1]), cfg,
                                        window=cfg.local_window)
            return rglru._mlp_res(lp, x, cfg)

        rec_j, attn_j = jax.jit(rec_block), jax.jit(attn_block)

        @jax.jit
        def head(x):
            return _sync(tfm.unembed(params, x, cfg))

        segs = [Segment(f"{cfg.name}/embed", lambda t: embed(t))]
        for lp, kind in zip(params["blocks"], kinds):
            fn = rec_j if kind == "rec" else attn_j
            segs.append(Segment(
                f"{cfg.name}/{kind}",
                partial(lambda f, p, x: _sync(f(p, x)), fn, lp),
                host_work=_sleep_work(self.host_gap)))
        segs.append(Segment(f"{cfg.name}/head", lambda x: head(x),
                            host_work=self._sample_work()))
        self.segments = segs

    def _build_encdec(self):
        cfg, params = self.cfg, self.params

        @jax.jit
        def encode(batch):
            frames, tokens = batch
            return _sync((encdec_encode(params, frames, cfg),
                          tfm.embed_tokens(params, tokens, cfg)))

        from repro.models import encdec as ed
        encdec_encode = ed.encode

        def dec_layer(lp, state):
            enc_out, x = state
            x = ed._dec_layer(lp, x, self._positions(x.shape[1]), enc_out,
                              cfg)
            return (enc_out, x)
        dec_j = jax.jit(dec_layer)

        @jax.jit
        def head(state):
            _, x = state
            return _sync(tfm.unembed(params, x, cfg))

        Ld = cfg.num_decoder_layers or cfg.num_layers
        lps = [jax.tree.map(lambda a, i=i: a[i], params["dec_layers"])
               for i in range(Ld)]
        segs = [Segment(f"{cfg.name}/encode", lambda b: encode(b))]
        for i in range(Ld):
            segs.append(Segment(
                f"{cfg.name}/dec_layer",
                partial(lambda p, s: _sync(dec_j(p, s)), lps[i]),
                host_work=_sleep_work(self.host_gap)))
        segs.append(Segment(f"{cfg.name}/head", lambda s: head(s),
                            host_work=self._sample_work()))
        self.segments = segs

    # -------------------------------------------------------------- helpers
    def _sample_work(self):
        tail = self.tail_gap

        def work(logits):
            # host-side sampling: argmax -> python ints (detokenize analog)
            import numpy as np
            toks = np.asarray(jax.device_get(jnp.argmax(logits[..., :64],
                                                        axis=-1)))
            if tail > 0:
                time.sleep(tail)
            return toks
        return work

    def make_input(self, key=None):
        from repro.models import api
        return api.make_batch(self.cfg, self.batch, self.seq, key)

    def warmup(self):
        """Compile all segment programs once (outside any measurement)."""
        state = self.make_input()
        for seg in self.segments:
            state = seg.fn(state)
            if seg.host_work is not None and seg is self.segments[-1]:
                pass
        return True
