"""Encoder-decoder backbone (seamless-m4t-medium, arXiv:2308.11596).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB
per the assignment: the encoder consumes precomputed frame embeddings
[B, S_frames, D] provided by ``input_specs()``. This module implements the
transformer backbone: bidirectional encoder + causal decoder with
cross-attention.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.layers import Maker, mlp_apply, mlp_build, rms_norm


class DecCache(NamedTuple):
    self_kv: attn.KVCache
    cross_k: jax.Array       # [B, Sf, Kh, Dh] static after prefill
    cross_v: jax.Array


def _enc_layer_build(make: Maker, cfg: ModelConfig, stack=()):
    D = cfg.d_model
    s = tuple(stack)
    return {
        "ln1": make("enc_ln1", s + (D,), "zeros"),
        "attn": tfm.attn_build(make, cfg, stack=s, prefix="enc_"),
        "ln2": make("enc_ln2", s + (D,), "zeros"),
        "mlp": mlp_build(make, D, cfg.d_ff, prefix="enc_", stack=s),
    }


def _dec_layer_build(make: Maker, cfg: ModelConfig, stack=()):
    D = cfg.d_model
    s = tuple(stack)
    return {
        "ln1": make("dec_ln1", s + (D,), "zeros"),
        "attn": tfm.attn_build(make, cfg, stack=s, prefix="dec_"),
        "lnx": make("dec_lnx", s + (D,), "zeros"),
        "xattn": tfm.attn_build(make, cfg, stack=s, prefix="dec_x_"),
        "ln2": make("dec_ln2", s + (D,), "zeros"),
        "mlp": mlp_build(make, D, cfg.d_ff, prefix="dec_", stack=s),
    }


def build_params(cfg: ModelConfig, key=None):
    make = Maker(key, cfg.dtype)
    Le = cfg.num_encoder_layers or cfg.num_layers
    Ld = cfg.num_decoder_layers or cfg.num_layers
    p = {
        "embed": make("embed", (cfg.vocab_size, cfg.d_model), "embed"),
        "enc_in": make("enc_in", (cfg.d_model, cfg.d_model)),
        "enc_layers": _enc_layer_build(make, cfg, stack=(Le,)),
        "enc_norm": make("enc_norm", (cfg.d_model,), "zeros"),
        "dec_layers": _dec_layer_build(make, cfg, stack=(Ld,)),
        "final_norm": make("final_norm", (cfg.d_model,), "zeros"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = make("lm_head", (cfg.d_model, cfg.vocab_size))
    return p


def encode(params, frames, cfg: ModelConfig):
    """frames: [B, Sf, D] stub embeddings -> encoder output [B, Sf, D]."""
    x = jnp.einsum("bsd,de->bse", frames.astype(jnp.dtype(cfg.dtype)),
                   params["enc_in"])
    Sf = x.shape[1]
    positions = jnp.arange(Sf, dtype=jnp.int32)

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        carry = carry + tfm.attn_apply_full(lp["attn"], h, positions, cfg,
                                            causal=False)
        h = rms_norm(carry, lp["ln2"], cfg.norm_eps)
        return carry + mlp_apply(lp["mlp"], h), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, lp["xattn"]["k_norm"], cfg.norm_eps)
    return k, v


def _dec_layer(lp, x, positions, enc_out, cfg: ModelConfig):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + tfm.attn_apply_full(lp["attn"], h, positions, cfg)
    h = rms_norm(x, lp["lnx"], cfg.norm_eps)
    k, v = _cross_kv(lp, enc_out, cfg)
    kpos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    x = x + tfm.attn_apply_full(lp["xattn"], h, positions, cfg,
                                causal=False, kv=(k, v, kpos))
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h)


def forward(params, batch, cfg: ModelConfig):
    """batch: (frames [B,Sf,D], tokens [B,St]) -> logits [B,St,V]."""
    frames, tokens = batch
    enc_out = encode(params, frames, cfg)
    x = tfm.embed_tokens(params, tokens, cfg)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)

    def body(carry, lp):
        return _dec_layer(lp, carry, positions, enc_out, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return tfm.unembed(params, x, cfg)


def prefill(params, batch, cfg: ModelConfig, extra_capacity: int = 0):
    frames, tokens = batch
    enc_out = encode(params, frames, cfg)
    x = tfm.embed_tokens(params, tokens, cfg)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)
    capacity = St + extra_capacity

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        y, self_cache = tfm.attn_prefill(lp["attn"], h, positions, cfg,
                                         capacity)
        x = carry + y
        h = rms_norm(x, lp["lnx"], cfg.norm_eps)
        k, v = _cross_kv(lp, enc_out, cfg)
        kpos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        x = x + tfm.attn_apply_full(lp["xattn"], h, positions, cfg,
                                    causal=False, kv=(k, v, kpos))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h)
        return x, DecCache(self_cache, k, v)

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    return tfm.unembed(params, x[:, -1:, :], cfg), caches


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    x = tfm.embed_tokens(params, token, cfg)

    def body(carry, xs):
        lp, cache = xs
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        y, self_cache = tfm.attn_apply_decode(lp["attn"], h, cache.self_kv,
                                              pos, cfg)
        x = carry + y
        h = rms_norm(x, lp["lnx"], cfg.norm_eps)
        positions = jnp.asarray(pos, jnp.int32)[None]
        kpos = jnp.arange(cache.cross_k.shape[1], dtype=jnp.int32)
        x = x + tfm.attn_apply_full(lp["xattn"], h, positions, cfg,
                                    causal=False,
                                    kv=(cache.cross_k, cache.cross_v, kpos))
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h)
        return x, DecCache(self_cache, cache.cross_k, cache.cross_v)

    x, caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    return tfm.unembed(params, x, cfg), caches


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int):
    dt = jnp.dtype(cfg.dtype)
    Ld = cfg.num_decoder_layers or cfg.num_layers
    Sf = cfg.encoder_frames
    one = DecCache(
        self_kv=attn.init_kv_cache(batch, seq_len, cfg.num_kv_heads,
                                   cfg.resolved_head_dim, dt),
        cross_k=jnp.zeros((batch, Sf, cfg.num_kv_heads,
                           cfg.resolved_head_dim), dt),
        cross_v=jnp.zeros((batch, Sf, cfg.num_kv_heads,
                           cfg.resolved_head_dim), dt),
    )
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (Ld,) + a.shape), one)
