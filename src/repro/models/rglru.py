"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks interleaved with local (sliding-window) attention blocks, pattern
(rec, rec, attn) repeating. Decode state is O(lru_width) + O(window), so
this arch runs ``long_500k``.

The RG-LRU diagonal linear recurrence h_t = a_t * h_{t-1} + b_t is computed
with ``jax.lax.associative_scan`` (log-depth) for full sequences and as a
single fused update for decode. The Pallas kernel in
``repro.kernels.rglru_scan`` is the TPU fast path for the same recurrence.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.layers import Maker, mlp_apply, mlp_build, rms_norm

C_SCALE = 8.0  # RG-LRU "c" constant

# H3 (EXPERIMENTS.md §Perf): the r/i gate matmuls contract over the
# model-sharded width dim against replicated-row [W,W] weights, which GSPMD
# resolves with an fp32 psum of [B,S,W] per gate per layer. Gathering the
# (bf16, 2x smaller) activations once instead and computing gates with
# output-sharded columns removes those all-reduces.
import os as _os
GATE_GATHER = _os.environ.get("REPRO_GATE_GATHER", "0") == "1"


class RecCache(NamedTuple):
    h: jax.Array         # [B, W] fp32 recurrent state
    conv: jax.Array      # [B, K-1, W] conv history


def block_kinds(cfg: ModelConfig):
    """Static per-layer kind list, e.g. 38 layers of (rec, rec, attn)."""
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _rec_build(make: Maker, cfg: ModelConfig, i: int):
    D, W = cfg.d_model, cfg.resolved_lru_width
    K = cfg.ssm_conv or 4
    pre = f"b{i}_"
    return {
        "ln": make(pre + "ln", (D,), "zeros"),
        "w_y": make(pre + "w_y", (D, W)),
        "w_gate": make(pre + "w_gate", (D, W)),
        "conv": make(pre + "conv", (K, W), scale=0.5),
        "w_r": make(pre + "w_r", (W, W), scale=0.5),
        "w_i": make(pre + "w_i", (W, W), scale=0.5),
        "lam": make(pre + "lam", (W,), "ones"),
        "w_out": make(pre + "w_out", (W, D)),
        "ln2": make(pre + "ln2", (D,), "zeros"),
        "mlp": mlp_build(make, D, cfg.d_ff, prefix=pre),
    }


def _attn_build(make: Maker, cfg: ModelConfig, i: int):
    D = cfg.d_model
    pre = f"b{i}_"
    # attn_build uses fixed param names; wrap with per-layer prefix via a
    # shim Maker.
    class _Pre:
        def __call__(self, name, shape, kind="dense", scale=1.0):
            return make(pre + name, shape, kind, scale)
    return {
        "ln1": make(pre + "ln1", (D,), "zeros"),
        "attn": tfm.attn_build(_Pre(), cfg),
        "ln2": make(pre + "ln2", (D,), "zeros"),
        "mlp": mlp_build(make, D, cfg.d_ff, prefix=pre),
    }


def build_params(cfg: ModelConfig, key=None):
    make = Maker(key, cfg.dtype)
    blocks = []
    for i, kind in enumerate(block_kinds(cfg)):
        blocks.append(_rec_build(make, cfg, i) if kind == "rec"
                      else _attn_build(make, cfg, i))
    p = {
        "embed": make("embed", (cfg.vocab_size, cfg.d_model), "embed"),
        "blocks": blocks,
        "final_norm": make("final_norm", (cfg.d_model,), "zeros"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = make("lm_head", (cfg.d_model, cfg.vocab_size))
    return p


# ---------------------------------------------------------------------------
# RG-LRU recurrence
# ---------------------------------------------------------------------------
def _rglru_gates(lp, y, cfg: ModelConfig):
    """y: [B,S,W] post-conv. Returns (a [B,S,W] fp32, gated input fp32)."""
    y_in = y
    if GATE_GATHER:
        from repro.sharding.context import constrain
        y_in = constrain(y, "batch", None, None)   # gather W once (bf16)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", y_in, lp["w_r"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", y_in, lp["w_i"])
                       .astype(jnp.float32))
    log_a = -C_SCALE * r * jax.nn.softplus(lp["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * y.astype(jnp.float32))
    return a, gated


def rglru_scan_full(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a/b: [B,S,W] fp32."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    del aa
    return hh


def _rec_apply(lp, x, cfg: ModelConfig, cache: RecCache = None,
               return_cache: bool = False):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, lp["w_gate"])
                       .astype(jnp.float32)).astype(h.dtype)
    y = jnp.einsum("bsd,dw->bsw", h, lp["w_y"])
    from repro.models.mamba2 import _causal_conv
    y, buf = _causal_conv(y, lp["conv"], None if cache is None else cache.conv)
    a, b = _rglru_gates(lp, y, cfg)
    hs = rglru_scan_full(a, b, None if cache is None else cache.h)
    out = (hs.astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", out, lp["w_out"])
    x = x + out
    if return_cache:
        return x, RecCache(hs[:, -1], buf)
    return x


def _rec_decode(lp, x, cache: RecCache, cfg: ModelConfig):
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", h, lp["w_gate"])
                       .astype(jnp.float32)).astype(h.dtype)
    y = jnp.einsum("bsd,dw->bsw", h, lp["w_y"])
    from repro.models.mamba2 import _causal_conv
    y, buf = _causal_conv(y, lp["conv"], cache.conv)
    a, b = _rglru_gates(lp, y, cfg)
    h_new = a[:, 0] * cache.h + b[:, 0]                    # [B,W]
    out = (h_new[:, None].astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", out, lp["w_out"])
    return x + out, RecCache(h_new, buf)


def _mlp_res(lp, x, cfg):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h)


# ---------------------------------------------------------------------------
# Model (python loop over heterogeneous blocks)
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg: ModelConfig, extra_embeds=None):
    x = tfm.embed_tokens(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    kinds = block_kinds(cfg)

    for lp, kind in zip(params["blocks"], kinds):
        def blockfn(x, lp=lp, kind=kind):
            if kind == "rec":
                x = _rec_apply(lp, x, cfg)
            else:
                h = rms_norm(x, lp["ln1"], cfg.norm_eps)
                x = x + tfm.attn_apply_full(lp["attn"], h, positions, cfg,
                                            window=cfg.local_window)
            return _mlp_res(lp, x, cfg)
        x = jax.checkpoint(blockfn)(x) if cfg.remat else blockfn(x)
    return tfm.unembed(params, x, cfg)


def prefill(params, tokens, cfg: ModelConfig, extra_embeds=None,
            extra_capacity: int = 0):
    x = tfm.embed_tokens(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    capacity = min(S + extra_capacity, cfg.local_window or S)
    kinds = block_kinds(cfg)
    caches = []
    for lp, kind in zip(params["blocks"], kinds):
        if kind == "rec":
            x, cache = _rec_apply(lp, x, cfg, return_cache=True)
        else:
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, cache = tfm.attn_prefill(lp["attn"], h, positions, cfg,
                                        capacity, window=cfg.local_window)
            x = x + y
        x = _mlp_res(lp, x, cfg)
        caches.append(cache)
    return tfm.unembed(params, x[:, -1:, :], cfg), caches


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    x = tfm.embed_tokens(params, token, cfg)
    kinds = block_kinds(cfg)
    new_caches = []
    for lp, kind, cache in zip(params["blocks"], kinds, caches):
        if kind == "rec":
            x, cache = _rec_decode(lp, x, cache, cfg)
        else:
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            y, cache = tfm.attn_apply_decode(lp["attn"], h, cache, pos, cfg,
                                             window=cfg.local_window)
            x = x + y
        x = _mlp_res(lp, x, cfg)
        new_caches.append(cache)
    return tfm.unembed(params, x, cfg), new_caches


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int):
    dt = jnp.dtype(cfg.dtype)
    W = cfg.resolved_lru_width
    K = cfg.ssm_conv or 4
    capacity = min(seq_len, cfg.local_window or seq_len)
    caches = []
    for kind in block_kinds(cfg):
        if kind == "rec":
            caches.append(RecCache(jnp.zeros((batch, W), jnp.float32),
                                   jnp.zeros((batch, K - 1, W), dt)))
        else:
            caches.append(attn.init_kv_cache(batch, capacity,
                                             cfg.num_kv_heads,
                                             cfg.resolved_head_dim, dt))
    return caches
