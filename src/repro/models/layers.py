"""Common layers: RMSNorm, rotary embeddings, gated MLP, initializers."""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def rms_norm_params(dim: int, dtype) -> jax.Array:
    # stored as zero-centered scale (gemma convention: weight = 1 + gamma)
    return jnp.zeros((dim,), dtype=dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (partial rotation supported)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, rotary_pct: float, theta: float):
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float64) / rot_dim))
    return rot_dim, jnp.asarray(inv, jnp.float32)


def apply_rope(x: jax.Array, positions: jax.Array, rotary_pct: float,
               theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    head_dim = x.shape[-1]
    rot_dim, inv = rope_freqs(head_dim, rotary_pct, theta)
    if rot_dim == 0:
        return x
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, rot/2]
    cos = jnp.cos(ang)[..., None, :]                      # [..., S, 1, rot/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_params(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_shapes(d_model: int, d_ff: int, dtype):
    sds = jax.ShapeDtypeStruct
    return {
        "w_gate": sds((d_model, d_ff), dtype),
        "w_up": sds((d_model, d_ff), dtype),
        "w_down": sds((d_ff, d_model), dtype),
    }


# ---------------------------------------------------------------------------
# Param maker: one code path produces either real arrays (key given) or
# jax.ShapeDtypeStruct stand-ins (key=None) so dry-runs never allocate.
# ---------------------------------------------------------------------------
class Maker:
    def __init__(self, key, dtype):
        self.key = key
        self.dtype = jnp.dtype(dtype)

    def __call__(self, name: str, shape, kind: str = "dense",
                 scale: float = 1.0):
        if self.key is None:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype)
        k = jax.random.fold_in(self.key, zlib.crc32(name.encode()) % (2 ** 31))
        if kind == "dense":
            return dense_init(k, tuple(shape), self.dtype, scale)
        if kind == "embed":
            return embed_init(k, tuple(shape), self.dtype)
        if kind == "zeros":
            return jnp.zeros(tuple(shape), self.dtype)
        if kind == "ones":
            return jnp.ones(tuple(shape), self.dtype)
        if kind == "f32":
            return jnp.zeros(tuple(shape), jnp.float32)
        raise ValueError(kind)

    def f32(self, name: str, shape):
        if self.key is None:
            return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
        return jnp.zeros(tuple(shape), jnp.float32)


def mlp_build(make: Maker, d_model: int, d_ff: int, prefix: str = "",
              stack: tuple = ()):
    s = tuple(stack)
    return {
        "w_gate": make(prefix + "w_gate", s + (d_model, d_ff)),
        "w_up": make(prefix + "w_up", s + (d_model, d_ff)),
        "w_down": make(prefix + "w_down", s + (d_ff, d_model)),
    }


def mlp_apply(p, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])
