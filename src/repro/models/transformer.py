"""Dense decoder-only transformer LM (GQA / MQA / qk_norm / partial rotary /
sliding-window / chunked attention). Also provides the attention sublayer
used by the MoE, hybrid and enc-dec models, including MLA (deepseek-v2).

Everything is functional: ``build_params(cfg, key)`` returns real arrays when
``key`` is given, or ShapeDtypeStructs when ``key=None`` (dry-run path).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models.layers import Maker, mlp_apply, mlp_build, rms_norm


# ---------------------------------------------------------------------------
# Attention sublayer
# ---------------------------------------------------------------------------
def attn_build(make: Maker, cfg: ModelConfig, stack=(), prefix=""):
    D, H, Kh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    Dh = cfg.resolved_head_dim
    s = tuple(stack)
    if cfg.use_mla:
        r, Dr, dv = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.resolved_v_head_dim
        p = {
            "wq": make(prefix + "wq", s + (D, H, Dh + Dr)),
            "w_dkv": make(prefix + "w_dkv", s + (D, r + Dr)),
            "kv_norm": make(prefix + "kv_norm", s + (r,), "zeros"),
            "w_uk": make(prefix + "w_uk", s + (H, Dh, r)),
            "w_uv": make(prefix + "w_uv", s + (H, r, dv)),
            "wo": make(prefix + "wo", s + (H, dv, D)),
        }
        if cfg.q_lora_rank:
            rq = cfg.q_lora_rank
            p["w_dq"] = make(prefix + "w_dq", s + (D, rq))
            p["q_norm_lora"] = make(prefix + "q_norm_lora", s + (rq,), "zeros")
            p["wq"] = make(prefix + "wq", s + (rq, H, Dh + Dr))
        return p
    p = {
        "wq": make(prefix + "wq", s + (D, H, Dh)),
        "wk": make(prefix + "wk", s + (D, Kh, Dh)),
        "wv": make(prefix + "wv", s + (D, Kh, Dh)),
        "wo": make(prefix + "wo", s + (H, Dh, D)),
    }
    if cfg.qk_norm:
        p["q_norm"] = make(prefix + "q_norm", s + (Dh,), "zeros")
        p["k_norm"] = make(prefix + "k_norm", s + (Dh,), "zeros")
    return p


def _qkv(p, h, positions, cfg: ModelConfig, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = attn.apply_rope(q, positions, cfg.rotary_pct, cfg.rope_theta)
        k = attn.apply_rope(k, positions, cfg.rotary_pct, cfg.rope_theta)
    return q, k, v


def attn_apply_full(p, h, positions, cfg: ModelConfig, *, window=None,
                    chunk=None, causal=True, rope=True, kv=None,
                    return_kv=False):
    """Full-sequence self (or cross, via kv=(k,v)) attention sublayer."""
    if cfg.use_mla:
        return _mla_apply_full(p, h, positions, cfg, return_kv=return_kv)
    if kv is None:
        q, k, v = _qkv(p, h, positions, cfg, rope=rope)
        kpos = positions
    else:
        q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k, v, kpos = kv
    out = attn.attend(q, k, v, positions, kpos, causal=causal, window=window,
                      chunk=chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def _mla_apply_full(p, h, positions, cfg: ModelConfig, return_kv=False):
    Dh, Dr = cfg.resolved_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        hq = rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]),
                      p["q_norm_lora"], cfg.norm_eps)
    else:
        hq = h
    qall = jnp.einsum("bsd,dhk->bshk", hq, p["wq"])
    q_nope, q_rope = qall[..., :Dh], qall[..., Dh:]
    q_rope = attn.apply_rope(q_rope, positions, 1.0, cfg.rope_theta)
    ckr = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    c, kr = ckr[..., :cfg.kv_lora_rank], ckr[..., cfg.kv_lora_rank:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    kr = attn.apply_rope(kr[:, :, None, :], positions, 1.0,
                         cfg.rope_theta)[:, :, 0, :]
    out = attn.mla_attend_full(q_nope, q_rope, c, kr, p["w_uk"], p["w_uv"],
                               positions, positions, causal=True)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    if return_kv:
        return y, (c, kr)
    return y


def attn_apply_decode(p, h, cache, pos, cfg: ModelConfig, *, window=None,
                      chunk=None, rope=True):
    """One-token self-attention. h: [B,1,D]. Returns (y, new_cache)."""
    if cfg.use_mla:
        return _mla_apply_decode(p, h, cache, pos, cfg)
    positions = jnp.asarray(pos, jnp.int32)[None]
    q, k, v = _qkv(p, h, positions, cfg, rope=rope)
    cache = attn.cache_write(cache, k, v, pos)
    out = attn.decode_attend(q, cache, pos, window=window, chunk=chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def _mla_apply_decode(p, h, cache, pos, cfg: ModelConfig):
    Dh = cfg.resolved_head_dim
    positions = jnp.asarray(pos, jnp.int32)[None]
    if cfg.q_lora_rank:
        hq = rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]),
                      p["q_norm_lora"], cfg.norm_eps)
    else:
        hq = h
    qall = jnp.einsum("bsd,dhk->bshk", hq, p["wq"])
    q_nope, q_rope = qall[..., :Dh], qall[..., Dh:]
    q_rope = attn.apply_rope(q_rope, positions, 1.0, cfg.rope_theta)
    ckr = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    c, kr = ckr[..., :cfg.kv_lora_rank], ckr[..., cfg.kv_lora_rank:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    kr = attn.apply_rope(kr[:, :, None, :], positions, 1.0,
                         cfg.rope_theta)[:, :, 0, :]
    cache = attn.mla_cache_write(cache, c, kr, pos)
    out = attn.mla_decode_attend(q_nope, q_rope, cache, p["w_uk"], p["w_uv"],
                                 pos)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), cache


def attn_prefill(p, h, positions, cfg: ModelConfig, capacity: int, *,
                 window=None, chunk=None):
    """Full-seq attention that also builds the decode cache (ring layout)."""
    B = h.shape[0]
    dt = h.dtype
    if cfg.use_mla:
        y, (c, kr) = _mla_apply_full(p, h, positions, cfg, return_kv=True)
        zc = attn.init_mla_cache(B, capacity, cfg.kv_lora_rank,
                                 cfg.rope_head_dim, dt)
        return y, _mla_cache_prefill(zc, c, kr)
    y, (k, v) = attn_apply_full(p, h, positions, cfg, window=window,
                                chunk=chunk, return_kv=True)
    Kh, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    zero = attn.init_kv_cache(B, capacity, Kh, Dh, dt)
    cache = attn.cache_prefill(zero, k, v)
    return y, cache


def _mla_cache_prefill(cache, c_all, kr_all):
    S, C = c_all.shape[1], cache.capacity
    if S >= C:
        c = c_all[:, S - C:]
        kr = kr_all[:, S - C:]
        pos = jnp.arange(S - C, S, dtype=jnp.int32)
        order = jnp.argsort(jnp.mod(pos, C))
        return attn.MLACache(c[:, order].astype(cache.c.dtype),
                             kr[:, order].astype(cache.kr.dtype), pos[order])
    pos = jnp.arange(S, dtype=jnp.int32)
    slots = jnp.mod(pos, C)
    return attn.MLACache(cache.c.at[:, slots].set(c_all.astype(cache.c.dtype)),
                         cache.kr.at[:, slots].set(kr_all.astype(cache.kr.dtype)),
                         cache.pos.at[slots].set(pos))


# ---------------------------------------------------------------------------
# Dense decoder layer
# ---------------------------------------------------------------------------
def layer_build(make: Maker, cfg: ModelConfig, stack=()):
    D = cfg.d_model
    s = tuple(stack)
    return {
        "ln1": make("ln1", s + (D,), "zeros"),
        "attn": attn_build(make, cfg, stack=s),
        "ln2": make("ln2", s + (D,), "zeros"),
        "mlp": mlp_build(make, cfg.d_model, cfg.d_ff, stack=s),
    }


def layer_apply(lp, x, positions, cfg: ModelConfig, *, window=None,
                chunk=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + attn_apply_full(lp["attn"], h, positions, cfg, window=window,
                            chunk=chunk)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h)


def layer_prefill(lp, x, positions, cfg: ModelConfig, capacity, *,
                  window=None, chunk=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, cache = attn_prefill(lp["attn"], h, positions, cfg, capacity,
                            window=window, chunk=chunk)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h), cache


def layer_decode(lp, x, cache, pos, cfg: ModelConfig, *, window=None,
                 chunk=None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, cache = attn_apply_decode(lp["attn"], h, cache, pos, cfg,
                                 window=window, chunk=chunk)
    x = x + y
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h), cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
def build_params(cfg: ModelConfig, key=None):
    make = Maker(key, cfg.dtype)
    p = {
        "embed": make("embed", (cfg.vocab_size, cfg.d_model), "embed"),
        "layers": layer_build(make, cfg, stack=(cfg.num_layers,)),
        "final_norm": make("final_norm", (cfg.d_model,), "zeros"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = make("lm_head", (cfg.d_model, cfg.vocab_size))
    return p


def embed_tokens(params, tokens, cfg: ModelConfig, extra_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def unembed(params, x, cfg: ModelConfig):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def forward(params, tokens, cfg: ModelConfig, extra_embeds=None):
    """tokens: [B, S_text] -> logits [B, S_total, V]."""
    x = embed_tokens(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        return layer_apply(lp, carry, positions, cfg,
                           window=cfg.sliding_window,
                           chunk=cfg.attention_chunk), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return unembed(params, x, cfg)


def prefill(params, tokens, cfg: ModelConfig, extra_embeds=None,
            extra_capacity: int = 0):
    """Returns (last-position logits [B,1,V], stacked caches)."""
    x = embed_tokens(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    capacity = attn.cache_capacity(S + extra_capacity, cfg.sliding_window,
                                   cfg.attention_chunk)

    def body(carry, lp):
        y, cache = layer_prefill(lp, carry, positions, cfg, capacity,
                                 window=cfg.sliding_window,
                                 chunk=cfg.attention_chunk)
        return y, cache

    x, caches = jax.lax.scan(body, x, params["layers"])
    return unembed(params, x[:, -1:, :], cfg), caches


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    """token: [B,1] int32; caches: stacked over layers. -> (logits, caches)."""
    x = embed_tokens(params, token, cfg)

    def body2(carry, xs):
        lp, cache = xs
        y, new_cache = layer_decode(lp, carry, cache, pos, cfg,
                                    window=cfg.sliding_window,
                                    chunk=cfg.attention_chunk)
        return y, new_cache

    x, caches = jax.lax.scan(body2, x, (params["layers"], caches))
    return unembed(params, x, cfg), caches


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int):
    """Stacked (over layers) empty caches sized for decoding at seq_len."""
    capacity = attn.cache_capacity(seq_len, cfg.sliding_window,
                                   cfg.attention_chunk)
    dt = jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    if cfg.use_mla:
        one = attn.init_mla_cache(batch, capacity, cfg.kv_lora_rank,
                                  cfg.rope_head_dim, dt)
    else:
        one = attn.init_kv_cache(batch, capacity, cfg.num_kv_heads,
                                 cfg.resolved_head_dim, dt)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)
