"""Attention: blockwise (flash-style) jnp attention with GQA, causal /
sliding-window / chunked masks, KV caches, and MLA (deepseek-v2).

The blockwise q-scan keeps peak memory at O(S * q_block) instead of O(S^2),
which is what lets ``prefill_32k`` fit on a v5e during the dry-run. The
Pallas kernel in ``repro.kernels.flash_attention`` is the TPU fast path;
this module is the lowering-friendly reference path (and the oracle).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope  # re-export  # noqa: F401

NEG_INF = -2.0e38


def _mask(qpos, kpos, *, causal: bool, window: Optional[int],
          chunk: Optional[int]):
    """qpos: [..., Q], kpos: [..., K] int32 -> bool [..., Q, K].

    kpos < 0 marks an invalid (unwritten) cache slot.
    """
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    m = k >= 0
    if causal:
        m = m & (k <= q)
    if window is not None:
        m = m & (q - k < window)
    if chunk is not None:
        m = m & ((q // chunk) == (k // chunk))
    return m


def _expand_kv(k, H: int):
    """Broadcast kv heads to the full H query heads (GQA). Keeping a single
    head dim (instead of a [Kh, G] split) gives GSPMD one cleanly
    model-sharded axis; XLA fuses the broadcast so only each device's head
    slice materializes."""
    Kh = k.shape[2]
    if Kh == H:
        return k
    G = H // Kh
    B, S, _, Dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, Kh, G, Dh))
    return k.reshape(B, S, H, Dh)


def _sdpa_block(qblk, k, v, qpos, kpos, *, causal, window, chunk, scale,
                shard=("batch", "model", None, None)):
    """qblk: [B,Qb,H,Dh], k/v: [B,S,H,Dh] -> [B,Qb,H,Dh].

    ``shard`` pins the [B,H,Qb,S] logits/probs layout: head-sharded for
    full-sequence attention, seq(kv)-sharded for decode over a seq-sharded
    cache (H2)."""
    from repro.sharding.context import constrain
    logits = jnp.einsum("bqhd,bshd->bhqs", qblk, k,
                        preferred_element_type=jnp.float32) * scale
    # GSPMD loses shardings inside scanned bodies and would replicate the
    # [B,H,Qb,S] tensors -> pin shardings explicitly.
    lg_shard = (shard[0], shard[1], None, shard[3]) \
        if len(shard) == 4 else shard
    logits = constrain(logits, *lg_shard)
    m = _mask(qpos, kpos, causal=causal, window=window, chunk=chunk)
    logits = jnp.where(m[:, None], logits, NEG_INF)
    # softmax in fp32; fully-masked rows produce zeros
    mx = jnp.max(logits, axis=-1, keepdims=True)
    mx = jnp.maximum(mx, -1e30)
    p = jnp.exp(logits - mx)
    denom = jnp.sum(p, axis=-1, keepdims=True) + 1e-30
    p = (p / denom).astype(v.dtype)
    p = constrain(p, *lg_shard)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def attend(q, k, v, qpos, kpos, *, causal=True, window=None, chunk=None,
           q_block: int = 512, scale: Optional[float] = None):
    """Blockwise attention.

    q: [B, Sq, H, Dh]; k/v: [B, Sk, Kh, Dh]; qpos: [Sq] or [B,Sq];
    kpos: [Sk] or [B,Sk]. Returns [B, Sq, H, Dh].
    """
    B, Sq, H, Dh = q.shape
    scale = scale if scale is not None else Dh ** -0.5
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    if qpos.ndim == 1:
        qpos = jnp.broadcast_to(qpos[None], (1, Sq))
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos[None], (1, k.shape[1]))

    if Sq <= q_block:
        return _sdpa_block(q, k, v, qpos, kpos, causal=causal, window=window,
                           chunk=chunk, scale=scale)

    nb = Sq // q_block
    assert Sq % q_block == 0, f"Sq={Sq} not divisible by q_block={q_block}"
    qs = q.reshape(B, nb, q_block, H, Dh).transpose(1, 0, 2, 3, 4)
    qps = qpos.reshape(qpos.shape[0], nb, q_block).transpose(1, 0, 2)

    def body(_, blk):
        qb, qp = blk
        o = _sdpa_block(qb, k, v, qp, kpos, causal=causal, window=window,
                        chunk=chunk, scale=scale)
        return None, o

    # checkpoint: recompute the per-block softmax in backward instead of
    # saving [B,H,q_block,S] probabilities for every block (flash-style).
    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qs, qps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


# ---------------------------------------------------------------------------
# KV cache (ring buffer when window/chunk-limited)
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array        # [B, C, Kh, Dh]
    v: jax.Array        # [B, C, Kh, Dh]
    pos: jax.Array      # [C] int32, position held in each slot (-1 = empty)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_kv_cache(batch: int, capacity: int, kv_heads: int, head_dim: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
        pos=jnp.full((capacity,), -1, jnp.int32),
    )


def cache_capacity(seq_len: int, window: Optional[int],
                   chunk: Optional[int]) -> int:
    """Ring-buffer capacity needed to decode at positions up to seq_len."""
    if window is not None:
        return min(seq_len, window)
    if chunk is not None:
        return min(seq_len, chunk)
    return seq_len


def cache_write(cache: KVCache, k_new, v_new, pos) -> KVCache:
    """Write one token (k_new/v_new: [B, 1, Kh, Dh]) at position ``pos``."""
    slot = jnp.mod(pos, cache.capacity)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    p = jax.lax.dynamic_update_slice(cache.pos,
                                     jnp.asarray(pos, jnp.int32)[None], (slot,))
    return KVCache(k, v, p)


def cache_prefill(cache: KVCache, k_all, v_all, start: int = 0) -> KVCache:
    """Bulk write S tokens (positions start..start+S-1). S <= capacity uses a
    tail write for ring semantics; S == capacity overwrites fully."""
    S = k_all.shape[1]
    C = cache.capacity
    if S >= C:
        k = k_all[:, S - C:].astype(cache.k.dtype)
        v = v_all[:, S - C:].astype(cache.v.dtype)
        p = jnp.arange(start + S - C, start + S, dtype=jnp.int32)
        # slot i holds position p where p % C == i
        order = jnp.argsort(jnp.mod(p, C))
        return KVCache(k[:, order], v[:, order], p[order])
    pos = jnp.arange(start, start + S, dtype=jnp.int32)
    slots = jnp.mod(pos, C)
    k = cache.k.at[:, slots].set(k_all.astype(cache.k.dtype))
    v = cache.v.at[:, slots].set(v_all.astype(cache.v.dtype))
    p = cache.pos.at[slots].set(pos)
    return KVCache(k, v, p)


# H2 (EXPERIMENTS.md §Perf): when the KV cache is sharded on its sequence
# dim (kv_heads not divisible by the model axis), keep it that way during
# decode — compute seq-sharded partial softmax + psum of the tiny context
# instead of all-gathering gigabytes of cache per decoded token.
import os as _os
DECODE_PREFER_SEQ_SHARD = _os.environ.get("REPRO_DECODE_SEQ_SHARD", "1") == "1"  # H2: on by default (validated)


def decode_attend(q, cache: KVCache, pos, *, causal=True, window=None,
                  chunk=None, scale=None):
    """One-token attention against a cache. q: [B, 1, H, Dh]."""
    from repro.sharding.context import constrain, model_axis_size
    B, _, H, Dh = q.shape
    Kh, C = cache.k.shape[2], cache.k.shape[1]
    scale = scale if scale is not None else Dh ** -0.5
    msize = model_axis_size()
    seq_sharded = (DECODE_PREFER_SEQ_SHARD and msize > 1
                   and Kh % msize != 0 and C % msize == 0)
    k = _expand_kv(cache.k, H)
    v = _expand_kv(cache.v, H)
    if seq_sharded:
        k = constrain(k, "batch", "model", None, None)   # [B,C,H,Dh]: C
        v = constrain(v, "batch", "model", None, None)
    qpos = jnp.asarray(pos, jnp.int32)[None, None]        # [1,1]
    kpos = cache.pos[None]                                # [1,C]
    shard = (("batch", None, None, "model") if seq_sharded
             else ("batch", "model", None, None))
    return _sdpa_block(q, k, v, qpos, kpos, causal=causal,
                       window=window, chunk=chunk, scale=scale,
                       shard=shard)


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): compressed-KV attention. Cache = (c_kv, k_rope, pos).
# ---------------------------------------------------------------------------
class MLACache(NamedTuple):
    c: jax.Array        # [B, C, r]        compressed latent
    kr: jax.Array       # [B, C, Dr]       rope'd shared key part
    pos: jax.Array      # [C]

    @property
    def capacity(self) -> int:
        return self.c.shape[1]


def init_mla_cache(batch: int, capacity: int, r: int, rope_dim: int,
                   dtype) -> MLACache:
    return MLACache(
        c=jnp.zeros((batch, capacity, r), dtype),
        kr=jnp.zeros((batch, capacity, rope_dim), dtype),
        pos=jnp.full((capacity,), -1, jnp.int32),
    )


def mla_attend_full(q_nope, q_rope, c, k_rope, w_uk, w_uv, qpos, kpos,
                    *, causal=True, q_block: int = 512):
    """Absorbed MLA attention over full sequences.

    q_nope: [B,Sq,H,dh], q_rope: [B,Sq,H,Dr], c: [B,Sk,r], k_rope: [B,Sk,Dr],
    w_uk: [H,dh,r], w_uv: [H,r,dv]. Returns [B,Sq,H,dv].
    """
    B, Sq, H, dh = q_nope.shape
    Dr = q_rope.shape[-1]
    scale = (dh + Dr) ** -0.5
    qc = jnp.einsum("bqhd,hdr->bqhr", q_nope, w_uk)       # absorb W_uk
    if qpos.ndim == 1:
        qpos = qpos[None]
    if kpos.ndim == 1:
        kpos = kpos[None]

    def blockfn(qc_b, qr_b, qp):
        from repro.sharding.context import constrain
        lg = (jnp.einsum("bqhr,bsr->bhqs", qc_b, c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", qr_b, k_rope,
                           preferred_element_type=jnp.float32)) * scale
        lg = constrain(lg, "batch", "model", None, None)
        m = _mask(qp, kpos, causal=causal, window=None, chunk=None)
        lg = jnp.where(m[:, None], lg, NEG_INF)
        mx = jnp.maximum(jnp.max(lg, axis=-1, keepdims=True), -1e30)
        p = jnp.exp(lg - mx)
        p = (p / (jnp.sum(p, axis=-1, keepdims=True) + 1e-30)).astype(c.dtype)
        p = constrain(p, "batch", "model", None, None)
        ctx = jnp.einsum("bhqs,bsr->bqhr", p, c)
        return jnp.einsum("bqhr,hrv->bqhv", ctx, w_uv)

    if Sq <= q_block:
        return blockfn(qc, q_rope, qpos)
    nb = Sq // q_block
    qc_s = qc.reshape(B, nb, q_block, H, -1).transpose(1, 0, 2, 3, 4)
    qr_s = q_rope.reshape(B, nb, q_block, H, Dr).transpose(1, 0, 2, 3, 4)
    qp_s = qpos.reshape(qpos.shape[0], nb, q_block).transpose(1, 0, 2)

    def body(_, blk):
        a, b_, p_ = blk
        return None, blockfn(a, b_, p_)

    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qc_s, qr_s, qp_s))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, -1)


def mla_cache_write(cache: MLACache, c_new, kr_new, pos) -> MLACache:
    slot = jnp.mod(pos, cache.capacity)
    c = jax.lax.dynamic_update_slice(cache.c, c_new.astype(cache.c.dtype),
                                     (0, slot, 0))
    kr = jax.lax.dynamic_update_slice(cache.kr, kr_new.astype(cache.kr.dtype),
                                      (0, slot, 0))
    p = jax.lax.dynamic_update_slice(cache.pos,
                                     jnp.asarray(pos, jnp.int32)[None], (slot,))
    return MLACache(c, kr, p)


def mla_decode_attend(q_nope, q_rope, cache: MLACache, w_uk, w_uv, pos):
    qpos = jnp.asarray(pos, jnp.int32)[None, None]
    return mla_attend_full(q_nope, q_rope, cache.c, cache.kr, w_uk, w_uv,
                           qpos[0], cache.pos, causal=True)
