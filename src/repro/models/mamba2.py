"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) decoder LM.

Chunked SSD algorithm in pure JAX: within-chunk quadratic ("attention-like")
term + inter-chunk linear recurrence over chunk states (lax.scan). Decode is
a single O(1)-state update, which is why mamba2 runs the ``long_500k`` shape.

Sharding: SSM heads on ``model``, batch on ``data``/``pod`` — all via GSPMD
(no shard_map needed; the recurrence is elementwise in the head dim).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import Maker, rms_norm


class SSMCache(NamedTuple):
    state: jax.Array     # [B, H, P, N]
    conv_x: jax.Array    # [B, K-1, d_inner]
    conv_B: jax.Array    # [B, K-1, N]
    conv_C: jax.Array    # [B, K-1, N]


def layer_build(make: Maker, cfg: ModelConfig, stack=()):
    D, W = cfg.d_model, cfg.ssm_d_inner
    N, H, K = cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv
    s = tuple(stack)
    return {
        "ln": make("ln", s + (D,), "zeros"),
        "w_z": make("w_z", s + (D, W)),
        "w_x": make("w_x", s + (D, W)),
        "w_B": make("w_B", s + (D, N)),
        "w_C": make("w_C", s + (D, N)),
        "w_dt": make("w_dt", s + (D, H)),
        "conv_x": make("conv_x", s + (K, W), scale=0.5),
        "conv_B": make("conv_B", s + (K, N), scale=0.5),
        "conv_C": make("conv_C", s + (K, N), scale=0.5),
        "A_log": make("A_log", s + (H,), "zeros"),
        "dt_bias": make("dt_bias", s + (H,), "zeros"),
        "D_skip": make("D_skip", s + (H,), "zeros"),
        "out_norm": make("out_norm", s + (W,), "zeros"),
        "w_out": make("w_out", s + (W, D)),
    }


def build_params(cfg: ModelConfig, key=None):
    make = Maker(key, cfg.dtype)
    p = {
        "embed": make("embed", (cfg.vocab_size, cfg.d_model), "embed"),
        "layers": layer_build(make, cfg, stack=(cfg.num_layers,)),
        "final_norm": make("final_norm", (cfg.d_model,), "zeros"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = make("lm_head", (cfg.d_model, cfg.vocab_size))
    return p


def _causal_conv(x, w, buf=None):
    """Depthwise causal conv. x: [B,S,F], w: [K,F]. buf: [B,K-1,F] history.

    Returns (y [B,S,F], new_buf [B,K-1,F]).
    """
    K = w.shape[0]
    if buf is None:
        buf = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    # y_t = sum_k w[k] * xp[t + k]
    S = x.shape[1]
    y = jnp.zeros_like(x)
    for k in range(K):
        y = y + xp[:, k:k + S] * w[k]
    new_buf = xp[:, -(K - 1):] if K > 1 else buf
    return y, new_buf


def _ssd_chunked(xh, dt, A, B_, C_, chunk: int):
    """SSD scan. xh: [B,S,H,P]; dt: [B,S,H]; A: [H]; B_/C_: [B,S,N].

    Scans over chunks so the quadratic within-chunk tensors only ever exist
    for ONE chunk at a time (peak memory O(B * Lc^2 * H) instead of
    O(B * S * Lc * H)); the inter-chunk state recurrence rides the same scan.

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bb, S, H, Pd = xh.shape
    N = B_.shape[-1]
    Lc = min(chunk, S)
    assert S % Lc == 0, (S, Lc)
    nc = S // Lc
    f32 = jnp.float32
    xs = xh.reshape(Bb, nc, Lc, H, Pd).transpose(1, 0, 2, 3, 4).astype(f32)
    dts = dt.reshape(Bb, nc, Lc, H).transpose(1, 0, 2, 3).astype(f32)
    Bs = B_.reshape(Bb, nc, Lc, N).transpose(1, 0, 2, 3).astype(f32)
    Cs = C_.reshape(Bb, nc, Lc, N).transpose(1, 0, 2, 3).astype(f32)
    mask = jnp.tril(jnp.ones((Lc, Lc), bool))

    def body(h, inp):
        x_c, dt_c, B_c, C_c = inp                    # [B,Lc,...] one chunk
        dA = dt_c * A                                # [B,Lc,H] (negative)
        seg = jnp.cumsum(dA, axis=1)
        total = seg[:, -1, :]                        # [B,H]
        # within-chunk decay L[l,m] = exp(seg_l - seg_m) * dt_m, m <= l
        dec = seg[:, :, None, :] - seg[:, None, :, :]        # [B,l,m,H]
        dec = jnp.where(mask[None, :, :, None], dec, -jnp.inf)
        Lmat = jnp.exp(dec) * dt_c[:, None, :, :]
        att = jnp.einsum("bln,bmn->blm", C_c, B_c,
                         preferred_element_type=f32)
        y_diag = jnp.einsum("blm,blmh,bmhp->blhp", att, Lmat, x_c)
        # contribution of carried state
        y_off = jnp.einsum("bln,bhpn,blh->blhp", C_c, h, jnp.exp(seg))
        # chunk state + recurrence
        decay_to_end = jnp.exp(total[:, None, :] - seg) * dt_c  # [B,Lc,H]
        s_c = jnp.einsum("blh,bln,blhp->bhpn", decay_to_end, B_c, x_c)
        h_new = h * jnp.exp(total)[:, :, None, None] + s_c
        return h_new, y_diag + y_off

    h0 = jnp.zeros((Bb, H, Pd, N), f32)
    hT, ys = jax.lax.scan(body, h0, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, Pd).astype(xh.dtype)
    return y, hT


def _gated_out(p, y, z, x_in, cfg: ModelConfig):
    W = cfg.ssm_d_inner
    y = y + x_in * p["D_skip"][..., None]                # skip connection
    y = y.reshape(y.shape[0], -1, W) if y.ndim == 4 else y
    z = z.reshape(z.shape[0], -1, W)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return jnp.einsum("bsw,wd->bsd", y, p["w_out"])


def layer_apply(lp, x, cfg: ModelConfig, cache: SSMCache = None,
                return_cache: bool = False):
    """Full-sequence SSD mixer. x: [B,S,D]."""
    Bb, S, D = x.shape
    H, Pd, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,dw->bsw", h, lp["w_z"])
    xi = jnp.einsum("bsd,dw->bsw", h, lp["w_x"])
    Bi = jnp.einsum("bsd,dn->bsn", h, lp["w_B"])
    Ci = jnp.einsum("bsd,dn->bsn", h, lp["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", h, lp["w_dt"])

    bufs = (None, None, None) if cache is None else (
        cache.conv_x, cache.conv_B, cache.conv_C)
    xi, bx = _causal_conv(xi, lp["conv_x"], bufs[0])
    Bi, bB = _causal_conv(Bi, lp["conv_B"], bufs[1])
    Ci, bC = _causal_conv(Ci, lp["conv_C"], bufs[2])
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(xi.dtype)
    Bi = jax.nn.silu(Bi.astype(jnp.float32)).astype(Bi.dtype)
    Ci = jax.nn.silu(Ci.astype(jnp.float32)).astype(Ci.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = xi.reshape(Bb, S, H, Pd)
    y, hT = _ssd_chunked(xh, dt, A, Bi, Ci, cfg.ssm_chunk)
    out = _gated_out(lp, y, z, xh, cfg)
    x = x + out
    if return_cache:
        return x, SSMCache(hT.astype(jnp.float32), bx, bB, bC)
    return x


def layer_decode(lp, x, cache: SSMCache, cfg: ModelConfig):
    """One token. x: [B,1,D]."""
    Bb = x.shape[0]
    H, Pd, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,dw->bsw", h, lp["w_z"])
    xi = jnp.einsum("bsd,dw->bsw", h, lp["w_x"])
    Bi = jnp.einsum("bsd,dn->bsn", h, lp["w_B"])
    Ci = jnp.einsum("bsd,dn->bsn", h, lp["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", h, lp["w_dt"])

    xi, bx = _causal_conv(xi, lp["conv_x"], cache.conv_x)
    Bi, bB = _causal_conv(Bi, lp["conv_B"], cache.conv_B)
    Ci, bC = _causal_conv(Ci, lp["conv_C"], cache.conv_C)
    xi = jax.nn.silu(xi.astype(jnp.float32)).astype(xi.dtype)
    Bi = jax.nn.silu(Bi.astype(jnp.float32)).astype(Bi.dtype)
    Ci = jax.nn.silu(Ci.astype(jnp.float32)).astype(Ci.dtype)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])[:, 0]  # [B,H]
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = xi.reshape(Bb, H, Pd).astype(jnp.float32)
    g = jnp.exp(dt * A)                                    # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bi[:, 0].astype(jnp.float32), xh)
    state = cache.state * g[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Ci[:, 0].astype(jnp.float32))
    y = y[:, None].astype(x.dtype)                         # [B,1,H,P]
    out = _gated_out(lp, y, z, xh[:, None].astype(x.dtype), cfg)
    return x + out, SSMCache(state, bx, bB, bC)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
def forward(params, tokens, cfg: ModelConfig, extra_embeds=None):
    x = tfm.embed_tokens(params, tokens, cfg, extra_embeds)

    def body(carry, lp):
        return layer_apply(lp, carry, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return tfm.unembed(params, x, cfg)


def prefill(params, tokens, cfg: ModelConfig, extra_embeds=None,
            extra_capacity: int = 0):
    x = tfm.embed_tokens(params, tokens, cfg, extra_embeds)

    def body(carry, lp):
        y, cache = layer_apply(lp, carry, cfg, return_cache=True)
        return y, cache

    x, caches = jax.lax.scan(body, x, params["layers"])
    return tfm.unembed(params, x[:, -1:, :], cfg), caches


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    del pos  # SSM state is position-free
    x = tfm.embed_tokens(params, token, cfg)

    def body(carry, xs):
        lp, cache = xs
        return layer_decode(lp, carry, cache, cfg)

    x, caches = jax.lax.scan(body, x, (params["layers"], caches))
    return tfm.unembed(params, x, cfg), caches


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int):
    del seq_len
    H, Pd, N, K, W = (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state,
                      cfg.ssm_conv, cfg.ssm_d_inner)
    dt = jnp.dtype(cfg.dtype)
    one = SSMCache(
        state=jnp.zeros((batch, H, Pd, N), jnp.float32),
        conv_x=jnp.zeros((batch, K - 1, W), dt),
        conv_B=jnp.zeros((batch, K - 1, N), dt),
        conv_C=jnp.zeros((batch, K - 1, N), dt),
    )
    L = cfg.num_layers
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape), one)
