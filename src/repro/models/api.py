"""Uniform model API over all architecture families.

    params = build_params(cfg, key)          # arrays, or SDS when key=None
    logits, aux = forward(params, batch, cfg)
    logits, caches = prefill(params, batch, cfg)
    logits, caches = decode_step(params, token, pos, caches, cfg)
    caches = init_decode_caches(cfg, batch, seq_len)   # key-ful
    batch = make_batch(cfg, shape_or_dims, key)        # real arrays
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.config import (DENSE, ENCDEC, HYBRID, MOE, SSM, VLM,
                          ModelConfig)
from repro.models import encdec, mamba2, moe, rglru, transformer, vlm


def _mod(cfg: ModelConfig):
    return {DENSE: transformer, MOE: moe, SSM: mamba2, HYBRID: rglru,
            ENCDEC: encdec, VLM: vlm}[cfg.family]


def build_params(cfg: ModelConfig, key=None):
    return _mod(cfg).build_params(cfg, key)


def forward(params, batch, cfg: ModelConfig) -> Tuple[Any, Any]:
    """Returns (logits, aux_loss)."""
    m = _mod(cfg)
    if cfg.family == MOE:
        return m.forward(params, batch, cfg)
    if cfg.family in (ENCDEC, VLM):
        return m.forward(params, batch, cfg), jnp.zeros((), jnp.float32)
    return m.forward(params, batch, cfg), jnp.zeros((), jnp.float32)


def prefill(params, batch, cfg: ModelConfig, extra_capacity: int = 0):
    m = _mod(cfg)
    if cfg.family in (ENCDEC, VLM):
        return m.prefill(params, batch, cfg, extra_capacity=extra_capacity)
    return m.prefill(params, batch, cfg, extra_capacity=extra_capacity)


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    return _mod(cfg).decode_step(params, token, pos, caches, cfg)


def init_decode_caches(cfg: ModelConfig, batch: int, seq_len: int):
    return _mod(cfg).init_decode_caches(cfg, batch, seq_len)


# ---------------------------------------------------------------------------
# Batch construction (real arrays, for smoke tests / reduced-scale serving)
# ---------------------------------------------------------------------------
def make_batch(cfg: ModelConfig, batch: int, seq_len: int, key=None):
    key = key if key is not None else jax.random.key(0)
    if cfg.family == ENCDEC:
        Sf = cfg.encoder_frames
        frames = (jax.random.normal(key, (batch, Sf, cfg.d_model),
                                    jnp.float32) * 0.02)
        tokens = jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size)
        return (frames.astype(jnp.dtype(cfg.dtype)), tokens.astype(jnp.int32))
    if cfg.family == VLM:
        P = cfg.num_patches
        st = max(seq_len - P, 1)
        patches = vlm.stub_patches(cfg, batch)
        tokens = jax.random.randint(key, (batch, st), 0, cfg.vocab_size)
        return (patches, tokens.astype(jnp.int32))
    tokens = jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size)
    return tokens.astype(jnp.int32)


def batch_labels(cfg: ModelConfig, batch) -> jax.Array:
    """Next-token labels aligned to the logits of ``forward(batch)``."""
    if cfg.family == ENCDEC:
        tokens = batch[1]
        return jnp.roll(tokens, -1, axis=1)
    if cfg.family == VLM:
        patches, tokens = batch
        P = patches.shape[1]
        lab = jnp.roll(tokens, -1, axis=1)
        pad = jnp.full((tokens.shape[0], P), -100, jnp.int32)  # ignore vision
        return jnp.concatenate([pad, lab], axis=1)
    return jnp.roll(batch, -1, axis=1)


def loss_fn(logits, labels, aux, aux_weight: float = 0.01):
    """Masked next-token cross entropy (labels == -100 ignored).

    Vocab stays sharded: logsumexp reduces over the (possibly model-sharded)
    vocab axis; GSPMD inserts the psum.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    picked = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * valid.astype(jnp.float32)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
    return loss + aux_weight * aux
