"""VLM backbone (llava-next-mistral-7b): Mistral decoder consuming anyres
patch embeddings from a STUB vision frontend (per assignment: the ViT/
projector is not implemented; ``input_specs()`` supplies patch embeddings of
the right shape, prepended to the text tokens).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as tfm

build_params = tfm.build_params
init_decode_caches = tfm.init_decode_caches


def forward(params, batch, cfg: ModelConfig):
    """batch: (patches [B,P,D], tokens [B,St]) -> logits [B, P+St, V]."""
    patches, tokens = batch
    return tfm.forward(params, tokens, cfg, extra_embeds=patches)


def prefill(params, batch, cfg: ModelConfig, extra_capacity: int = 0):
    patches, tokens = batch
    return tfm.prefill(params, tokens, cfg, extra_embeds=patches,
                       extra_capacity=extra_capacity)


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    return tfm.decode_step(params, token, pos, caches, cfg)


def stub_patches(cfg: ModelConfig, batch: int, dtype=None):
    """Deterministic stand-in for the vision tower output."""
    dt = dtype or jnp.dtype(cfg.dtype)
    P, D = cfg.num_patches, cfg.d_model
    base = jnp.linspace(-0.5, 0.5, P * D, dtype=jnp.float32).reshape(1, P, D)
    return jnp.broadcast_to(base.astype(dt), (batch, P, D))
