"""Model zoo: pure-JAX functional model definitions for all assigned archs."""
from repro.models import api  # noqa: F401
