"""Mixture-of-Experts decoder LM (llama4-scout: 16e top-1 + shared expert +
chunked attention; deepseek-v2: 160e top-6 + 2 shared experts + MLA).

Expert parallelism: experts are sharded on the ``model`` mesh axis and the
MoE block runs under ``shard_map`` — every expert-parallel rank routes the
full local token set to *its* experts (activations are already replicated
over ``model`` at this point), computes capacity-bounded expert FFNs with a
sort-based dispatch (no T×E×C dense dispatch einsum), and the partial
outputs are combined with a single psum over ``model``. FSDP-sharded expert
weights are all-gathered over ``data`` inside the block, exactly like a
hand-written FSDP layer.

With no mesh in context (smoke tests) the same math runs single-device.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import Maker, rms_norm
from repro.sharding import context as shctx


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def moe_ffn_build(make: Maker, cfg: ModelConfig, stack=()):
    D, E = cfg.d_model, cfg.num_experts
    F = cfg.resolved_moe_d_ff
    s = tuple(stack)
    p = {
        "router": make("router", s + (D, E), scale=0.1),
        "w1": make("moe_w1", s + (E, D, F)),          # gate proj
        "w3": make("moe_w3", s + (E, D, F)),          # up proj
        "w2": make("moe_w2", s + (E, F, D)),          # down proj
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        p["sh_gate"] = make("moe_sh_gate", s + (D, Fs))
        p["sh_up"] = make("moe_sh_up", s + (D, Fs))
        p["sh_down"] = make("moe_sh_down", s + (Fs, D))
    return p


def layer_build(make: Maker, cfg: ModelConfig, stack=()):
    D = cfg.d_model
    s = tuple(stack)
    return {
        "ln1": make("ln1", s + (D,), "zeros"),
        "attn": tfm.attn_build(make, cfg, stack=s),
        "ln2": make("ln2", s + (D,), "zeros"),
        "moe": moe_ffn_build(make, cfg, stack=s),
    }


def build_params(cfg: ModelConfig, key=None):
    make = Maker(key, cfg.dtype)
    p = {
        "embed": make("embed", (cfg.vocab_size, cfg.d_model), "embed"),
        "layers": layer_build(make, cfg, stack=(cfg.num_layers,)),
        "final_norm": make("final_norm", (cfg.d_model,), "zeros"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = make("lm_head", (cfg.d_model, cfg.vocab_size))
    return p


# ---------------------------------------------------------------------------
# Routing + capacity dispatch (runs per expert-parallel rank)
# ---------------------------------------------------------------------------
def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(cfg.capacity_factor * num_tokens * cfg.top_k
                      / max(cfg.num_experts, 1)))
    c = max(c, 8)
    return min(-(-c // 8) * 8, num_tokens * cfg.top_k)


def _moe_ffn_block(x2, p, cfg: ModelConfig, e_start: int, e_local: int,
                   w1, w3, w2):
    """Expert contribution of experts [e_start, e_start+e_local) to tokens.

    x2: [T, D] local tokens (replicated over the expert axis).
    Returns (partial_y [T, D], aux_loss scalar partial, router probs [T, E]).
    """
    T, D = x2.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", x2, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                  # [T,k]
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    flat_e = idx.reshape(-1)                              # [T*k]
    flat_t = jnp.arange(T * k, dtype=jnp.int32) // k
    local_e = flat_e - e_start
    is_local = (local_e >= 0) & (local_e < e_local)
    sort_key = jnp.where(is_local, local_e, e_local)      # non-local -> end
    order = jnp.argsort(sort_key, stable=True)
    sorted_e = sort_key[order]
    pos_in_grp = (jnp.arange(T * k, dtype=jnp.int32)
                  - jnp.searchsorted(sorted_e, sorted_e, side="left"))
    C = _capacity(T, cfg)
    keep = (sorted_e < e_local) & (pos_in_grp < C)
    dest = jnp.where(keep, sorted_e * C + pos_in_grp, e_local * C)

    gathered = x2[flat_t[order]]                          # [T*k, D]
    buf = jnp.zeros((e_local * C + 1, D), x2.dtype).at[dest].add(
        jnp.where(keep[:, None], gathered, 0))
    buf = buf[: e_local * C].reshape(e_local, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1).astype(jnp.float32))
    h = h.astype(x2.dtype) * jnp.einsum("ecd,edf->ecf", buf, w3)
    out = jnp.einsum("ecf,efd->ecd", h, w2).reshape(e_local * C, D)
    out = jnp.concatenate([out, jnp.zeros((1, D), out.dtype)], axis=0)

    contrib_sorted = out[dest] * keep[:, None].astype(out.dtype)
    inv = jnp.argsort(order)
    contrib = contrib_sorted[inv]                         # [T*k, D]
    y = (contrib * gates.reshape(-1, 1).astype(contrib.dtype)
         ).reshape(T, k, D).sum(axis=1)

    # Switch-style load-balance aux loss (over ALL experts; identical on
    # every rank, so dividing by the expert-parallel degree after psum is
    # handled by the caller).
    me = jnp.mean(probs, axis=0)                          # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(idx, E, dtype=jnp.float32)).sum(1), axis=0)
    aux = E * jnp.sum(me * ce) * (1.0 / k)
    return y, aux


def _shared_expert(x2, p, lo: int, hi: int):
    """Shared-expert MLP on a column slice [lo, hi) of the hidden dim."""
    g = jnp.einsum("td,df->tf", x2, p["sh_gate"][:, lo:hi])
    u = jnp.einsum("td,df->tf", x2, p["sh_up"][:, lo:hi])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x2.dtype) * u
    return jnp.einsum("tf,fd->td", h, p["sh_down"][lo:hi, :])


def moe_apply(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> (y [B, S, D], aux scalar)."""
    B, S, D = x.shape
    mesh = shctx.get_mesh()
    ep = shctx.model_axis_size()
    if mesh is None or ep == 1 or cfg.num_experts < ep:
        x2 = x.reshape(B * S, D)
        y, aux = _moe_ffn_block(x2, p, cfg, 0, cfg.num_experts,
                                p["w1"], p["w3"], p["w2"])
        if cfg.num_shared_experts:
            y = y + _shared_expert(x2, p, 0, p["sh_gate"].shape[1])
        return y.reshape(B, S, D), aux

    baxes = shctx.batch_axes()
    if baxes:
        nshards = 1
        for a in baxes:
            nshards *= mesh.shape[a]
        if B % nshards != 0:
            baxes = None          # tiny/unshardable batch: replicate tokens
    E_loc = cfg.num_experts // ep
    Fs = p["sh_gate"].shape[1] if cfg.num_shared_experts else 0
    Fs_loc = Fs // ep if Fs else 0

    def block(x_blk, p_blk):
        ei = jax.lax.axis_index("model")
        # FSDP: gather the data-sharded weight dims before use.
        w1 = jax.lax.all_gather(p_blk["w1"], "data", axis=1, tiled=True)
        w3 = jax.lax.all_gather(p_blk["w3"], "data", axis=1, tiled=True)
        w2 = jax.lax.all_gather(p_blk["w2"], "data", axis=2, tiled=True)
        T_loc = x_blk.shape[0] * x_blk.shape[1]
        x2 = x_blk.reshape(T_loc, D)
        y, aux = _moe_ffn_block(x2, p_blk, cfg, ei * E_loc, E_loc, w1, w3, w2)
        if cfg.num_shared_experts:
            y = y + _shared_expert(x2, p_blk, 0, Fs_loc)
        y = jax.lax.psum(y, "model")
        # aux varies across token shards only (it is invariant over the
        # expert-parallel axis) -> mean over the batch axes.
        if baxes:
            nb = 1
            for a in baxes:
                nb *= mesh.shape[a]
            aux = jax.lax.psum(aux, baxes) / nb
        return y.reshape(x_blk.shape), aux

    in_specs = (
        P(baxes, None, None),
        {
            "router": P(),
            "w1": P("model", "data", None),
            "w3": P("model", "data", None),
            "w2": P("model", None, "data"),
            **({"sh_gate": P(None, "model"), "sh_up": P(None, "model"),
                "sh_down": P("model", None)} if cfg.num_shared_experts else {}),
        },
    )
    # With a replicated batch (long_500k, B=1) the outputs are data-invariant
    # because the FSDP all_gather returns identical weights on every data
    # rank — a fact the static vma checker cannot prove, so disable it.
    y, aux = jax.shard_map(
        block, mesh=mesh, in_specs=in_specs,
        out_specs=(P(baxes, None, None), P()),
        check_vma=baxes is not None,
    )(x, p)
    return y, aux


# ---------------------------------------------------------------------------
# Layers + model (mirrors transformer.py but FFN -> MoE, returns aux loss)
# ---------------------------------------------------------------------------
def _layer_kinds(cfg: ModelConfig):
    """(window, chunk) per layer. llama4: 3-of-4 chunked, every 4th full."""
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.chunk_pattern and (i + 1) % cfg.chunk_pattern == 0:
            kinds.append((cfg.sliding_window, None))      # full/NoPE layer
        else:
            kinds.append((cfg.sliding_window, cfg.attention_chunk))
    return kinds


def layer_apply(lp, x, positions, cfg: ModelConfig, *, window, chunk):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    x = x + tfm.attn_apply_full(lp["attn"], h, positions, cfg, window=window,
                                chunk=chunk)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    y, aux = moe_apply(lp["moe"], h, cfg)
    return x + y, aux


def _grouped_scan(params_layers, cfg: ModelConfig, per_layer_fn, init,
                  carries_cache=None):
    """Scan over layer groups honouring the chunk pattern.

    per_layer_fn(lp, carry, is_full_attn, cache_slice) -> (carry, aux, new_cache)
    """
    L = cfg.num_layers
    pat = cfg.chunk_pattern or 1
    assert L % pat == 0, (L, pat)
    ngroups = L // pat
    grouped = jax.tree.map(
        lambda a: a.reshape((ngroups, pat) + a.shape[1:]), params_layers)
    gcache = None
    if carries_cache is not None:
        gcache = jax.tree.map(
            lambda a: a.reshape((ngroups, pat) + a.shape[1:]), carries_cache)

    def body(carry, xs):
        if gcache is None:
            lp_grp = xs
        else:
            lp_grp, cache_grp = xs
        aux_tot = jnp.zeros((), jnp.float32)
        new_caches = []
        x = carry
        for j in range(pat):
            lp = jax.tree.map(lambda a: a[j], lp_grp)
            is_full = cfg.chunk_pattern and (j + 1) % pat == 0
            cache_j = (jax.tree.map(lambda a: a[j], cache_grp)
                       if gcache is not None else None)
            x, aux, nc = per_layer_fn(lp, x, bool(is_full), cache_j)
            aux_tot = aux_tot + aux
            if nc is not None:
                new_caches.append(nc)
        ys = aux_tot
        if new_caches:
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_caches)
            ys = (aux_tot, stacked)
        return x, ys

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = grouped if gcache is None else (grouped, gcache)
    x, ys = jax.lax.scan(body, init, xs)
    return x, ys


def forward(params, tokens, cfg: ModelConfig, extra_embeds=None):
    x = tfm.embed_tokens(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def per_layer(lp, x, is_full, _cache):
        window, chunk = ((cfg.sliding_window, None) if is_full
                         else (cfg.sliding_window, cfg.attention_chunk))
        x, aux = layer_apply(lp, x, positions, cfg, window=window, chunk=chunk)
        return x, aux, None

    x, aux = _grouped_scan(params["layers"], cfg, per_layer, x)
    return tfm.unembed(params, x, cfg), jnp.sum(aux)


def prefill(params, tokens, cfg: ModelConfig, extra_embeds=None,
            extra_capacity: int = 0):
    from repro.models import attention as attn
    x = tfm.embed_tokens(params, tokens, cfg, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    capacity = attn.cache_capacity(S + extra_capacity, cfg.sliding_window,
                                   cfg.attention_chunk)

    def per_layer(lp, x, is_full, _):
        window, chunk = ((cfg.sliding_window, None) if is_full
                         else (cfg.sliding_window, cfg.attention_chunk))
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, cache = tfm.attn_prefill(lp["attn"], h, positions, cfg, capacity,
                                    window=window, chunk=chunk)
        x = x + y
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = moe_apply(lp["moe"], h, cfg)
        return x + y, aux, cache

    x, (aux, caches) = _grouped_scan(params["layers"], cfg, per_layer, x)
    caches = jax.tree.map(
        lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), caches)
    return tfm.unembed(params, x[:, -1:, :], cfg), caches


def decode_step(params, token, pos, caches, cfg: ModelConfig):
    x = tfm.embed_tokens(params, token, cfg)

    def per_layer(lp, x, is_full, cache):
        window, chunk = ((cfg.sliding_window, None) if is_full
                         else (cfg.sliding_window, cfg.attention_chunk))
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, cache = tfm.attn_apply_decode(lp["attn"], h, cache, pos, cfg,
                                         window=window, chunk=chunk)
        x = x + y
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = moe_apply(lp["moe"], h, cfg)
        return x + y, aux, cache

    x, (_, caches) = _grouped_scan(params["layers"], cfg, per_layer, x,
                                   carries_cache=caches)
    caches = jax.tree.map(
        lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), caches)
    return tfm.unembed(params, x, cfg), caches


init_decode_caches = tfm.init_decode_caches
