"""Fleet-level analytics over ``SimReport``.

Pure reductions — no simulator state — so they apply equally to a
monolithic ``SimScheduler`` report and a sharded ``FleetResult.report``:

- :func:`percentile` / :func:`jct_stats`: distribution summaries with
  linear interpolation (numpy-free; the sim layer stays stdlib-only).
- :func:`per_class_jct`: p50/p99 JCT per tenant class (default: the
  task's priority), the paper's hi-vs-lo protection evidence at scale.
- :func:`miss_rate_by_class`: deadline-miss counts and rates per class;
  points on a miss-rate-vs-load curve when swept over utilizations.
- :func:`utilization_histogram`: per-device utilization histogram —
  fleet imbalance at a glance.
- :func:`fleet_summary`: one JSON-ready dict combining all of the above
  (what ``benchmarks/bench_fleet.py`` emits per scenario).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.scheduler import SimReport
from repro.core.task import TaskSpec

__all__ = ["percentile", "jct_stats", "per_class_jct",
           "miss_rate_by_class", "utilization_histogram", "fleet_summary"]

ClassOf = Callable[[TaskSpec], object]


def _default_class(spec: TaskSpec) -> object:
    return spec.priority


def percentile(values: Sequence[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation; nan if empty."""
    if not values:
        return math.nan
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    s = sorted(values)
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * q / 100.0
    lo = int(pos)
    frac = pos - lo
    if lo + 1 >= len(s):
        return s[-1]
    return s[lo] * (1.0 - frac) + s[lo + 1] * frac


def jct_stats(values: Sequence[float]) -> Dict[str, float]:
    """count / mean / p50 / p99 / max summary of a JCT sample."""
    if not values:
        return {"count": 0, "mean": math.nan, "p50": math.nan,
                "p99": math.nan, "max": math.nan}
    return {"count": len(values),
            "mean": sum(values) / len(values),
            "p50": percentile(values, 50.0),
            "p99": percentile(values, 99.0),
            "max": max(values)}


def per_class_jct(specs: Sequence[TaskSpec], report: SimReport,
                  class_of: Optional[ClassOf] = None
                  ) -> Dict[object, Dict[str, float]]:
    """Per-class JCT distributions. Tasks that never completed
    (``completion < 0``, e.g. cancelled) are excluded."""
    class_of = class_of or _default_class
    buckets: Dict[object, List[float]] = {}
    for spec, res in zip(specs, report.results):
        if res is None or res.completion < 0:
            continue
        buckets.setdefault(class_of(spec), []).append(res.jct)
    return {c: jct_stats(v) for c, v in sorted(buckets.items(),
                                               key=lambda kv: str(kv[0]))}


def miss_rate_by_class(specs: Sequence[TaskSpec], report: SimReport,
                       class_of: Optional[ClassOf] = None
                       ) -> Dict[object, Dict[str, float]]:
    """Deadline tally per class: tagged / missed / miss_rate. Only
    deadline-tagged tasks count; classes with none are omitted."""
    class_of = class_of or _default_class
    tally: Dict[object, List[int]] = {}
    for spec, res in zip(specs, report.results):
        if spec.deadline is None or res is None:
            continue
        t = tally.setdefault(class_of(spec), [0, 0])
        t[0] += 1
        if res.completion < 0 or res.completion > spec.deadline:
            t[1] += 1
    return {c: {"tagged": tagged, "missed": missed,
                "miss_rate": missed / tagged}
            for c, (tagged, missed) in sorted(tally.items(),
                                              key=lambda kv: str(kv[0]))}


def utilization_histogram(report: SimReport, bins: int = 10
                          ) -> Dict[str, List[float]]:
    """Histogram of per-device utilization over [0, 1]: ``edges`` has
    ``bins + 1`` entries, ``counts`` has ``bins`` (devices above 1.0 —
    impossible for a serial timeline — clamp into the last bin)."""
    if bins <= 0:
        raise ValueError(f"need bins >= 1, got {bins}")
    utils = report.per_device_utilization()
    edges = [i / bins for i in range(bins + 1)]
    counts = [0] * bins
    for u in utils:
        counts[min(int(u * bins), bins - 1)] += 1
    return {"edges": edges, "counts": counts}


def fleet_summary(specs: Sequence[TaskSpec], report: SimReport,
                  class_of: Optional[ClassOf] = None,
                  bins: int = 10) -> Dict[str, object]:
    """JSON-ready rollup of one fleet scenario."""
    return {
        "tasks": len(specs),
        "devices": report.devices,
        "events": report.events,
        "makespan": report.makespan,
        "utilization": report.utilization(),
        "fills": report.fills,
        "steals": report.steals,
        "deadline_misses": report.deadline_misses,
        "deadlines_tagged": report.deadlines_tagged,
        "deadline_miss_rate": report.deadline_miss_rate,
        "jct_by_class": per_class_jct(specs, report, class_of),
        "miss_by_class": miss_rate_by_class(specs, report, class_of),
        "util_histogram": utilization_histogram(report, bins=bins),
    }
