"""Synthetic workload generation for cluster-scale simulation.

Real-time GPU scheduling work evaluates on periodic/sporadic task sets
sampled by total utilization (UUNIFAST, Bini & Buttazzo 2005); serving
work is judged on load-vs-latency curves over tenant classes driven by
arrival traces. This module produces both, deterministically by seed:

- :func:`uunifast` / :func:`uunifast_discard`: per-task utilization
  sampling summing exactly to a target, each share in ``(0, 1]``.
- :func:`periodic_taskset`: a :class:`TaskSet` of :class:`PeriodicTask`
  records — period drawn from an integer-millisecond grid (so the
  hyperperiod stays a small exact ``lcm``), WCET = u * period split into
  a kernel trace by a :class:`KernelShape`, priority assigned by bands.
- :func:`release_jobs`: expand a task set over a horizon (default one
  hyperperiod) into arrival-sorted ``TaskSpec`` job instances; periodic
  releases at ``phase + k * period``, or sporadic releases whose
  inter-arrival times are ``>= period`` (period = minimum separation).
- :func:`specs_from_arrivals` (+ :func:`poisson_trace` /
  :func:`diurnal_trace`): adapt ``serving/loadgen.py``'s seeded Poisson
  and diurnal schedules into ``TaskSpec`` lists for the simulator.

Every job instance of a task shares the task's (immutable) kernel list,
so a million-request trace does not materialise a million kernel lists.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

from repro.core.kernel_id import KernelID
from repro.core.task import NUM_PRIORITIES, TaskKey, TaskSpec, TraceKernel
from repro.serving.loadgen import (Arrival, diurnal_arrivals,
                                   poisson_arrivals)

__all__ = [
    "uunifast", "uunifast_discard", "hyperperiod_ms",
    "KernelShape", "DEFAULT_SHAPES", "shape_from_profile",
    "PeriodicTask", "TaskSet", "periodic_taskset", "release_jobs",
    "specs_from_arrivals", "poisson_trace", "diurnal_trace",
    "DEFAULT_PERIODS_MS", "DEFAULT_PRIORITY_BANDS",
]

#: Period grid (integer milliseconds). Chosen so the lcm over any subset
#: is at most 2000 ms — hyperperiod sweeps stay short and exact.
DEFAULT_PERIODS_MS: Tuple[int, ...] = (10, 20, 40, 50, 100, 200, 250, 500,
                                       1000)

#: (priority, weight) bands: the first ~20% of tasks are hi-priority
#: interactive tenants (Q0), the next 30% mid (Q4), the rest batch (Q8).
DEFAULT_PRIORITY_BANDS: Tuple[Tuple[int, float], ...] = ((0, 0.2), (4, 0.3),
                                                         (8, 0.5))


def _as_rng(seed_or_rng: Union[int, random.Random]) -> random.Random:
    if isinstance(seed_or_rng, random.Random):
        return seed_or_rng
    return random.Random(seed_or_rng)


def uunifast(n: int, total_util: float,
             seed_or_rng: Union[int, random.Random]) -> List[float]:
    """UUNIFAST: ``n`` utilizations summing to ``total_util``, uniformly
    distributed over the valid simplex. Individual shares may exceed 1
    when ``total_util > 1``; use :func:`uunifast_discard` to bound them.
    """
    if n <= 0:
        raise ValueError(f"need n >= 1 tasks, got {n}")
    if total_util <= 0:
        raise ValueError(f"need total_util > 0, got {total_util}")
    rng = _as_rng(seed_or_rng)
    utils: List[float] = []
    remaining = float(total_util)
    for i in range(n - 1, 0, -1):
        nxt = remaining * rng.random() ** (1.0 / i)
        utils.append(remaining - nxt)
        remaining = nxt
    utils.append(remaining)
    return utils


def _clamp_redistribute(utils: List[float]) -> List[float]:
    """Clamp shares above 1 to 1 and hand their excess to the others
    proportionally to remaining headroom. Feasible whenever
    ``sum(utils) <= n``; one proportional pass keeps every share <= 1
    (each receives at most its own headroom), iterated defensively for
    float rounding."""
    utils = list(utils)
    for _ in range(len(utils)):
        excess = 0.0
        free: List[int] = []
        for i, u in enumerate(utils):
            if u > 1.0:
                excess += u - 1.0
                utils[i] = 1.0
            elif u < 1.0:
                free.append(i)
        if excess <= 0.0 or not free:
            break
        headroom = sum(1.0 - utils[i] for i in free)
        for i in free:
            utils[i] += excess * (1.0 - utils[i]) / headroom
    return utils


def uunifast_discard(n: int, total_util: float,
                     seed_or_rng: Union[int, random.Random],
                     max_tries: int = 50) -> List[float]:
    """UUNIFAST with discard-resampling: every share lies in ``(0, 1]``.

    Requires ``total_util <= n`` (otherwise no valid assignment exists).
    Resamples whole vectors until one qualifies. Near saturation
    (``total_util`` -> ``n``) the accept probability of a raw UUNIFAST
    draw collapses — P(max Dirichlet spacing <= 1/U) is astronomically
    small already at ``U ~ 0.8 n`` for moderate ``n`` — so after
    ``max_tries`` discards the last draw is repaired deterministically
    by clamp-and-redistribute (slightly biased toward uniform shares,
    exactly feasible, still a pure function of the seed).
    """
    if total_util > n:
        raise ValueError(f"total_util {total_util} infeasible for {n} tasks")
    rng = _as_rng(seed_or_rng)
    utils: List[float] = []
    for _ in range(max_tries):
        utils = uunifast(n, total_util, rng)
        if all(0.0 < u <= 1.0 for u in utils):
            return utils
    return _clamp_redistribute(utils)


def hyperperiod_ms(periods_ms: Sequence[int]) -> int:
    """Exact hyperperiod (lcm) of integer-millisecond periods."""
    if not periods_ms:
        return 0
    h = 1
    for p in periods_ms:
        if int(p) != p or p <= 0:
            raise ValueError(f"periods must be positive integers (ms): {p}")
        h = math.lcm(h, int(p))
    return h


@dataclass(frozen=True)
class KernelShape:
    """How a task's WCET is split into a kernel trace.

    ``n_kernels`` kernels whose durations are drawn with multiplicative
    spread ``+-spread`` around equal shares (then renormalised so the
    kernel durations sum exactly to the compute budget); each kernel is
    followed by a host gap of ``gap_fraction`` of its duration (the last
    gap does not count toward solo JCT). ``max_inflight`` models the
    client: 1 = synchronous, >1 = CUDA-style async launch-ahead.
    """
    name: str
    n_kernels: int
    gap_fraction: float = 0.1
    spread: float = 0.5
    max_inflight: int = 1
    kclass_cycle: Tuple[Optional[str], ...] = (None,)

    def synthesize(self, wcet_s: float,
                   rng: random.Random) -> List[TraceKernel]:
        """Split ``wcet_s`` of solo JCT into a deterministic kernel list."""
        n = self.n_kernels
        if n <= 0:
            raise ValueError(f"shape {self.name}: need n_kernels >= 1")
        weights = [rng.uniform(1.0 - self.spread, 1.0 + self.spread)
                   for _ in range(n)]
        # solo JCT = sum(dur_i * (1 + gap_fraction)) - last gap
        budget = wcet_s / (1.0 + self.gap_fraction
                           - self.gap_fraction * weights[-1] / sum(weights))
        scale = budget / sum(weights)
        out: List[TraceKernel] = []
        for i, w in enumerate(weights):
            dur = w * scale
            out.append(TraceKernel(
                kid=KernelID(f"{self.name}_k{i}", grid=(n,), block=(i,)),
                duration=dur,
                gap_after=dur * self.gap_fraction,
                kclass=self.kclass_cycle[i % len(self.kclass_cycle)]))
        return out


#: Shapes mirroring the profiled model families used by the benchmarks:
#: short interactive decode steps vs. long memory-heavy batch pipelines.
DEFAULT_SHAPES: Tuple[KernelShape, ...] = (
    KernelShape("interactive", n_kernels=6, gap_fraction=0.15, spread=0.4,
                max_inflight=1,
                kclass_cycle=("compute", "compute", "memory")),
    KernelShape("batch", n_kernels=12, gap_fraction=0.05, spread=0.6,
                max_inflight=4,
                kclass_cycle=("memory", "compute")),
)


def shape_from_profile(profile, name: Optional[str] = None,
                       max_inflight: int = 1) -> KernelShape:
    """Derive a :class:`KernelShape` from a profiled ``TaskProfile``
    (its SK/SG tables): kernel count, mean gap/duration ratio and the
    empirical duration spread, so synthetic fleets inherit the shape of
    real measured models."""
    if not profile.SK:
        raise ValueError("profile has no SK entries")
    durs = list(profile.SK.values())
    gaps = [profile.SG.get(k, 0.0) for k in profile.SK]
    mean = sum(durs) / len(durs)
    spread = min(0.95, (max(durs) - min(durs)) / (2.0 * mean)) if mean else 0.0
    gap_fraction = (sum(gaps) / sum(durs)) if sum(durs) else 0.0
    return KernelShape(name=name or profile.key.process,
                       n_kernels=len(durs), gap_fraction=gap_fraction,
                       spread=spread, max_inflight=max_inflight)


@dataclass(frozen=True)
class PeriodicTask:
    """One recurring task of a synthetic task set."""
    index: int
    key: TaskKey
    priority: int
    utilization: float
    period_ms: int
    phase_s: float
    wcet_s: float
    kernels: Tuple[TraceKernel, ...]
    max_inflight: int = 1
    #: relative deadline (seconds after each release); implicit = period.
    rel_deadline_s: float = 0.0

    @property
    def period_s(self) -> float:
        return self.period_ms / 1000.0


@dataclass(frozen=True)
class TaskSet:
    """A sampled task set plus the parameters that reproduce it."""
    tasks: Tuple[PeriodicTask, ...]
    total_util: float
    seed: int

    @property
    def hyperperiod_ms(self) -> int:
        return hyperperiod_ms([t.period_ms for t in self.tasks])

    @property
    def hyperperiod_s(self) -> float:
        return self.hyperperiod_ms / 1000.0

    def utilization(self) -> float:
        return sum(t.utilization for t in self.tasks)


def _band_priority(i: int, n: int,
                   bands: Sequence[Tuple[int, float]]) -> int:
    """Deterministic band assignment by index proportion: the first
    ``weight`` fraction of tasks gets the first band's priority, etc."""
    total = sum(w for _, w in bands)
    frac = (i + 0.5) / n
    cum = 0.0
    for prio, w in bands:
        cum += w / total
        if frac <= cum:
            return prio
    return bands[-1][0]


def periodic_taskset(n: int, total_util: float, seed: int, *,
                     periods_ms: Sequence[int] = DEFAULT_PERIODS_MS,
                     priority_bands: Sequence[Tuple[int, float]]
                     = DEFAULT_PRIORITY_BANDS,
                     shapes: Sequence[KernelShape] = DEFAULT_SHAPES,
                     phase_jitter: float = 0.0,
                     name: str = "synth") -> TaskSet:
    """Sample a periodic task set: UUNIFAST utilizations, log-uniform
    period from the integer grid, WCET = u * period synthesised into a
    kernel trace by an alternating shape, priority by index bands.
    Fully deterministic given ``seed``."""
    rng = random.Random(seed)
    utils = uunifast_discard(n, total_util, rng)
    for prio, _ in priority_bands:
        if not 0 <= prio < NUM_PRIORITIES:
            raise ValueError(f"band priority {prio} out of range")
    log_periods = sorted(periods_ms)
    tasks: List[PeriodicTask] = []
    for i, u in enumerate(utils):
        # log-uniform pick over the grid biases toward shorter periods,
        # matching interactive-heavy tenant mixes.
        pick = int(len(log_periods) * rng.random() ** 1.5)
        period_ms = log_periods[min(pick, len(log_periods) - 1)]
        wcet_s = u * period_ms / 1000.0
        shape = shapes[i % len(shapes)]
        kernels = tuple(shape.synthesize(wcet_s, rng))
        phase = rng.uniform(0.0, phase_jitter * period_ms / 1000.0)
        tasks.append(PeriodicTask(
            index=i,
            key=TaskKey(f"{name}_{shape.name}", args=(i,)),
            priority=_band_priority(i, n, priority_bands),
            utilization=u, period_ms=period_ms, phase_s=phase,
            wcet_s=wcet_s, kernels=kernels,
            max_inflight=shape.max_inflight,
            rel_deadline_s=period_ms / 1000.0))
    return TaskSet(tasks=tuple(tasks), total_util=total_util, seed=seed)


def release_jobs(taskset: TaskSet, *, cycles: int = 1,
                 horizon_s: Optional[float] = None, sporadic: bool = False,
                 sporadic_slack: float = 0.5,
                 seed: Optional[int] = None,
                 tag_deadlines: bool = True) -> List[TaskSpec]:
    """Expand a task set into arrival-sorted ``TaskSpec`` job instances.

    Horizon defaults to ``cycles`` hyperperiods. Periodic tasks release
    at ``phase + k * period``; with ``sporadic=True`` the period becomes
    the *minimum* inter-arrival time and each successive gap is
    ``period + Exp(mean = sporadic_slack * period)`` (seeded by ``seed``,
    default the task set's own seed). Deadlines are absolute
    (``release + rel_deadline``) when ``tag_deadlines``.
    """
    if horizon_s is None:
        horizon_s = taskset.hyperperiod_s * cycles
    rng = random.Random(taskset.seed if seed is None else seed)
    jobs: List[TaskSpec] = []
    for t in taskset.tasks:
        kernels = list(t.kernels)  # one shared list per task, not per job
        rel = t.rel_deadline_s if tag_deadlines else None
        arr = t.phase_s
        while arr < horizon_s:
            jobs.append(TaskSpec(
                key=t.key, priority=t.priority, kernels=kernels,
                arrival=arr, max_inflight=t.max_inflight,
                deadline=(arr + rel) if rel is not None else None))
            if sporadic:
                arr += t.period_s + rng.expovariate(
                    1.0 / (sporadic_slack * t.period_s))
            else:
                arr += t.period_s
    jobs.sort(key=lambda s: s.arrival)
    return jobs


# ---------------------------------------------------------------------------
# Arrival-trace synthesis (reuses serving/loadgen schedules)
# ---------------------------------------------------------------------------

def specs_from_arrivals(schedule: Sequence[Arrival],
                        template_of: Optional[Callable[[Arrival],
                                                       TaskSpec]] = None
                        ) -> List[TaskSpec]:
    """Turn a loadgen schedule into simulator jobs.

    Each ``Arrival.service`` must be a ``TaskSpec`` template (or
    ``template_of(arrival)`` must produce one). The template's kernels
    are shared across instances; ``Arrival.deadline`` — a *relative*
    per-request override in loadgen — becomes an absolute sim deadline.
    """
    out: List[TaskSpec] = []
    for a in sorted(schedule, key=lambda a: a.t):
        tpl = template_of(a) if template_of is not None else a.service
        if not isinstance(tpl, TaskSpec):
            raise TypeError(f"arrival service is not a TaskSpec: {tpl!r}")
        if a.deadline is not None:
            deadline = a.t + a.deadline
        elif tpl.deadline is not None:
            deadline = a.t + tpl.deadline
        else:
            deadline = None
        out.append(TaskSpec(key=tpl.key, priority=tpl.priority,
                            kernels=tpl.kernels, arrival=a.t,
                            max_inflight=tpl.max_inflight,
                            deadline=deadline))
    return out


def poisson_trace(template: TaskSpec, rate: float, duration: float,
                  seed: int, deadline: Optional[float] = None,
                  qos: str = "default") -> List[TaskSpec]:
    """Seeded homogeneous-Poisson job trace for one service template."""
    sched = poisson_arrivals(rate, duration, template, qos,
                             random.Random(seed), deadline=deadline)
    return specs_from_arrivals(sched)


def diurnal_trace(template: TaskSpec, base_rate: float, duration: float,
                  seed: int, period: Optional[float] = None,
                  depth: float = 0.5, deadline: Optional[float] = None,
                  qos: str = "default") -> List[TaskSpec]:
    """Seeded diurnal (thinned non-homogeneous Poisson) job trace."""
    sched = diurnal_arrivals(base_rate, duration, template, qos,
                             random.Random(seed), period=period,
                             depth=depth, deadline=deadline)
    return specs_from_arrivals(sched)
