"""Cluster-scale simulation layer: synthetic workload generation
(`workload`), sharded fleet execution behind the placement seam (`fleet`)
and fleet-level analytics over `SimReport` (`analytics`)."""
