"""Sharded fleet simulation behind the placement seam.

A K-device ``SimScheduler`` with a *static* placement discipline and
work-stealing off is embarrassingly parallel: once each task's device is
known up front, the fleet factorises into K independent single-device
simulations — no event on one device can influence another (no steal
migration, no cross-device load state, and the per-device decision
sequence is a function of that device's task subset alone). This module
exploits that: :func:`elect_devices` reproduces the monolithic layer's
election statically, :func:`simulate_fleet` runs one K=1 subsimulation
per device (optionally across process workers) and merges the results
into a single ``SimReport`` whose decision traces are **identical** to
the monolithic run after remapping shard-local instance ids to global
ones (pinned by ``tests/test_sim_fastcore.py``).

**No-coupling rule** (the module's one load-bearing assumption): a
shard may depend on nothing outside its own task subset. Any feature
that lets one device's events influence another — dynamic election,
steal migration, a shared RNG stream, a shared mutable collaborator —
is coupling, and coupled configurations must be **rejected eagerly**
(raise at ``simulate_fleet`` entry), never sharded approximately. The
concrete rejections below are instances of this rule; when extending
the fleet runner, add the check rather than weakening the guarantee.

Equivalence contract — the sharded run matches the monolithic K-device
run bit-for-bit only when:

- the discipline is static (``round_robin`` / ``priority_affinity`` / a
  ``fn(index, spec, devices)`` callable) — ``least_loaded`` consults
  global load and is rejected;
- ``steal=False`` (migration couples devices);
- ``jitter == 0`` — with noise the monolithic run interleaves one RNG
  stream across devices while shards each draw their own;
- shared mutable collaborators (``online=``, ``interference=``,
  ``jobstore=``) are absent — each shard would otherwise need its own.

Outside that envelope, run the monolithic ``SimScheduler`` instead; the
fleet runner raises rather than silently diverging.
"""
from __future__ import annotations

import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

from repro.core.policy import Mode
from repro.core.scheduler import KernelExec, SimReport, SimScheduler
from repro.core.task import NUM_PRIORITIES, TaskSpec

__all__ = ["elect_devices", "simulate_fleet", "FleetResult",
           "STATIC_DISCIPLINES"]

#: Disciplines whose election is a pure function of (arrival order,
#: priority) — reproducible without running the simulation.
STATIC_DISCIPLINES: Tuple[str, ...] = ("round_robin", "priority_affinity")

StaticDiscipline = Union[str, Callable[[int, TaskSpec, int], int]]


def elect_devices(tasks: Sequence[TaskSpec], devices: int,
                  discipline: StaticDiscipline = "round_robin"
                  ) -> List[int]:
    """Statically reproduce ``PlacementLayer`` election for each task.

    ``round_robin`` rotates in arrival-event order — the order the
    simulator's event heap delivers ``task_begin`` calls: ascending
    ``(arrival, submission index)``. ``priority_affinity`` is stateless
    (``priority * K // NUM_PRIORITIES``). A callable gets
    ``(index, spec, devices)`` and must return a device in range.
    """
    if devices <= 0:
        raise ValueError(f"need devices >= 1, got {devices}")
    n = len(tasks)
    out = [0] * n
    if callable(discipline):
        for i, t in enumerate(tasks):
            d = discipline(i, t, devices)
            if not 0 <= d < devices:
                raise ValueError(f"custom discipline placed task {i} on "
                                 f"device {d} of {devices}")
            out[i] = d
    elif discipline == "round_robin":
        order = sorted(range(n), key=lambda i: (tasks[i].arrival, i))
        for pos, i in enumerate(order):
            out[i] = pos % devices
    elif discipline == "priority_affinity":
        for i, t in enumerate(tasks):
            out[i] = t.priority * devices // NUM_PRIORITIES
    else:
        raise ValueError(
            f"discipline {discipline!r} is not statically electable "
            f"(static: {STATIC_DISCIPLINES} or a callable); use the "
            f"monolithic SimScheduler for dynamic disciplines")
    return out


@dataclass
class FleetResult:
    """Merged outcome of a sharded fleet run.

    ``report`` mirrors the monolithic K-device ``SimReport``: global
    task order, summed counters, per-device ``busy`` accumulators.
    ``traces[d]`` is device ``d``'s decision trace with instance ids
    remapped to global task indices; ``device_of[i]`` is task ``i``'s
    elected device; ``shards[d]`` lists the global indices simulated on
    device ``d``. ``wall_s`` is the end-to-end wall-clock cost
    (including election, sharding and merging).
    """
    report: SimReport
    device_of: List[int]
    shards: List[List[int]]
    traces: List[list] = field(default_factory=list)
    wall_s: float = 0.0


def _remap_trace(trace: Sequence[tuple], to_global: Sequence[int]) -> list:
    """Rewrite shard-local instance ids (tuple index 1; ``holder`` may
    carry None) to global task indices."""
    out = []
    for ev in trace:
        inst = ev[1]
        out.append((ev[0],
                    inst if inst is None else to_global[inst]) + ev[2:])
    return out


def _run_shard(payload):
    tasks, mode, kwargs = payload
    sim = SimScheduler(tasks, mode, devices=1, **kwargs)
    report = sim.run()
    return report, list(sim.placement.policies[0].trace)


def simulate_fleet(tasks: Sequence[TaskSpec], mode: Mode, *,
                   devices: int,
                   discipline: StaticDiscipline = "round_robin",
                   workers: int = 1,
                   trace: str = "off",
                   record_timeline: bool = False,
                   **sim_kwargs) -> FleetResult:
    """Run ``tasks`` over a ``devices``-GPU fleet as sharded K=1 sims.

    ``workers > 1`` fans the shards across a process pool (shards and
    reports pickle cleanly; ``KernelID`` interning survives the round
    trip). Remaining ``sim_kwargs`` forward to each ``SimScheduler``
    (``profiled=``, ``queue_discipline=``, ``pipeline_depth=``, ...);
    kwargs that break the sharding equivalence contract are rejected.
    Defaults favour scale: traces and timelines off.
    """
    for bad in ("devices", "steal", "jobstore", "fault_plan", "online",
                "interference", "jitter"):
        if sim_kwargs.get(bad):
            raise ValueError(f"simulate_fleet does not support {bad}= "
                             f"(breaks the sharding equivalence contract)")
        sim_kwargs.pop(bad, None)
    t0 = time.perf_counter()
    device_of = elect_devices(tasks, devices, discipline)
    shards: List[List[int]] = [[] for _ in range(devices)]
    for i, d in enumerate(device_of):
        shards[d].append(i)
    kwargs = dict(sim_kwargs, trace=trace, record_timeline=record_timeline)
    payloads = [([tasks[i] for i in shard], mode, kwargs)
                for shard in shards]
    if workers > 1:
        with ProcessPoolExecutor(max_workers=workers) as pool:
            outs = list(pool.map(_run_shard, payloads, chunksize=1))
    else:
        outs = [_run_shard(p) for p in payloads]

    results = [None] * len(tasks)
    timeline: list = []
    traces: List[list] = []
    busy = [0.0] * devices
    fills = steals = misses = tagged = events = 0
    overshoot = 0.0
    for d, (rep, tr) in enumerate(outs):
        shard = shards[d]
        for li, r in enumerate(rep.results):
            results[shard[li]] = r
        for k in rep.timeline:
            # relabel the shard's device 0 as fleet device d and its
            # local task ids as global indices
            timeline.append(KernelExec(task=shard[k.task], seq=k.seq,
                                       start=k.start, end=k.end,
                                       filler=k.filler, device=d))
        traces.append(_remap_trace(tr, shard))
        busy[d] = (rep.busy[0] if rep.busy else rep.device_busy())
        fills += rep.fills
        steals += rep.steals
        misses += rep.deadline_misses
        tagged += rep.deadlines_tagged
        events += rep.events
        overshoot += rep.overshoot_time
    timeline.sort(key=lambda k: (k.start, k.device))
    report = SimReport(results=results, timeline=timeline, fills=fills,
                       overshoot_time=overshoot, devices=devices,
                       steals=steals, deadline_misses=misses,
                       deadlines_tagged=tagged, events=events, busy=busy)
    return FleetResult(report=report, device_of=device_of, shards=shards,
                       traces=traces, wall_s=time.perf_counter() - t0)
