"""Deterministic synthetic data pipeline for training runs.

Generates seeded token streams with a Zipfian-ish marginal + local structure
(n-gram echo) so that a small LM actually has something learnable, plus
next-token labels. Double-buffered host-side prefetch thread so the train
loop never waits on generation.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class TokenBatch:
    tokens: np.ndarray    # [B, S] int32
    labels: np.ndarray    # [B, S] int32 (next token, last = first)


class SyntheticTextPipeline:
    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0,
                 zipf_a: float = 1.2, echo_prob: float = 0.3,
                 prefetch: int = 2):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.zipf_a = zipf_a
        self.echo_prob = echo_prob
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._step = 0

    # ----------------------------------------------------------- generation
    def _gen(self, step: int) -> TokenBatch:
        rng = np.random.default_rng((self.seed, step))
        # zipf marginal truncated to vocab
        z = rng.zipf(self.zipf_a, size=(self.batch, self.seq))
        toks = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        # local structure: with prob echo_prob, token t = token t-2
        echo = rng.random((self.batch, self.seq)) < self.echo_prob
        toks[:, 2:] = np.where(echo[:, 2:], toks[:, :-2], toks[:, 2:])
        labels = np.roll(toks, -1, axis=1)
        return TokenBatch(toks, labels)

    def __iter__(self) -> Iterator[TokenBatch]:
        return self

    def __next__(self) -> TokenBatch:
        if self._thread is None:
            b = self._gen(self._step)
            self._step += 1
            return b
        return self._q.get()

    # ------------------------------------------------------------- prefetch
    def start(self) -> "SyntheticTextPipeline":
        def loop():
            step = 0
            while not self._stop:
                try:
                    self._q.put(self._gen(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop = True
        while not self._q.empty():
            self._q.get_nowait()
