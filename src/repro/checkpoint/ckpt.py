"""Checkpointing: pytree -> msgpack (+ atomic rename), with dtype/shape
round-trip including bfloat16. No external deps beyond msgpack + numpy.
"""
from __future__ import annotations

import os
import tempfile
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    a = np.asarray(jax.device_get(x))
    if a.dtype == jnp.bfloat16:
        return {"d": "bfloat16", "s": list(a.shape),
                "b": a.view(np.uint16).tobytes()}
    return {"d": a.dtype.name, "s": list(a.shape), "b": a.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    if d["d"] == "bfloat16":
        a = np.frombuffer(d["b"], np.uint16).reshape(d["s"])
        return a.view(jnp.bfloat16)
    return np.frombuffer(d["b"], np.dtype(d["d"])).reshape(d["s"])


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [_pack_leaf(l) for l in leaves],
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree.flatten(like)
    stored = payload["leaves"]
    if len(stored) != len(leaves):
        raise ValueError(f"leaf count mismatch: ckpt {len(stored)} "
                         f"vs target {len(leaves)}")
    out = []
    for tgt, d in zip(leaves, stored):
        arr = _unpack_leaf(d)
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch {arr.shape} vs {tgt.shape}")
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), payload["step"]
