"""Step builders: train_step / prefill_step / serve_step as jit-able
functions with input specs (ShapeDtypeStructs) and shardings per
(architecture x input shape x mesh).

Used by the dry-run (lower+compile only) and by the real train/serve
drivers at reduced scale.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ENCDEC, VLM, InputShape, ModelConfig
from repro.models import api
from repro.optim.adamw import adamw_init, adamw_update
from repro.sharding import specs as sh
from repro.sharding.context import mesh_context


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocate)
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, B: int, S: int) -> Any:
    """Model-input stand-ins for a full sequence (train / prefill)."""
    if cfg.family == ENCDEC:
        return (_sds((B, cfg.encoder_frames, cfg.d_model), cfg.dtype),
                _sds((B, S), jnp.int32))
    if cfg.family == VLM:
        st = max(S - cfg.num_patches, 1)
        return (_sds((B, cfg.num_patches, cfg.d_model), cfg.dtype),
                _sds((B, st), jnp.int32))
    return _sds((B, S), jnp.int32)


def batch_in_specs(cfg: ModelConfig, mesh, B: int):
    if cfg.family in (ENCDEC, VLM):
        return (sh.embeds_spec(mesh, B), sh.token_spec(mesh, B))
    return sh.token_spec(mesh, B)


def label_specs(cfg: ModelConfig, B: int, S: int):
    # labels cover the full (possibly patch/frame-prefixed) logit stream;
    # the train step truncates to the logits length.
    return _sds((B, S), jnp.int32)


def params_specs(cfg: ModelConfig) -> Any:
    return api.build_params(cfg, key=None)   # SDS tree


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------
ACT_BUDGET_BYTES = 2 << 30   # residual-carry budget per device


def default_grad_accum(cfg: ModelConfig, mesh, shape: InputShape) -> int:
    """Microbatch count: smallest power-of-2 A such that the per-device
    layer-boundary residuals (L x (B/shards/A) x S x D x 2B) fit the
    activation budget, with (B/A) still divisible by the batch shards."""
    B, S = shape.global_batch, shape.seq_len
    nsh = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            nsh *= mesh.shape[a]
    L = cfg.num_layers
    A = 1
    while True:
        act = L * (B // nsh / A) * S * cfg.d_model * 2
        if act <= ACT_BUDGET_BYTES or A * 2 > B // nsh:
            return A
        A *= 2


def _split_micro(tree, A):
    return jax.tree.map(
        lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]), tree)


def make_train_step(cfg: ModelConfig, mesh, shape: InputShape,
                    grad_accum: int = 0, moments_dtype=None,
                    zero_pod: bool = False):
    """moments_dtype / zero_pod are the H1 levers (EXPERIMENTS.md §Perf):
    bf16 optimizer moments and ZeRO-style moment sharding across the pod
    axis (pods are otherwise pure DP replicas of the optimizer state)."""
    import jax.numpy as _jnp
    B, S = shape.global_batch, shape.seq_len
    A = grad_accum or default_grad_accum(cfg, mesh, shape)
    params_sds = params_specs(cfg)
    opt_sds = adamw_init(params_sds,
                         moments_dtype=moments_dtype or _jnp.float32)
    p_spec = sh.param_specs(params_sds, mesh)
    o_spec = sh.opt_specs(opt_sds, p_spec)
    if zero_pod and "pod" in mesh.axis_names:
        o_spec = sh.opt_specs(opt_sds, p_spec, zero_axis="pod",
                              params=params_sds, mesh=mesh)
    batch_sds = batch_specs(cfg, B, S)
    lbl_sds = label_specs(cfg, B, S)
    b_spec = batch_in_specs(cfg, mesh, B)
    l_spec = sh.token_spec(mesh, B)

    def train_step(params, opt_state, batch, labels):
        # mesh_context at trace time: model code (MoE shard_map, sharding
        # constraints inside scan bodies) reads the mesh from context.
        with mesh_context(mesh):
            def loss(p, b, l):
                logits, aux = api.forward(p, b, cfg)
                L_ = logits.shape[1]
                return api.loss_fn(logits, l[:, :L_], aux)

            if A == 1:
                lval, grads = jax.value_and_grad(loss)(params, batch, labels)
            else:
                mb = _split_micro(batch, A)
                ml = _split_micro(labels, A)
                mbax = sh.batch_axes(mesh)

                def constrain_mb(x):
                    from jax.sharding import NamedSharding
                    spec = P(*((None, mbax) + (None,) * (x.ndim - 2)))
                    return jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, spec))

                mb = jax.tree.map(constrain_mb, mb)
                ml = jax.tree.map(constrain_mb, ml)

                def micro(acc, xs):
                    b, l = xs
                    lv, g = jax.value_and_grad(loss)(params, b, l)
                    acc = jax.tree.map(
                        lambda a, gi: a + (gi / A).astype(a.dtype), acc, g)
                    return acc, lv

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                  params)
                grads, lvals = jax.lax.scan(micro, g0, (mb, ml))
                lval = jnp.mean(lvals)
            new_params, new_opt, metrics = adamw_update(grads, opt_state,
                                                        params)
            metrics["loss"] = lval
            return new_params, new_opt, metrics

    ns = partial(NamedSharding, mesh)
    jitted = jax.jit(
        train_step,
        in_shardings=(jax.tree.map(ns, p_spec), jax.tree.map(ns, o_spec),
                      jax.tree.map(ns, b_spec) if isinstance(b_spec, tuple)
                      else ns(b_spec), ns(l_spec)),
        out_shardings=(jax.tree.map(ns, p_spec), jax.tree.map(ns, o_spec),
                       None),
        donate_argnums=(0, 1),
    )
    args = (params_sds, opt_sds, batch_sds, lbl_sds)
    return jitted, args


# ---------------------------------------------------------------------------
# prefill_step
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, mesh, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    params_sds = params_specs(cfg)
    p_spec = sh.param_specs(params_sds, mesh)
    batch_sds = batch_specs(cfg, B, S)
    b_spec = batch_in_specs(cfg, mesh, B)
    cache_sds = jax.eval_shape(
        lambda: api.init_decode_caches(cfg, B, S))
    c_spec = sh.cache_specs(cfg, cache_sds, mesh, B)

    def prefill_step(params, batch):
        with mesh_context(mesh):
            logits, caches = api.prefill(params, batch, cfg)
            return logits, caches

    ns = partial(NamedSharding, mesh)
    jitted = jax.jit(
        prefill_step,
        in_shardings=(jax.tree.map(ns, p_spec),
                      jax.tree.map(ns, b_spec) if isinstance(b_spec, tuple)
                      else ns(b_spec)),
        out_shardings=(ns(sh.logits_spec(mesh, B, cfg.vocab_size)),
                       jax.tree.map(ns, c_spec,
                                    is_leaf=lambda x: isinstance(x, P))),
    )
    return jitted, (params_sds, batch_sds)


# ---------------------------------------------------------------------------
# serve_step (decode): ONE token with a KV cache of seq_len
# ---------------------------------------------------------------------------
def make_serve_step(cfg: ModelConfig, mesh, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    params_sds = params_specs(cfg)
    p_spec = sh.param_specs(params_sds, mesh)
    cache_sds = jax.eval_shape(lambda: api.init_decode_caches(cfg, B, S))
    c_spec = sh.cache_specs(cfg, cache_sds, mesh, B)
    tok_sds = _sds((B, 1), jnp.int32)
    pos_sds = _sds((), jnp.int32)

    def serve_step(params, token, pos, caches):
        with mesh_context(mesh):
            logits, caches = api.decode_step(params, token, pos, caches, cfg)
            return logits, caches

    ns = partial(NamedSharding, mesh)
    c_shard = jax.tree.map(ns, c_spec, is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(
        serve_step,
        in_shardings=(jax.tree.map(ns, p_spec), ns(sh.token_spec(mesh, B)),
                      None, c_shard),
        out_shardings=(ns(sh.logits_spec(mesh, B, cfg.vocab_size)), c_shard),
        donate_argnums=(3,),
    )
    return jitted, (params_sds, tok_sds, pos_sds, cache_sds)


def make_step(cfg: ModelConfig, mesh, shape: InputShape):
    """Dispatch by shape kind. Returns (jitted_fn, example_args_sds).

    Env flags (EXPERIMENTS.md §Perf hillclimbs): REPRO_MOMENTS_BF16=1 uses
    bf16 optimizer moments; REPRO_ZERO_POD=1 shards moments across pods."""
    import os

    import jax.numpy as _jnp
    kw = {}
    if os.environ.get("REPRO_MOMENTS_BF16", "0") == "1":
        kw["moments_dtype"] = _jnp.bfloat16
    if os.environ.get("REPRO_ZERO_POD", "0") == "1":
        kw["zero_pod"] = True
    if os.environ.get("REPRO_GRAD_ACCUM"):
        kw["grad_accum"] = int(os.environ["REPRO_GRAD_ACCUM"])
    with mesh_context(mesh):
        if shape.kind == "train":
            return make_train_step(cfg, mesh, shape, **kw)
        if shape.kind == "prefill":
            return make_prefill_step(cfg, mesh, shape)
        return make_serve_step(cfg, mesh, shape)
