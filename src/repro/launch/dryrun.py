import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is the actual dry-run driver.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and dump the roofline
inputs (FLOPs, bytes, per-collective traffic) as JSON.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402

from repro.config import SHAPES, get_config          # noqa: E402
from repro.launch.hlo_cost import (                   # noqa: E402
    bytes_accessed_corrected, collective_bytes_corrected,
    cost_analysis_dict, dot_flops_corrected)
from repro.configs import ARCH_IDS                   # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.launch.steps import make_step             # noqa: E402

# (arch, shape) combos excluded from long_500k: pure full-attention archs
# (quadratic decode state) — documented in DESIGN.md §Arch-applicability.
LONG_SKIP = {
    "stablelm-1.6b": "full attention, no sub-quadratic variant",
    "granite-20b": "full attention, no sub-quadratic variant",
    "qwen3-4b": "full attention, no sub-quadratic variant",
    "deepseek-v2-236b": "full MLA attention, no sub-quadratic variant",
    "seamless-m4t-medium": "enc-dec with full decoder attention",
}


def combos():
    for arch in ARCH_IDS:
        for sname in SHAPES:
            if sname == "long_500k" and arch in LONG_SKIP:
                continue
            yield arch, sname


# ---------------------------------------------------------------------------
# Collective traffic: parse the HLO and sum operand bytes per collective op.
# ---------------------------------------------------------------------------
_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|u32|s8|u8|s64|u64|pred|s16|u16)"
                       r"\[([\d,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "s64": 8, "u64": 8, "pred": 1, "s16": 2,
                "u16": 2}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum of OUTPUT shape bytes per collective kind (per-device program)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(3)
        nbytes = _shape_bytes(m.group(2))
        out[kind] = out.get(kind, 0) + nbytes
    # ignore -done duplicates: the regex matches both start and done lines;
    # conservatively halve pairs by matching only '-start' when present
    starts = len(re.findall(r"-start\(", hlo_text))
    return out, starts


def run_one(arch: str, sname: str, multi_pod: bool = False,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[sname]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        jitted, args = make_step(cfg, mesh, shape)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll, _ = collective_bytes(hlo)
    # trip-count-corrected totals (XLA cost analysis visits loop bodies
    # only once; see repro.launch.hlo_cost)
    coll_c = collective_bytes_corrected(hlo)
    flops_c = dot_flops_corrected(hlo)
    bytes_c = bytes_accessed_corrected(hlo)
    rec = {
        "arch": arch,
        "shape": sname,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": mesh.size,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "flops_corrected": flops_c,
        "bytes_corrected": bytes_c,
        "collective_bytes_corrected": coll_c,
        "mem": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "output_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
    }
    if verbose:
        print(f"[dryrun] {arch} x {sname} on {rec['mesh']}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['mem']['argument_bytes']/2**30:.2f}GiB "
              f"out={rec['mem']['output_bytes']/2**30:.2f}GiB "
              f"temp={rec['mem']['temp_bytes']/2**30:.2f}GiB")
        print(f"  cost_analysis: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e}")
        print(f"  corrected:     flops={flops_c:.3e} bytes={bytes_c:.3e}")
        print(f"  collectives (corrected): "
              f"{ {k: round(v/2**30, 2) for k, v in coll_c.items()} } GiB")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on the single-pod mesh")
    ap.add_argument("--all-multipod", action="store_true",
                    help="run every (arch x shape) on the 2x16x16 mesh")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args(argv)

    records = []
    if args.all or args.all_multipod:
        todo = [(a, s, args.all_multipod) for a, s in combos()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        if args.shape == "long_500k" and args.arch in LONG_SKIP:
            print(f"[dryrun] SKIP {args.arch} x long_500k: "
                  f"{LONG_SKIP[args.arch]}")
            return 0
        todo = [(args.arch, args.shape, args.multi_pod)]

    def save(recs):
        if not args.out or not recs:
            return
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        keyed = {(r["arch"], r["shape"], r["mesh"]): r for r in existing}
        for r in recs:
            keyed[(r["arch"], r["shape"], r["mesh"])] = r
        with open(args.out, "w") as f:
            json.dump(list(keyed.values()), f, indent=1)

    failures = []
    for arch, sname, mp in todo:
        try:
            rec = run_one(arch, sname, multi_pod=mp)
            records.append(rec)
            save([rec])         # incremental: survive interruption
        except Exception as e:  # noqa: BLE001
            failures.append((arch, sname, repr(e)))
            print(f"[dryrun] FAIL {arch} x {sname}: {e}")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES")
        return 1
    print(f"[dryrun] OK ({len(records)} combos)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
