"""Serving driver: hosts reduced-scale services on the FIKIT engine with
batched requests — the end-to-end serving example path, plus the ops
plane's operator CLI.

    # serve (the original flat invocation still works — "submit" is the
    # default verb):
    PYTHONPATH=src python -m repro.launch.serve \
        --high qwen3-4b --low mamba2-2.7b --mode fikit --requests 10 \
        --discipline sjf

    # durable serving + crash recovery:
    ... serve submit --jobstore /tmp/fikit.db --resume

    # operator verbs against a live serving process sharing the store
    # (each enqueues a control row the server's poller consumes; status
    # reads the store directly and needs no live server):
    ... serve status --jobstore /tmp/fikit.db
    ... serve cancel 3 --jobstore /tmp/fikit.db
    ... serve pause  3 --jobstore /tmp/fikit.db
    ... serve resume 3 --jobstore /tmp/fikit.db --device 1
    ... serve drain    --jobstore /tmp/fikit.db

    # open-loop traffic through the admission plane (Poisson arrivals,
    # optionally diurnal-modulated low-priority; per-QoS-class latency,
    # goodput, shed/reject counts):
    ... serve load --high qwen3-4b --low mamba2-2.7b \
        --rate 30 --duration 2 --deadline 0.5 --diurnal
"""
from __future__ import annotations

import argparse
import random
import statistics as st
import sys as _sys

from repro.core.jobstore import JobStore
from repro.core.queues import QUEUE_DISCIPLINES
from repro.core.scheduler import Mode
from repro.serving.loadgen import (diurnal_arrivals, merge_schedules,
                                   poisson_arrivals, replay)

# NOTE: repro.serving engine / repro.config imports (which pull in JAX
# and the model zoo) happen inside the commands that run models — the
# pure-store verbs (status, controls, workers) must start in
# milliseconds.


def serve_pair(high: str, low: str, mode: str = "fikit", requests: int = 8,
               measure_runs: int = 4, batch: int = 2, seq: int = 48,
               host_gap: float = 0.002, devices: int = 1,
               discipline: str = "fifo", deadline: float = None,
               online_measure: bool = False,
               jobstore: str = None, resume: bool = False,
               verbose: bool = True):
    """Host a high/low priority service pair on the wall-clock engine.

    ``discipline`` is the intra-device queue discipline ("fifo"/"sjf"/
    "edf"); ``deadline`` optionally gives every LOW-priority invocation a
    relative completion budget in seconds — the tag edf levels order by,
    and the source of the ``deadline_misses`` stat. ``online_measure``
    keeps refining SK/SG live during the sharing phase (EMA epochs +
    cold-start predictions; see ``repro.core.online``): the LOW service is
    then NOT onboarded offline — it starts cold and becomes gap-fillable
    from its own observed kernels, the scenario the offline two-phase
    design cannot serve.

    ``jobstore`` attaches the durable ops plane (a SQLite path): every
    invocation is recorded write-ahead and the operator verbs
    (cancel/pause/resume/drain, see ``main``) act on this run through
    the shared store; ``resume=True`` first re-runs every invocation a
    previous (killed) run left incomplete in the store."""
    from repro.config import get_config
    from repro.serving import InferenceService, ServingSystem
    hi = InferenceService(get_config(high).reduced(), priority=0,
                          batch=batch, seq=seq, host_gap=host_gap)
    lo = InferenceService(get_config(low).reduced(), priority=5,
                          batch=batch * 2, seq=seq)
    with ServingSystem(Mode(mode), measure_runs=measure_runs,
                       devices=devices,
                       queue_discipline=discipline,
                       online_measure=online_measure,
                       jobstore=jobstore) as sys_:
        meas_hi = sys_.onboard(hi)
        if online_measure:
            lo.svc.warmup()            # compile outside the timed phase
            meas_lo = []
        else:
            meas_lo = sys_.onboard(lo)
        recovered = sys_.recover([hi, lo]) if (resume and jobstore) else []
        res = sys_.invoke_concurrent([
            ("high", hi, requests, 0.0, 0.01),
            ("low", lo, requests, 0.0, 0.0, deadline),
        ])
        fills = sys_.engine.fill_count
        steals = sys_.engine.steal_count
        misses = sys_.deadline_misses
        tagged = sys_.deadlines_tagged
        cancelled = sys_.cancelled_invocations
    # read AFTER the context closes: stop() flushes the final partial epoch
    online_stats = sys_.online_stats
    out = {
        "mode": mode,
        "devices": devices,
        "discipline": discipline,
        "online_measure": online_measure,
        "measure_high_ms": 1e3 * st.mean(meas_hi),
        "measure_low_ms": 1e3 * st.mean(meas_lo) if meas_lo else 0.0,
        "high_jct_ms": 1e3 * st.mean(res["high"]),
        "low_jct_ms": 1e3 * st.mean(res["low"]),
        "high_jct_cv": (st.pstdev(res["high"]) / st.mean(res["high"])),
        "low_jct_cv": (st.pstdev(res["low"]) / st.mean(res["low"])),
        "fills": fills,
        "steals": steals,
        "deadline_misses": misses,
        "deadlines_tagged": tagged,
        "cancelled_invocations": cancelled,
    }
    if jobstore is not None:
        out["jobstore"] = jobstore
        out["recovered_jobs"] = len(recovered)
    if online_stats is not None:
        out["online_observations"] = online_stats["observations"]
        out["online_commits"] = online_stats["commits"]
        out["online_cold_observations"] = online_stats["cold_observations"]
        out["online_drift_rel_err"] = round(
            online_stats["drift_mean_rel_err"], 4)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v if isinstance(v, (str, int)) else round(v, 3)}")
    return out


def serve_load(high: str, low: str, mode: str = "fikit",
               rate: float = 20.0, duration: float = 2.0,
               hi_share: float = 0.3, deadline: float = None,
               diurnal: bool = False, speed: float = 1.0,
               measure_runs: int = 3, devices: int = 1, seed: int = 0,
               verbose: bool = True):
    """Open-loop traffic through the admission plane: the high service
    maps to the ``gold`` QoS class (FIKIT Q0), the low service to
    ``bronze`` (Q5). Arrivals are drawn up front (Poisson at ``rate``
    req/s total, split by ``hi_share``; ``diurnal=True`` modulates the
    bronze rate sinusoidally) and replayed without ever waiting on
    completions — offered load is independent of service capacity, so
    pushing ``rate`` past capacity exercises backpressure (rejects) and,
    with ``deadline`` set, SLO shedding. The measurement phase's JCTs
    prime the plane's service-time EMA, so shedding is informed from the
    first request."""
    from repro.config import get_config
    from repro.serving import InferenceService, QoSClass, ServingSystem
    hi = InferenceService(get_config(high).reduced(), priority=0,
                          batch=1, seq=32)
    lo = InferenceService(get_config(low).reduced(), priority=5,
                          batch=2, seq=32)
    classes = (QoSClass("gold", priority=0, queue_limit=64,
                        deadline=deadline, max_batch=4),
               QoSClass("bronze", priority=5, queue_limit=256,
                        deadline=None, max_batch=8))
    rng = random.Random(seed)
    with ServingSystem(Mode(mode), measure_runs=measure_runs,
                       devices=devices,
                       admission={"classes": classes}) as sys_:
        meas_hi = sys_.onboard(hi)
        meas_lo = sys_.onboard(lo)
        sys_.admission.note_latency(hi, st.mean(meas_hi))
        sys_.admission.note_latency(lo, st.mean(meas_lo))
        gen_lo = diurnal_arrivals if diurnal else poisson_arrivals
        sched = merge_schedules(
            poisson_arrivals(rate * hi_share, duration, hi, "gold", rng),
            gen_lo(rate * (1 - hi_share), duration, lo, "bronze", rng))
        rep = replay(sys_.admission, sched, speed=speed,
                     keep_tickets=False)
        sys_.admission.drain(timeout=120)
        stats = sys_.admission.stats()
    out = {
        "mode": mode,
        "offered": rep.offered,
        "rate_rps": rate,
        "wall_s": round(rep.wall_s, 3),
        "feeder_lag_max_ms": round(1e3 * rep.lag_max_s, 2),
        "priority_inversions": stats["priority_inversions"],
    }
    for cname, s in stats["classes"].items():
        out[f"{cname}_offered"] = s["offered"]
        out[f"{cname}_completed"] = s["completed"]
        out[f"{cname}_rejected"] = s["rejected"]
        out[f"{cname}_shed"] = s["shed"]
        out[f"{cname}_p50_ms"] = round(s["p50_ms"], 2)
        out[f"{cname}_p99_ms"] = round(s["p99_ms"], 2)
        out[f"{cname}_goodput"] = round(s["goodput"], 4)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v}")
    return out


#: CLI verbs; anything else as the first argv token means the legacy
#: flat form, which is rewritten to ``submit`` for back-compat
VERBS = ("submit", "load", "status", "cancel", "pause", "resume", "drain",
         "workers")

#: Sub-verbs of ``workers`` (the multi-process fleet surface).
WORKER_VERBS = ("run", "status", "stop")


def _cmd_submit(args) -> None:
    serve_pair(args.high, args.low, args.mode, args.requests,
               devices=args.devices, discipline=args.discipline,
               deadline=args.deadline, online_measure=args.online_measure,
               jobstore=args.jobstore, resume=args.resume)


def _cmd_status(args) -> None:
    with JobStore(args.jobstore) as store:
        jobs = store.jobs()
        if not jobs:
            print("no jobs in store")
            return
        print(f"{'job':>5} {'process':<24} {'prio':>4} {'state':<10} "
              f"{'done':>5} {'total':>5}")
        for j in jobs:
            print(f"{j.job_id:>5} {j.key.process:<24} {j.priority:>4} "
                  f"{j.state:<10} {j.completed:>5} {j.n_kernels:>5}")


def _cmd_control(verb: str, args) -> None:
    """Enqueue an operator verb for the serving process sharing the
    store file; it is applied at the next poller tick (a kernel-boundary
    action on the engine side)."""
    job_id = getattr(args, "job", None)
    arg = None
    if verb == "resume" and args.device is not None:
        arg = str(args.device)
    with JobStore(args.jobstore) as store:
        store.request_control(verb, job_id, arg=arg)
    target = f" for job {job_id}" if job_id is not None else ""
    print(f"queued {verb}{target} in {args.jobstore}")


def _add_store_arg(p, required=True) -> None:
    p.add_argument("--jobstore", required=required,
                   help="path of the durable job store (SQLite)")


def _cmd_workers(args) -> None:
    """The fleet surface: ``workers run`` spawns N worker processes
    over one store and drains it; ``workers status`` aggregates the
    fleet view (per-worker goodput, per-class JCT, lease churn);
    ``workers stop`` requests a graceful drain (each worker finishes
    its current batch, then exits)."""
    import json as _json

    from repro.serving.workers import WorkerSupervisor, fleet_status
    if args.wverb == "run":
        sup = WorkerSupervisor(args.jobstore, n=args.n, mode=args.mode,
                               lease_s=args.lease,
                               heartbeat_s=args.heartbeat,
                               batch=args.batch, pace_s=args.pace,
                               shard=args.shard)
        sup.start()
        try:
            summaries = sup.wait(timeout=args.timeout)
        finally:
            sup.kill()
        for s in summaries:
            print(f"  {s['worker_id']}: jobs={s['jobs_done']} "
                  f"kernels={s['kernels_done']} steals={s['steals']} "
                  f"batches={s['batches']}")
    with JobStore(args.jobstore) as store:
        if args.wverb == "stop":
            store.set_flag("workers_stop", "1")
            print(f"queued fleet stop in {args.jobstore}")
            return
        fs = fleet_status(store)
    if getattr(args, "json", False):
        print(_json.dumps(fs, indent=2))
        return
    print(f"{'worker':<10} {'state':<9} {'jobs':>5} {'kernels':>8} "
          f"{'steals':>6} {'reaped':>6} {'goodput/s':>10}")
    for w in fs["workers"]:
        print(f"{w['worker_id']:<10} {w['state']:<9} {w['jobs_done']:>5} "
              f"{w['kernels_done']:>8} {w['steals']:>6} {w['reaped']:>6} "
              f"{w['goodput_kps']:>10.1f}")
    for name, c in fs["classes"].items():
        print(f"  class {name}: jobs={c['jobs']} "
              f"jct_p50={c['jct_p50']:.3f}s jct_p99={c['jct_p99']:.3f}s")
    print(f"  pending={fs['pending']} leased={fs['leased']} "
          f"lease_churn={fs['lease_churn']}")


def main(argv=None):
    argv = list(_sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] not in VERBS + ("-h", "--help"):
        argv.insert(0, "submit")       # legacy flat form

    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    sub = ap.add_subparsers(dest="verb", required=True)

    sp = sub.add_parser("submit", help="host a high/low service pair")
    sp.add_argument("--high", default="qwen3-4b")
    sp.add_argument("--low", default="mamba2-2.7b")
    sp.add_argument("--mode", default="fikit",
                    choices=[m.value for m in Mode])
    sp.add_argument("--requests", type=int, default=8)
    sp.add_argument("--devices", type=int, default=1,
                    help="number of device executors (placement layer)")
    sp.add_argument("--discipline", default="fifo",
                    choices=sorted(QUEUE_DISCIPLINES),
                    help="intra-device queue discipline")
    sp.add_argument("--deadline", type=float, default=None,
                    help="relative completion budget (s) tagged onto "
                         "low-priority invocations (edf ordering + "
                         "deadline_misses stat)")
    sp.add_argument("--online-measure", action="store_true",
                    help="refine SK/SG live during the sharing phase "
                         "(EMA epoch commits + cold-start predictions); "
                         "the low-priority service is NOT onboarded "
                         "offline and learns its profile online")
    _add_store_arg(sp, required=False)
    sp.add_argument("--resume", action="store_true",
                    help="first re-run invocations a previous run left "
                         "incomplete in the jobstore")

    lp = sub.add_parser("load", help="open-loop Poisson/diurnal traffic "
                                     "through the admission plane")
    lp.add_argument("--high", default="qwen3-4b")
    lp.add_argument("--low", default="mamba2-2.7b")
    lp.add_argument("--mode", default="fikit",
                    choices=[m.value for m in Mode])
    lp.add_argument("--rate", type=float, default=20.0,
                    help="total offered request rate (req/s)")
    lp.add_argument("--duration", type=float, default=2.0,
                    help="schedule length (s)")
    lp.add_argument("--hi-share", type=float, default=0.3,
                    help="fraction of offered load in the gold class")
    lp.add_argument("--deadline", type=float, default=None,
                    help="gold-class SLO budget (s); enables SLO-aware "
                         "shedding")
    lp.add_argument("--diurnal", action="store_true",
                    help="modulate the bronze rate sinusoidally")
    lp.add_argument("--speed", type=float, default=1.0,
                    help="replay speedup (2.0 = twice as fast)")
    lp.add_argument("--devices", type=int, default=1)
    lp.add_argument("--seed", type=int, default=0)

    st_ = sub.add_parser("status", help="print the store's job table")
    _add_store_arg(st_)

    wp = sub.add_parser("workers", help="multi-process worker fleet "
                                        "over one job store")
    wsub = wp.add_subparsers(dest="wverb", required=True)
    wr = wsub.add_parser("run", help="spawn N workers and drain the "
                                     "store's submitted jobs")
    wr.add_argument("-n", type=int, default=2, help="worker processes")
    wr.add_argument("--mode", default="fikit",
                    choices=[m.value for m in Mode])
    wr.add_argument("--batch", type=int, default=16,
                    help="max jobs per claimed batch")
    wr.add_argument("--pace", type=float, default=0.0,
                    help="wall seconds slept per kernel completion "
                         "(0 = replay at store speed)")
    wr.add_argument("--lease", type=float, default=5.0,
                    help="claim lease duration (s); crashed workers' "
                         "jobs are reclaimed after expiry")
    wr.add_argument("--heartbeat", type=float, default=1.0,
                    help="lease renewal period (s)")
    wr.add_argument("--shard", action="store_true",
                    help="partition the store's qos shard keys across "
                         "workers (with any-shard stealing) instead of "
                         "one shared queue")
    wr.add_argument("--timeout", type=float, default=300.0)
    _add_store_arg(wr)
    ws = wsub.add_parser("status", help="aggregated fleet status: "
                                        "per-worker goodput, per-class "
                                        "JCT, lease churn")
    ws.add_argument("--json", action="store_true",
                    help="machine-readable output")
    _add_store_arg(ws)
    wx = wsub.add_parser("stop", help="graceful fleet drain (workers "
                                      "finish their batch, then exit)")
    _add_store_arg(wx)
    for verb, jobbed in (("cancel", True), ("pause", True),
                         ("resume", True), ("drain", False)):
        vp = sub.add_parser(verb, help=f"queue a {verb} for the live "
                                       f"serving process on this store")
        if jobbed:
            vp.add_argument("job", type=int, help="job id (see status)")
        if verb == "resume":
            vp.add_argument("--device", type=int, default=None,
                            help="pin the resumed task to this device")
        _add_store_arg(vp)

    args = ap.parse_args(argv)
    if args.verb == "submit":
        _cmd_submit(args)
    elif args.verb == "load":
        serve_load(args.high, args.low, args.mode, rate=args.rate,
                   duration=args.duration, hi_share=args.hi_share,
                   deadline=args.deadline, diurnal=args.diurnal,
                   speed=args.speed, devices=args.devices, seed=args.seed)
    elif args.verb == "status":
        _cmd_status(args)
    elif args.verb == "workers":
        _cmd_workers(args)
    else:
        _cmd_control(args.verb, args)


if __name__ == "__main__":
    main()
