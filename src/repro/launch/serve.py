"""Serving driver: hosts reduced-scale services on the FIKIT engine with
batched requests — the end-to-end serving example path.

    PYTHONPATH=src python -m repro.launch.serve \
        --high qwen3-4b --low mamba2-2.7b --mode fikit --requests 10 \
        --discipline sjf
"""
from __future__ import annotations

import argparse
import statistics as st

from repro.config import get_config
from repro.core.queues import QUEUE_DISCIPLINES
from repro.core.scheduler import Mode
from repro.serving import InferenceService, ServingSystem


def serve_pair(high: str, low: str, mode: str = "fikit", requests: int = 8,
               measure_runs: int = 4, batch: int = 2, seq: int = 48,
               host_gap: float = 0.002, devices: int = 1,
               discipline: str = "fifo", deadline: float = None,
               online_measure: bool = False,
               verbose: bool = True):
    """Host a high/low priority service pair on the wall-clock engine.

    ``discipline`` is the intra-device queue discipline ("fifo"/"sjf"/
    "edf"); ``deadline`` optionally gives every LOW-priority invocation a
    relative completion budget in seconds — the tag edf levels order by,
    and the source of the ``deadline_misses`` stat. ``online_measure``
    keeps refining SK/SG live during the sharing phase (EMA epochs +
    cold-start predictions; see ``repro.core.online``): the LOW service is
    then NOT onboarded offline — it starts cold and becomes gap-fillable
    from its own observed kernels, the scenario the offline two-phase
    design cannot serve."""
    hi = InferenceService(get_config(high).reduced(), priority=0,
                          batch=batch, seq=seq, host_gap=host_gap)
    lo = InferenceService(get_config(low).reduced(), priority=5,
                          batch=batch * 2, seq=seq)
    with ServingSystem(Mode(mode), measure_runs=measure_runs,
                       devices=devices,
                       queue_discipline=discipline,
                       online_measure=online_measure) as sys_:
        meas_hi = sys_.onboard(hi)
        if online_measure:
            lo.svc.warmup()            # compile outside the timed phase
            meas_lo = []
        else:
            meas_lo = sys_.onboard(lo)
        res = sys_.invoke_concurrent([
            ("high", hi, requests, 0.0, 0.01),
            ("low", lo, requests, 0.0, 0.0, deadline),
        ])
        fills = sys_.engine.fill_count
        steals = sys_.engine.steal_count
        misses = sys_.deadline_misses
        tagged = sys_.deadlines_tagged
    # read AFTER the context closes: stop() flushes the final partial epoch
    online_stats = sys_.online_stats
    out = {
        "mode": mode,
        "devices": devices,
        "discipline": discipline,
        "online_measure": online_measure,
        "measure_high_ms": 1e3 * st.mean(meas_hi),
        "measure_low_ms": 1e3 * st.mean(meas_lo) if meas_lo else 0.0,
        "high_jct_ms": 1e3 * st.mean(res["high"]),
        "low_jct_ms": 1e3 * st.mean(res["low"]),
        "high_jct_cv": (st.pstdev(res["high"]) / st.mean(res["high"])),
        "low_jct_cv": (st.pstdev(res["low"]) / st.mean(res["low"])),
        "fills": fills,
        "steals": steals,
        "deadline_misses": misses,
        "deadlines_tagged": tagged,
    }
    if online_stats is not None:
        out["online_observations"] = online_stats["observations"]
        out["online_commits"] = online_stats["commits"]
        out["online_cold_observations"] = online_stats["cold_observations"]
        out["online_drift_rel_err"] = round(
            online_stats["drift_mean_rel_err"], 4)
    if verbose:
        for k, v in out.items():
            print(f"  {k}: {v if isinstance(v, (str, int)) else round(v, 3)}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--high", default="qwen3-4b")
    ap.add_argument("--low", default="mamba2-2.7b")
    ap.add_argument("--mode", default="fikit",
                    choices=[m.value for m in Mode])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--devices", type=int, default=1,
                    help="number of device executors (placement layer)")
    ap.add_argument("--discipline", default="fifo",
                    choices=sorted(QUEUE_DISCIPLINES),
                    help="intra-device queue discipline")
    ap.add_argument("--deadline", type=float, default=None,
                    help="relative completion budget (s) tagged onto "
                         "low-priority invocations (edf ordering + "
                         "deadline_misses stat)")
    ap.add_argument("--online-measure", action="store_true",
                    help="refine SK/SG live during the sharing phase "
                         "(EMA epoch commits + cold-start predictions); "
                         "the low-priority service is NOT onboarded "
                         "offline and learns its profile online")
    args = ap.parse_args()
    serve_pair(args.high, args.low, args.mode, args.requests,
               devices=args.devices, discipline=args.discipline,
               deadline=args.deadline, online_measure=args.online_measure)


if __name__ == "__main__":
    main()
