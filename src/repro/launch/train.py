"""Training driver: real training at reduced scale on CPU (the end-to-end
example path) and the same code path the dry-run lowers at full scale.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50 \
        --reduced --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import InputShape, get_config
from repro.data.pipeline import SyntheticTextPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import api
from repro.optim.adamw import adamw_init


def train(arch: str, steps: int = 20, batch: int = 8, seq: int = 128,
          reduced: bool = True, seed: int = 0, log_every: int = 5,
          ckpt_path: str = "", mesh=None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = mesh or make_host_mesh()
    shape = InputShape("cli", seq, batch, "train")
    step_fn, _ = make_train_step(cfg, mesh, shape, grad_accum=1)

    params = api.build_params(cfg, jax.random.key(seed))
    opt = adamw_init(params)
    pipe = SyntheticTextPipeline(cfg.vocab_size, batch, seq,
                                 seed=seed).start()
    losses = []
    t0 = time.time()
    for step in range(steps):
        tb = next(pipe)
        tokens = jnp.asarray(tb.tokens)
        labels = jnp.asarray(tb.labels)
        if cfg.family == "vlm":
            from repro.models.vlm import stub_patches
            P = cfg.num_patches
            batch_in = (stub_patches(cfg, batch), tokens[:, :seq - P])
            labels = jnp.concatenate(
                [jnp.full((batch, P), -100, jnp.int32), labels[:, :seq - P]],
                axis=1)
        elif cfg.family == "encdec":
            frames = jnp.zeros((batch, cfg.encoder_frames, cfg.d_model),
                               jnp.dtype(cfg.dtype))
            batch_in = (frames, tokens)
        else:
            batch_in = tokens
        with mesh:
            params, opt, metrics = step_fn(params, opt, batch_in, labels)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
    pipe.stop()
    if ckpt_path:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(ckpt_path, {"params": params, "opt": opt},
                        step=steps)
        print(f"saved checkpoint to {ckpt_path}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, reduced=args.reduced, ckpt_path=args.ckpt)
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
