"""HLO cost extraction with loop trip-count correction.

XLA's HloCostAnalysis (behind ``compiled.cost_analysis()``) visits each
while-loop body ONCE, so anything inside a ``lax.scan`` (layers,
microbatches, flash q-blocks) is undercounted by its trip count. The
compiled HLO, however, carries ``known_trip_count`` backend configs. This
module walks the computation graph, propagates multipliers through while
bodies / fusions / calls, and produces trip-count-corrected totals for:

- per-collective traffic bytes (exact, from op output shapes), and
- dot FLOPs (2 * prod(output dims) * prod(contracting dims)).

Used by the dry-run and the roofline analysis. The corrected
(flops, bytes) pair also feeds ``resource_class_from_cost``: the
arithmetic-intensity split of a program into compute-bound vs
memory-bound against a per-arch ridge point, which is the offline
analog of the scheduler's kernel resource classes
(``repro.core.interference``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "s64": 8, "u64": 8, "pred": 1, "s16": 2,
                "u16": 2, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    Older jaxlibs return one dict; newer ones return a one-element list.
    The first entry is the per-device program's analysis — taking it (not
    summing) keeps the old single-dict semantics if a jaxlib ever returns
    one entry per device. Callers should never have to care."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def resource_class_from_cost(flops: float, nbytes: float,
                             ridge: float) -> str:
    """Compute-bound vs memory-bound from trip-count-corrected HLO cost.

    ``ridge`` is the arch's ridge point in FLOP/byte (peak FLOP/s over
    HBM bandwidth). Delegates to the scheduler-side classifier so the
    offline (HLO cost) and online (profiled kernel) paths can never
    disagree on the boundary."""
    from repro.core.interference import classify_intensity
    return classify_intensity(flops, nbytes, ridge)


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str, with_headers: bool = False):
    """computation name -> list of body lines (optionally also headers)."""
    comps: Dict[str, List[str]] = {}
    headers: Dict[str, str] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # computation headers sit at column 0: ``%name (params...) -> T {``
        # (params may contain nested tuple types) or ``ENTRY %name (...)``
        if (not line.startswith(" ") and line.rstrip().endswith("{")
                and ") -> " in line
                and (line.startswith("%") or line.startswith("ENTRY"))):
            m = _COMP_NAME.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                headers[cur] = line
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
                    headers["__entry__"] = line
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    if with_headers:
        return comps, headers
    return comps


_CALL_RE = re.compile(
    r"(?:body=%?([\w.\-]+))|(?:calls=%?([\w.\-]+))|"
    r"(?:to_apply=%?([\w.\-]+))|(?:condition=%?([\w.\-]+))")
_TRIP_RE = re.compile(r'known_trip_count\\?":{\\?"n\\?":\\?"(\d+)')


def _line_children(line: str) -> List[Tuple[str, int]]:
    """(child computation, multiplier) refs on this op line."""
    out = []
    is_while = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+while\(", line)
    trip = 1
    if is_while:
        m = _TRIP_RE.search(line)
        trip = int(m.group(1)) if m else 1
    for m in _CALL_RE.finditer(line):
        body, calls, to_apply, cond = m.groups()
        if body:
            out.append((body, trip))
        if calls:
            out.append((calls, 1))
        if to_apply:
            out.append((to_apply, 1))
        if cond:
            out.append((cond, 1))
    return out


def computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, int]:
    """Effective execution count per computation, from ENTRY down."""
    entry = "__entry__"
    mult: Dict[str, int] = defaultdict(int)
    stack = [(entry, 1)]
    # build static edges once
    edges: Dict[str, List[Tuple[str, int]]] = {}
    for name, lines in comps.items():
        e: List[Tuple[str, int]] = []
        for line in lines:
            e.extend(_line_children(line))
        edges[name] = e
    seen_guard = 0
    while stack:
        name, m = stack.pop()
        seen_guard += 1
        if seen_guard > 200_000:  # cycles shouldn't exist; guard anyway
            break
        mult[name] += m
        for child, k in edges.get(name, ()):
            if child in comps:
                stack.append((child, m * k))
    return dict(mult)


_TYPE = r"(\([^()]*\)|\S+)"   # tuple type (no nested parens) or one token
_COLL_OP = re.compile(
    r"=\s*" + _TYPE + r"\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_DOT_OUT = re.compile(r"%?[\w.\-]+\s*=\s*" + _TYPE + r"\s+dot\(")
_VARDEF = re.compile(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*" + _TYPE + r"\s")
_CONTRACT = re.compile(r"(?:lhs_contracting_dims|rhs_contracting_dims)="
                       r"{([\d,]*)}")


def _operand_refs(line: str) -> List[str]:
    """Operand variable names of the op call on this line.

    Handles both operand syntaxes XLA emits: bare refs (``dot(%a, %b)``)
    and typed refs (``dot(f32[64,64]{1,0} %a, f32[64,64]{1,0} %b)``), the
    latter possibly with tuple-typed operands containing nested parens —
    hence the balanced-paren scan rather than a regex."""
    m = _OPNAME.search(line)
    if not m:
        return []
    start = m.end()                      # just past the opening '('
    depth = 1
    end = start
    while end < len(line) and depth:
        if line[end] == "(":
            depth += 1
        elif line[end] == ")":
            depth -= 1
        end += 1
    args = line[start:end - 1]
    refs = re.findall(r"%([\w.\-]+)", args)
    if refs:
        return refs
    # no % sigils: split on TOP-LEVEL commas only (shape literals contain
    # commas inside []/{}/()), then take each argument's last token
    out, depth, seg = [], 0, []
    for ch in args + ",":
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            toks = "".join(seg).split()
            if toks:
                out.append(toks[-1])
            seg = []
            continue
        seg.append(ch)
    return out


def collective_bytes_corrected(hlo: str) -> Dict[str, float]:
    """Per-collective-kind traffic bytes, x loop trip counts (per device)."""
    comps = split_computations(hlo)
    mult = computation_multipliers(comps)
    totals: Dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0 or name == "__entry__" and "__entry__" != name:
            continue
        if name == "__entry__":
            continue  # alias of the real entry computation
        for line in lines:
            cm = _COLL_OP.search(line)
            if not cm:
                continue
            result_ty, kind, phase = cm.groups()
            if phase == "-done":
                continue  # counted at -start
            shapes = _shapes(result_ty)
            if phase == "-start" and len(shapes) > 1:
                # start result is a (operand, result, ...) tuple: count the
                # result element only
                dt, dims = shapes[1]
                n = 1
                for d in dims:
                    n *= d
                nbytes = n * _DTYPE_BYTES[dt]
            else:
                nbytes = _bytes_of(result_ty)
            totals[kind] += nbytes * m
    return dict(totals)


_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([\w\[\],]+)")

_SKIP_OPS = re.compile(
    r"=\s*(?:\([^()]*\)|\S+)\s+"
    r"(get-tuple-element|tuple|parameter|constant|bitcast|after-all|"
    r"partition-id|replica-id|iota)\b")
_OPNAME = re.compile(r"=\s*(?:\([^()]*\)|\S+)\s+([\w\-]+)\(")


def _control_computations(comps) -> Dict[str, int]:
    """Computations whose ops execute at top level (entry, while bodies /
    conds, call targets) with their multipliers — fusions and reducers are
    charged at their call site, not walked."""
    mult = computation_multipliers(comps)
    control = {"__entry__"}
    for name, lines in comps.items():
        for line in lines:
            if re.search(r"\s+(while|conditional)\(", line):
                for m in _CALL_RE.finditer(line):
                    body, _, _, cond = m.groups()
                    if body:
                        control.add(body)
                    if cond:
                        control.add(cond)
    return {n: mult.get(n, 0) for n in control if n in comps}


def bytes_accessed_corrected(hlo: str) -> float:
    """Trip-count-corrected HBM traffic estimate (per device): sum of
    output + operand bytes over top-level (post-fusion) ops, x loop trip
    counts — the same op-IO model HloCostAnalysis uses, with loops
    actually multiplied out."""
    comps, headers = split_computations(hlo, with_headers=True)
    control = _control_computations(comps)
    total = 0.0
    for name, m in control.items():
        if m == 0 or name == "__entry__":
            continue
        lines = comps[name]
        shapes_by_var: Dict[str, int] = {}
        hdr = headers.get(name, "")
        if "(" in hdr:
            for pm in _PARAM_RE.finditer(hdr[hdr.index("(") + 1:]):
                shapes_by_var[pm.group(1)] = _bytes_of(pm.group(2))
        for line in lines:
            vm = _VARDEF.match(line)
            if vm:
                shapes_by_var[vm.group(1)] = _bytes_of(vm.group(2))
        for line in lines:
            if _SKIP_OPS.search(line):
                continue
            vm = _VARDEF.match(line)
            if not vm:
                continue
            out_bytes = _bytes_of(vm.group(2))
            opnd_bytes = 0
            for ref in _operand_refs(line):
                opnd_bytes += shapes_by_var.get(ref, 0)
            total += (out_bytes + opnd_bytes) * m
    # add the entry computation itself (multiplier 1)
    comps2 = comps["__entry__"]
    shapes_by_var = {}
    for line in comps2:
        vm = _VARDEF.match(line)
        if vm:
            shapes_by_var[vm.group(1)] = _bytes_of(vm.group(2))
    for line in comps2:
        if _SKIP_OPS.search(line):
            continue
        vm = _VARDEF.match(line)
        if not vm:
            continue
        out_bytes = _bytes_of(vm.group(2))
        opnd_bytes = 0
        for ref in _operand_refs(line):
            opnd_bytes += shapes_by_var.get(ref, 0)
        total += out_bytes + opnd_bytes
    return total


def dot_flops_corrected(hlo: str) -> float:
    """Trip-count-corrected dot FLOPs (per device program)."""
    comps, headers = split_computations(hlo, with_headers=True)
    mult = computation_multipliers(comps)
    # symbol table of output shapes per computation
    total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if m == 0 or name == "__entry__":
            continue
        shapes_by_var: Dict[str, List[int]] = {}
        hdr = headers.get(name, "")
        if "(" in hdr:
            params = hdr[hdr.index("(") + 1:]
            for pm in _PARAM_RE.finditer(params):
                sh = _shapes(pm.group(2))
                if sh:
                    shapes_by_var[pm.group(1)] = sh[0][1]
        for line in lines:
            vm = _VARDEF.match(line)
            if vm:
                sh = _shapes(vm.group(2))
                if sh:
                    shapes_by_var[vm.group(1)] = sh[0][1]
        for line in lines:
            if " dot(" not in line:
                continue
            om = _DOT_OUT.search(line)
            ops = _operand_refs(line)
            cm = _CONTRACT.search(line)
            if not (om and ops and cm):
                continue
            out_shapes = _shapes(om.group(1))
            if not out_shapes:
                continue
            out_elems = 1
            for d in out_shapes[0][1]:
                out_elems *= d
            lhs = shapes_by_var.get(ops[0], [])
            cdims = [int(d) for d in cm.group(1).split(",") if d]
            contract = 1
            for ci in cdims:
                if ci < len(lhs):
                    contract *= lhs[ci]
            total += 2.0 * out_elems * contract * m
    return total
