"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: (16, 16) = 256 chips ("data", "model"); multi-pod
    (2, 16, 16) = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke testing of the launch path."""
    return jax.make_mesh((1, 1), ("data", "model"))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
