"""Profiler / SK / SG statistics tests (paper §3.2 formulas) + store
round-trip."""
import os

import pytest

from repro.core.kernel_id import KernelID, kernel_id_for
from repro.core.profile_store import load_profiles, save_profiles
from repro.core.profiler import ProfiledData, Profiler
from repro.core.task import TaskKey

pytestmark = pytest.mark.fast


def test_sk_sg_kronecker_delta_means():
    """Reproduces the paper's worked example: kernel j appears twice per run
    across 2 runs; SK_j / SG_j are means over all 4 occurrences."""
    key = TaskKey("svc")
    j = KernelID("j")
    other = KernelID("other")
    prof = Profiler(key)
    # run 1: j(2ms) gap 10ms, other(1ms) gap 1ms, j(4ms) gap 2ms, other(1ms)
    prof.start_run()
    prof.record(j, 0.002); prof.record_gap(0.010)
    prof.record(other, 0.001); prof.record_gap(0.001)
    prof.record(j, 0.004); prof.record_gap(0.002)
    prof.record(other, 0.001)
    prof.end_run()
    # run 2: j(6ms) gap 4ms, j(8ms) gap 8ms, other(1ms)
    prof.start_run()
    prof.record(j, 0.006); prof.record_gap(0.004)
    prof.record(j, 0.008); prof.record_gap(0.008)
    prof.record(other, 0.001)
    prof.end_run()

    stats = prof.statistics()
    assert stats.SK[j] == pytest.approx((0.002 + 0.004 + 0.006 + 0.008) / 4)
    assert stats.SG[j] == pytest.approx((0.010 + 0.002 + 0.004 + 0.008) / 4)
    assert stats.SK[other] == pytest.approx(0.001)
    # 'other' had a recorded gap only in run 1 (last kernel has no gap)
    assert stats.SG[other] == pytest.approx(0.001)
    assert stats.runs == 2
    assert stats.unique_ids == {j, other}


def test_last_kernel_has_no_gap():
    prof = Profiler(TaskKey("s"))
    k = KernelID("k")
    prof.start_run()
    prof.record(k, 1.0)
    prof.record_gap(9.9)   # would be a gap after the final kernel
    prof.end_run()         # end_run clears it (paper: N_t - 1 gaps)
    assert KernelID("k") not in prof.statistics().SG


def test_kernel_id_from_avals():
    import numpy as np
    kid = kernel_id_for("seg", inputs=[np.zeros((4, 8), np.float32)],
                        outputs=[np.zeros((4, 2), np.int32)])
    assert kid.name == "seg"
    assert kid.block == (4, 8, "float32")
    assert kid.grid == (4, 2, "int32")
    # same avals -> same id (dict key usable)
    kid2 = kernel_id_for("seg", inputs=[np.ones((4, 8), np.float32)],
                         outputs=[np.ones((4, 2), np.int32)])
    assert kid == kid2 and hash(kid) == hash(kid2)
    kid3 = kernel_id_for("seg", inputs=[np.zeros((4, 9), np.float32)])
    assert kid3 != kid


def test_store_roundtrip(tmp_path):
    key = TaskKey("svc", ("--batch", "4"))
    prof = Profiler(key)
    kid = kernel_id_for("seg", inputs=[], outputs=[])
    prof.start_run(); prof.record(kid, 0.5); prof.end_run()
    data = ProfiledData()
    data.load(prof.statistics())
    path = os.path.join(tmp_path, "profiles.json")
    save_profiles(path, data)
    loaded = load_profiles(path)
    assert loaded.predict_duration(key, kid) == pytest.approx(0.5)
    assert key in loaded


def test_load_missing_file_is_empty(tmp_path):
    data = load_profiles(os.path.join(tmp_path, "nope.json"))
    assert TaskKey("x") not in data
