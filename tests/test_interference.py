"""Directed tests for interference-aware gap filling (PR 6).

Covers the classifier boundary, the coefficient model, class plumbing
through profiles and the store (including pre-classification files), the
class-aware BestPrioFit semantics on BOTH paths, the effective gap
debit, online coefficient learning with SK de-rating, and a mini
end-to-end simulation where the aware policy beats the class-blind one
on an adversarial mix. The randomized indexed-vs-scan and
wired-but-disabled sweeps live in ``tests/test_policy_differential.py``.
"""
import json

import pytest

from repro.core.interference import (COMPUTE_BOUND, DEFAULT_COEFFS,
                                     MEMORY_BOUND, InterferenceModel,
                                     classify_intensity)
from repro.core.fikit import best_prio_fit, best_prio_fit_scan
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig, OnlineMeasurement
from repro.core.profile_store import load_profiles, save_profiles
from repro.core.profiler import ProfiledData, Profiler, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import KernelRequest, TaskKey, TaskSpec, TraceKernel
from repro.launch.hlo_cost import resource_class_from_cost

pytestmark = pytest.mark.fast

MEM = MEMORY_BOUND
COMP = COMPUTE_BOUND


# ---------------------------------------------------------------------------
# Classifier + model
# ---------------------------------------------------------------------------
def test_classify_intensity_boundary():
    # ridge 100 FLOP/byte: at the ridge counts as compute-bound
    assert classify_intensity(1000.0, 10.0, 100.0) == COMP
    assert classify_intensity(999.0, 10.0, 100.0) == MEM
    assert classify_intensity(1001.0, 10.0, 100.0) == COMP


def test_classify_zero_bytes_is_compute():
    """No recorded traffic -> conservative compute-bound default."""
    assert classify_intensity(0.0, 0.0, 100.0) == COMP
    assert classify_intensity(5.0, -1.0, 100.0) == COMP


def test_resource_class_from_cost_delegates():
    assert resource_class_from_cost(1e12, 1e9, 240.0) == COMP
    assert resource_class_from_cost(1e10, 1e9, 240.0) == MEM


def test_model_coeff_and_unknown_pair():
    m = InterferenceModel({(MEM, MEM): 1.5})
    assert m.coeff(MEM, MEM) == 1.5
    assert m.coeff(MEM, COMP) == 1.0      # unknown pair: no interference
    assert m.enabled


def test_model_update_ema_and_floor():
    m = InterferenceModel({(MEM, MEM): 1.4})
    m.update((MEM, MEM), 1.8, alpha=0.5)
    assert m.coeff(MEM, MEM) == pytest.approx(1.6)
    # floor clamp: a sub-1.0 batch (noise) can never model a speedup
    m.update((MEM, MEM), 0.0, alpha=1.0)
    assert m.coeff(MEM, MEM) == 1.0
    assert m.updates == 2


def test_model_coerce():
    assert InterferenceModel.coerce(None) is None
    assert InterferenceModel.coerce(False) is None
    m = InterferenceModel.coerce(True)
    assert m.snapshot() == DEFAULT_COEFFS
    same = InterferenceModel(enabled=False)
    assert InterferenceModel.coerce(same) is same
    m2 = InterferenceModel.coerce({(MEM, COMP): 1.2})
    assert m2.coeff(MEM, COMP) == 1.2
    with pytest.raises(TypeError):
        InterferenceModel.coerce(1.5)


# ---------------------------------------------------------------------------
# Class plumbing: profiler -> ProfiledData -> store
# ---------------------------------------------------------------------------
def _profile(key, sk, kclass=None):
    prof = TaskProfile(key=key, runs=1)
    prof.SK = dict(sk)
    prof.kclass = dict(kclass or {})
    return prof


def test_predict_class_default_compute():
    pd = ProfiledData()
    kid = KernelID("t/k")
    key = TaskKey("t")
    pd.load(_profile(key, {kid: 1.0}))
    assert pd.predict_class(key, kid) == COMP          # unclassified
    pd.load(_profile(key, {kid: 1.0}, {kid: MEM}))
    assert pd.predict_class(key, kid) == MEM
    # reload without a class drops the stale entry
    pd.load(_profile(key, {kid: 1.0}))
    assert pd.predict_class(key, kid) == COMP


def test_profiler_records_kclass():
    prof = Profiler(TaskKey("t"))
    kid = KernelID("t/k")
    prof.start_run()
    prof.record(kid, 1.0, kclass=MEM)
    prof.record(kid, 1.2)                  # None does not erase
    prof.end_run()
    stats = prof.statistics()
    assert stats.kclass == {kid: MEM}


def test_store_roundtrips_class_and_coeffs(tmp_path):
    pd = ProfiledData()
    kid = KernelID("svc/k", (4,), (128,))
    key = TaskKey("svc", (1, 32))
    pd.load(_profile(key, {kid: 2.0}, {kid: MEM}))
    pd.interference = InterferenceModel({(MEM, MEM): 1.43,
                                         (MEM, COMP): 1.07})
    path = str(tmp_path / "profiles.json")
    save_profiles(path, pd)
    with open(path) as f:
        raw = json.load(f)
    assert isinstance(raw, dict)           # envelope with a model attached
    assert set(raw) == {"profiles", "interference"}
    back = load_profiles(path)
    assert back.predict_class(key, kid) == MEM
    assert back.predict_duration(key, kid) == 2.0
    assert back.interference is not None
    assert back.interference.enabled
    assert back.interference.coeff(MEM, MEM) == 1.43
    assert back.interference.coeff(MEM, COMP) == 1.07


def test_store_without_model_stays_list_format(tmp_path):
    """Plain stores keep the original top-level list format and the exact
    offline key set — old readers keep working."""
    pd = ProfiledData()
    kid = KernelID("svc/k")
    key = TaskKey("svc")
    pd.load(_profile(key, {kid: 2.0}))
    path = str(tmp_path / "plain.json")
    save_profiles(path, pd)
    with open(path) as f:
        raw = json.load(f)
    assert isinstance(raw, list)
    assert set(raw[0]) == {"process", "args", "runs", "SK", "SG"}


def test_pre_classification_file_loads_compute_default(tmp_path):
    """A file written before resource classes existed (no ``class``
    field, top-level list) loads cleanly; every kernel defaults to
    compute-bound."""
    legacy = [{
        "process": "old", "args": [], "runs": 3,
        "SK": [[["old/k", [], []], 1.5]],
        "SG": [[["old/k", [], []], 0.2]],
    }]
    path = str(tmp_path / "legacy.json")
    with open(path, "w") as f:
        json.dump(legacy, f)
    pd = load_profiles(path)
    key = TaskKey("old")
    kid = KernelID("old/k")
    assert pd.predict_duration(key, kid) == 1.5
    assert pd.predict_class(key, kid) == COMP
    assert pd.interference is None


# ---------------------------------------------------------------------------
# Class-aware BestPrioFit: directed semantics, both paths
# ---------------------------------------------------------------------------
def _mk_pd():
    pd = ProfiledData()
    pd.load(_profile(TaskKey("mem"), {KernelID("mem/k"): 0.0045},
                     {KernelID("mem/k"): MEM}))
    pd.load(_profile(TaskKey("cpu"), {KernelID("cpu/k"): 0.004},
                     {KernelID("cpu/k"): COMP}))
    return pd


def _park(pd, model, discipline="fifo"):
    q = PriorityQueues(profiled=pd, threadsafe=False,
                       discipline_by_level=discipline,
                       interference=model)
    q.push(KernelRequest(task_key=TaskKey("mem"),
                         kernel_id=KernelID("mem/k"), priority=5,
                         task_instance=1, payload=0.0045))
    q.push(KernelRequest(task_key=TaskKey("cpu"),
                         kernel_id=KernelID("cpu/k"), priority=5,
                         task_instance=2, payload=0.004))
    return q


MODEL = {(MEM, MEM): 1.6, (MEM, COMP): 1.05,
         (COMP, COMP): 1.15, (COMP, MEM): 1.25}


@pytest.mark.parametrize("fit", [best_prio_fit, best_prio_fit_scan])
def test_blind_fit_picks_memory_bait(fit):
    """Without a holder class the longest fit wins: the memory-bound
    4.5 ms candidate — exactly the paper's Algorithm 2."""
    pd = _mk_pd()
    req, dur = fit(_park(pd, None), 0.006, pd)
    assert req.task_key == TaskKey("mem")
    assert dur == 0.0045


@pytest.mark.parametrize("fit", [best_prio_fit, best_prio_fit_scan])
def test_aware_fit_excludes_memory_bait(fit):
    """Memory-bound holder: the mem candidate's effective occupancy
    (4.5 x 1.6 = 7.2 ms) busts the 6 ms gap, so the compute candidate is
    selected instead (4.0 x 1.05 = 4.2 ms fits); the RAW duration is
    returned."""
    pd = _mk_pd()
    model = InterferenceModel(MODEL)
    req, dur = fit(_park(pd, model), 0.006, pd,
                   holder_class=MEM, interference=model)
    assert req.task_key == TaskKey("cpu")
    assert dur == 0.004                    # raw prediction, not effective


@pytest.mark.parametrize("fit", [best_prio_fit, best_prio_fit_scan])
def test_aware_fit_compute_holder_keeps_longest(fit):
    """Compute-bound holder: mem 4.5 x 1.25 = 5.625 < 6 still fits and is
    still the longest — the class dimension only changes decisions when
    the effective occupancy busts the gap."""
    pd = _mk_pd()
    model = InterferenceModel(MODEL)
    req, dur = fit(_park(pd, model), 0.006, pd,
                   holder_class=COMP, interference=model)
    assert req.task_key == TaskKey("mem")
    assert dur == 0.0045


@pytest.mark.parametrize("fit", [best_prio_fit, best_prio_fit_scan])
def test_disabled_model_ignores_holder_class(fit):
    """A wired-but-disabled model scores exactly like no model."""
    pd = _mk_pd()
    model = InterferenceModel(MODEL, enabled=False)
    req, dur = fit(_park(pd, model), 0.006, pd,
                   holder_class=MEM, interference=model)
    assert req.task_key == TaskKey("mem")
    assert dur == 0.0045


# ---------------------------------------------------------------------------
# Effective gap debit
# ---------------------------------------------------------------------------
def _debit_tasks():
    """9 ms gaps; compute-bound 4 ms fillers. Blind filling debits the
    raw 4 ms and fits TWO per gap; with coeff (mem, comp) = 1.4 the
    effective debit is 5.6 ms and only ONE fits."""
    hi = TaskSpec(TaskKey("hi"), 0,
                  [TraceKernel(KernelID("hi/k"), 0.002, 0.009,
                               kclass=MEM)] * 6)
    lo = TaskSpec(TaskKey("cpu"), 5,
                  [TraceKernel(KernelID("cpu/k"), 0.004, 0.0001,
                               kclass=COMP)] * 30,
                  arrival=0.0005, max_inflight=8)
    return [hi, lo]


def test_fill_loop_debits_effective_duration():
    tasks = _debit_tasks()
    pd = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    blind = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0).run()
    pd2 = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    model = InterferenceModel({(MEM, COMP): 1.4})
    aware = SimScheduler(tasks, Mode.FIKIT, pd2, jitter=0.0,
                         interference=model).run()
    assert blind.fills > aware.fills > 0


# ---------------------------------------------------------------------------
# Online coefficient learning + SK de-rating
# ---------------------------------------------------------------------------
def _om(model, **cfg):
    pd = ProfiledData()
    kid = KernelID("f/k")
    key = TaskKey("f")
    pd.load(_profile(key, {kid: 1.0}, {kid: MEM}))
    om = OnlineMeasurement(
        pd, OnlineConfig(epoch_observations=10 ** 9,
                         epoch_seconds=10 ** 9, **cfg),
        clock=lambda: 0.0, interference=model)
    return om, pd, key, kid


def test_pair_ratio_learned_at_commit():
    model = InterferenceModel({(MEM, MEM): 1.0})
    om, pd, key, kid = _om(model, ema_alpha=0.5)
    om.note_fill_pair(7, kid, MEM, MEM)
    om.observe(0, 7, key, kid, 0.0, 1.5)   # observed 1.5x the prediction
    assert om.interference_pair_obs == 1
    om.commit()
    assert om.interference_updates == 1
    assert model.coeff(MEM, MEM) == pytest.approx(1.25)  # EMA from 1.0
    # tag consumed: a later untagged completion adds no pair sample
    om.observe(0, 7, key, kid, 2.0, 3.5)
    om.commit()
    assert om.interference_pair_obs == 1


def test_sk_sample_derated_by_current_coeff():
    """A contended fill's duration enters the SK buffers de-rated by the
    model's current belief, so contention doesn't read as drift."""
    model = InterferenceModel({(MEM, MEM): 1.5})
    om, pd, key, kid = _om(model, ema_alpha=1.0)
    om.note_fill_pair(3, kid, MEM, MEM)
    om.observe(0, 3, key, kid, 0.0, 1.5)   # raw 1.5, de-rated 1.0
    om.commit()
    assert pd.predict_duration(key, kid) == pytest.approx(1.0)


def test_task_gone_drops_pending_pair_tags():
    model = InterferenceModel({(MEM, MEM): 1.0})
    om, pd, key, kid = _om(model)
    om.note_fill_pair(4, kid, MEM, MEM)
    om.task_gone(4)
    om.observe(0, 4, key, kid, 0.0, 1.5)
    assert om.interference_pair_obs == 0


def test_disabled_online_never_tags():
    model = InterferenceModel({(MEM, MEM): 1.0})
    om, pd, key, kid = _om(model, enabled=False)
    om.note_fill_pair(4, kid, MEM, MEM)
    assert om._pending_pairs == {}


def test_online_stats_carry_interference_counters():
    model = InterferenceModel({(MEM, MEM): 1.0})
    om, pd, key, kid = _om(model)
    s = om.stats()
    assert s["interference_pair_obs"] == 0
    assert s["interference_updates"] == 0


# ---------------------------------------------------------------------------
# Mini end-to-end: aware beats blind on the adversarial mix
# ---------------------------------------------------------------------------
def _adversarial_tasks(n_hi=40, n_lo=60):
    tasks = [TaskSpec(
        TaskKey("hi"), 0,
        [TraceKernel(KernelID("hi/k"), 0.002, 0.006,
                     kclass=MEM)] * n_hi)]
    tasks.append(TaskSpec(
        TaskKey("lo_mem"), 8,
        [TraceKernel(KernelID("lo_mem/k"), 0.0045, 0.0002,
                     kclass=MEM)] * n_lo,
        arrival=0.001, max_inflight=16))
    tasks.append(TaskSpec(
        TaskKey("lo_cpu"), 8,
        [TraceKernel(KernelID("lo_cpu/k"), 0.004, 0.0002,
                     kclass=COMP)] * n_lo,
        arrival=0.002, max_inflight=16))
    return tasks


TRUE_ENV = {(MEM, MEM): 1.6, (COMP, COMP): 1.15,
            (COMP, MEM): 1.25, (MEM, COMP): 1.05}


def test_aware_beats_blind_end_to_end():
    tasks = _adversarial_tasks()
    pd_a = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    pd_b = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    off = SimScheduler(tasks, Mode.FIKIT, pd_a, jitter=0.0,
                       interference_env=TRUE_ENV).run()
    aware = SimScheduler(tasks, Mode.FIKIT, pd_b, jitter=0.0,
                         interference=InterferenceModel(TRUE_ENV),
                         interference_env=TRUE_ENV).run()
    assert aware.jct(0) < off.jct(0)
    assert aware.fills > 0
    # the blind run pays overshoot (fillers physically bust the gaps);
    # the aware run avoids it entirely on this mix
    assert off.overshoot_time > 0.0
    assert aware.overshoot_time == 0.0


def test_env_without_model_slows_fillers():
    """The physical environment applies regardless of the scheduler's
    beliefs — a filler's simulated duration stretches by the ground-truth
    pair factor even with no model attached."""
    tasks = _adversarial_tasks(n_hi=10, n_lo=20)
    pd_a = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    pd_b = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    clean = SimScheduler(tasks, Mode.FIKIT, pd_a, jitter=0.0).run()
    env = SimScheduler(tasks, Mode.FIKIT, pd_b, jitter=0.0,
                       interference_env=TRUE_ENV).run()
    assert env.jct(0) > clean.jct(0)
