"""Unit tests for scripts/check_doc_refs.py — the docs-integrity CI gate.

The script was the only gate script without its own test file (unlike
``check_bench_gates.py``): a regex regression could silently stop
catching dangling links and the docs job would go green forever. Pinned
here: link-target extraction (scheme/anchor skipping, relative
resolution), the path-shaped-code-span heuristic (what is and is NOT a
checked path), ``check_document``'s missing list, and ``main``'s exit
codes and failure messaging, against synthetic repos in tmp_path.

Also pinned: the serve-CLI verb check (the AST-parsed registry must
equal the live ``repro.launch.serve`` tuples — the one place the
no-imports CI parse could drift from the real argparse tree) and the
``BENCH_*.json`` filename check.
"""
from __future__ import annotations

import pytest

import scripts.check_doc_refs as cdr

pytestmark = pytest.mark.fast


def _fake_repo(tmp_path, monkeypatch, docs=("README.md",)):
    """Point the module at a synthetic repo rooted in tmp_path."""
    monkeypatch.setattr(cdr, "REPO", tmp_path)
    monkeypatch.setattr(cdr, "DOCS", tuple(docs))
    return tmp_path


# ---------------------------------------------------------------------------
# extraction
# ---------------------------------------------------------------------------

def test_link_targets_skip_schemes_and_anchors():
    text = ("[a](docs/x.md) [b](http://x.y/z) [c](https://x.y) "
            "[d](mailto:a@b.c) [e](#section) [f](src/mod.py#L10)")
    got = dict(cdr._iter_link_targets(text))
    assert set(got.values()) == {"docs/x.md", "src/mod.py"}  # anchor cut


def test_code_spans_match_only_path_shaped_spans():
    text = " ".join(f"`{s}`" for s in (
        "src/repro/core/policy.py",           # yes: ext + top dir
        "tests/test_x.py::test_name",         # yes: ::Symbol stripped
        "benchmarks/bench_gates.json",        # yes
        ".github/workflows/ci.yml",           # yes: known top dir
        "docs/missing",                       # yes: top dir, no ext
        "repro.core.policy",                  # no: dotted module, no /
        "python -m scripts.check_doc_refs",   # no: spaces
        "src/<name>.py",                      # no: placeholder chars
        "a/b(c).py",                          # no: call syntax
        "src/*.py",                           # no: glob
        "just_a_word",                        # no: no /
        "vendor/thing.py",                    # no: unknown top dir, but
    ))                                        #     .py ext -> still yes
    got = [p for _, p in cdr._iter_code_paths(text)]
    assert got == ["src/repro/core/policy.py", "tests/test_x.py",
                   "benchmarks/bench_gates.json",
                   ".github/workflows/ci.yml", "docs/missing",
                   "vendor/thing.py"]


def test_code_span_ref_preserves_symbol_qualifier():
    refs = list(cdr._iter_code_paths("`src/m.py::Klass`"))
    assert refs == [("`src/m.py::Klass`", "src/m.py")]


# ---------------------------------------------------------------------------
# check_document
# ---------------------------------------------------------------------------

def test_check_document_resolves_links_relative_to_doc(tmp_path,
                                                       monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "other.md").write_text("x")
    (tmp_path / "README.md").write_text("hello")
    doc = tmp_path / "docs" / "GUIDE.md"
    # sibling link resolves against docs/, parent link against repo root
    doc.write_text("[sib](other.md) [up](../README.md) [gone](nope.md)")
    missing = cdr.check_document(doc)
    assert missing == [("[gone](nope.md)", "nope.md")]


def test_check_document_checks_code_paths_against_repo_root(tmp_path,
                                                            monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "real.py").write_text("pass")
    doc = tmp_path / "README.md"
    doc.write_text("see `src/real.py` and `src/fake.py::Sym` here")
    missing = cdr.check_document(doc)
    assert missing == [("`src/fake.py::Sym`", "src/fake.py")]


def test_check_document_clean_doc_returns_empty(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text("plain prose, a [link](#anchor), `repro.core.policy` "
                   "and `python -m benchmarks.run` — nothing checkable")
    assert cdr.check_document(doc) == []


# ---------------------------------------------------------------------------
# serve CLI verbs
# ---------------------------------------------------------------------------

def test_registry_matches_live_argparse_module():
    """The AST parse must equal the imported module's tuples; if serve.py
    restructures its verb registry, this is the test that fails loudly
    instead of the docs job silently checking nothing."""
    from repro.launch import serve
    verbs, worker_verbs = cdr.serve_verb_registry()
    assert verbs == serve.VERBS
    assert worker_verbs == serve.WORKER_VERBS


def test_unknown_verb_and_subverb_flagged(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text("run `python -m repro.launch.serve frobnicate --x`\n"
                   "then `python -m repro.launch.serve workers explode`\n")
    errs = dict(cdr.check_document(doc))
    assert "unknown serve verb 'frobnicate'" in errs[
        "`-m repro.launch.serve frobnicate`"]
    assert "unknown serve workers sub-verb 'explode'" in errs[
        "`-m repro.launch.serve workers`"]


def test_known_verbs_subverbs_and_flat_form_pass(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text(
        # real verbs, incl. a workers sub-verb and a flag after a verb
        "```\n"
        "python -m repro.launch.serve submit --high x\n"
        "python -m repro.launch.serve workers status --json\n"
        "python -m repro.launch.serve drain --jobstore /tmp/j.db\n"
        # legacy flat form: flags directly after the module, no verb
        "python -m repro.launch.serve \\\n  --high a --lo b\n"
        # usage-line placeholder, not a literal verb
        "python -m repro.launch.serve <verb> ...\n"
        # continuation between module and verb
        "python -m repro.launch.serve \\\n  submit --high x\n"
        "```\n")
    assert cdr.check_document(doc) == []


def test_pipe_joined_verbs_each_validated(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text("`python -m repro.launch.serve cancel|pause|resume`")
    assert cdr.check_document(doc) == []
    doc.write_text("`python -m repro.launch.serve cancel|explode`")
    (ref, err), = cdr.check_document(doc)
    assert "unknown serve verb 'explode'" in err


def test_inline_serve_spans_checked(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text("use `serve workers run` then `serve status`; "
                   "prose mentioning serve alone is not checked")
    assert cdr.check_document(doc) == []
    doc.write_text("use `serve workers explode` here")
    (ref, err), = cdr.check_document(doc)
    assert ref == "`serve workers explode`"
    assert "unknown serve workers sub-verb" in err


# ---------------------------------------------------------------------------
# BENCH_*.json filenames
# ---------------------------------------------------------------------------

def test_bench_json_mentions_must_exist_at_repo_root(tmp_path,
                                                     monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "BENCH_real.json").write_text("{}")
    doc = tmp_path / "README.md"
    doc.write_text("gates in BENCH_real.json and BENCH_ghost.json")
    assert cdr.check_document(doc) == [
        ("`BENCH_ghost.json`", "BENCH_ghost.json not at repo root")]


def test_bench_json_templates_and_globs_skipped(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text("emits `BENCH_<name>.json` files; see BENCH_*.json")
    assert cdr.check_document(doc) == []


# ---------------------------------------------------------------------------
# main: exit codes + messaging
# ---------------------------------------------------------------------------

def test_main_green_path(tmp_path, monkeypatch, capsys):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "README.md").write_text("all good")
    assert cdr.main([]) == 0
    assert "README.md: OK" in capsys.readouterr().out


def test_main_reports_each_dangling_reference(tmp_path, monkeypatch,
                                              capsys):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "README.md").write_text("[a](gone.md) and `src/gone.py`")
    assert cdr.main([]) == 1
    out = capsys.readouterr().out
    assert "dangling reference [a](gone.md) -> gone.md" in out
    assert "dangling reference `src/gone.py` -> src/gone.py" in out
    assert "2 dangling reference(s)" in out


def test_main_missing_document_fails(tmp_path, monkeypatch, capsys):
    _fake_repo(tmp_path, monkeypatch, docs=("README.md", "docs/ARCH.md"))
    (tmp_path / "README.md").write_text("fine")
    assert cdr.main([]) == 1
    assert "MISSING DOCUMENT" in capsys.readouterr().out


def test_main_checks_extra_argv_documents(tmp_path, monkeypatch, capsys):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "README.md").write_text("fine")
    extra = tmp_path / "EXTRA.md"
    extra.write_text("[broken](nowhere.md)")
    assert cdr.main([str(extra)]) == 1
    assert "nowhere.md" in capsys.readouterr().out


def test_repo_docs_are_currently_clean():
    """The real README/ARCHITECTURE must pass — the same invariant the
    CI docs job enforces, kept runnable from the unit suite."""
    assert cdr.main([]) == 0
