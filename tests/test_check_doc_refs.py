"""Unit tests for scripts/check_doc_refs.py — the docs-integrity CI gate.

The script was the only gate script without its own test file (unlike
``check_bench_gates.py``): a regex regression could silently stop
catching dangling links and the docs job would go green forever. Pinned
here: link-target extraction (scheme/anchor skipping, relative
resolution), the path-shaped-code-span heuristic (what is and is NOT a
checked path), ``check_document``'s missing list, and ``main``'s exit
codes and failure messaging, against synthetic repos in tmp_path.
"""
from __future__ import annotations

import pytest

import scripts.check_doc_refs as cdr

pytestmark = pytest.mark.fast


def _fake_repo(tmp_path, monkeypatch, docs=("README.md",)):
    """Point the module at a synthetic repo rooted in tmp_path."""
    monkeypatch.setattr(cdr, "REPO", tmp_path)
    monkeypatch.setattr(cdr, "DOCS", tuple(docs))
    return tmp_path


# ---------------------------------------------------------------------------
# extraction
# ---------------------------------------------------------------------------

def test_link_targets_skip_schemes_and_anchors():
    text = ("[a](docs/x.md) [b](http://x.y/z) [c](https://x.y) "
            "[d](mailto:a@b.c) [e](#section) [f](src/mod.py#L10)")
    got = dict(cdr._iter_link_targets(text))
    assert set(got.values()) == {"docs/x.md", "src/mod.py"}  # anchor cut


def test_code_spans_match_only_path_shaped_spans():
    text = " ".join(f"`{s}`" for s in (
        "src/repro/core/policy.py",           # yes: ext + top dir
        "tests/test_x.py::test_name",         # yes: ::Symbol stripped
        "benchmarks/bench_gates.json",        # yes
        ".github/workflows/ci.yml",           # yes: known top dir
        "docs/missing",                       # yes: top dir, no ext
        "repro.core.policy",                  # no: dotted module, no /
        "python -m scripts.check_doc_refs",   # no: spaces
        "src/<name>.py",                      # no: placeholder chars
        "a/b(c).py",                          # no: call syntax
        "src/*.py",                           # no: glob
        "just_a_word",                        # no: no /
        "vendor/thing.py",                    # no: unknown top dir, but
    ))                                        #     .py ext -> still yes
    got = [p for _, p in cdr._iter_code_paths(text)]
    assert got == ["src/repro/core/policy.py", "tests/test_x.py",
                   "benchmarks/bench_gates.json",
                   ".github/workflows/ci.yml", "docs/missing",
                   "vendor/thing.py"]


def test_code_span_ref_preserves_symbol_qualifier():
    refs = list(cdr._iter_code_paths("`src/m.py::Klass`"))
    assert refs == [("`src/m.py::Klass`", "src/m.py")]


# ---------------------------------------------------------------------------
# check_document
# ---------------------------------------------------------------------------

def test_check_document_resolves_links_relative_to_doc(tmp_path,
                                                       monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "other.md").write_text("x")
    (tmp_path / "README.md").write_text("hello")
    doc = tmp_path / "docs" / "GUIDE.md"
    # sibling link resolves against docs/, parent link against repo root
    doc.write_text("[sib](other.md) [up](../README.md) [gone](nope.md)")
    missing = cdr.check_document(doc)
    assert missing == [("[gone](nope.md)", "nope.md")]


def test_check_document_checks_code_paths_against_repo_root(tmp_path,
                                                            monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "real.py").write_text("pass")
    doc = tmp_path / "README.md"
    doc.write_text("see `src/real.py` and `src/fake.py::Sym` here")
    missing = cdr.check_document(doc)
    assert missing == [("`src/fake.py::Sym`", "src/fake.py")]


def test_check_document_clean_doc_returns_empty(tmp_path, monkeypatch):
    _fake_repo(tmp_path, monkeypatch)
    doc = tmp_path / "README.md"
    doc.write_text("plain prose, a [link](#anchor), `repro.core.policy` "
                   "and `python -m benchmarks.run` — nothing checkable")
    assert cdr.check_document(doc) == []


# ---------------------------------------------------------------------------
# main: exit codes + messaging
# ---------------------------------------------------------------------------

def test_main_green_path(tmp_path, monkeypatch, capsys):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "README.md").write_text("all good")
    assert cdr.main([]) == 0
    assert "README.md: OK" in capsys.readouterr().out


def test_main_reports_each_dangling_reference(tmp_path, monkeypatch,
                                              capsys):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "README.md").write_text("[a](gone.md) and `src/gone.py`")
    assert cdr.main([]) == 1
    out = capsys.readouterr().out
    assert "dangling reference [a](gone.md) -> gone.md" in out
    assert "dangling reference `src/gone.py` -> src/gone.py" in out
    assert "2 dangling reference(s)" in out


def test_main_missing_document_fails(tmp_path, monkeypatch, capsys):
    _fake_repo(tmp_path, monkeypatch, docs=("README.md", "docs/ARCH.md"))
    (tmp_path / "README.md").write_text("fine")
    assert cdr.main([]) == 1
    assert "MISSING DOCUMENT" in capsys.readouterr().out


def test_main_checks_extra_argv_documents(tmp_path, monkeypatch, capsys):
    _fake_repo(tmp_path, monkeypatch)
    (tmp_path / "README.md").write_text("fine")
    extra = tmp_path / "EXTRA.md"
    extra.write_text("[broken](nowhere.md)")
    assert cdr.main([str(extra)]) == 1
    assert "nowhere.md" in capsys.readouterr().out


def test_repo_docs_are_currently_clean():
    """The real README/ARCHITECTURE must pass — the same invariant the
    CI docs job enforces, kept runnable from the unit suite."""
    assert cdr.main([]) == 0
