"""Mode.PREEMPT (kernel-boundary preemptive sharing) in BOTH engines.

Semantics under test (paper Figs 19/20 baseline; cf. arXiv 2401.16529):
- while any strictly-higher-priority task is active, lower-priority
  launches park in the priority queues (the device is reserved at kernel
  boundaries — running kernels are never killed);
- parked work is released as soon as no higher-priority task is active,
  so the low-priority tenant is delayed, never starved;
- no gap filling: the high-priority tier's idle gaps stay idle.
"""
import threading
import time

import pytest

from repro.core.client import HookClient, Segment
from repro.core.executor import WallClockEngine
from repro.core.kernel_id import KernelID
from repro.core.policy import Mode
from repro.core.scheduler import SimScheduler, profile_tasks
from repro.core.task import KernelRequest, TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sim_scenario():
    hi = TaskSpec(TaskKey("hi"), priority=0,
                  kernels=[TraceKernel(KernelID("hi/k"), 0.002, 0.005)] * 20,
                  arrival=0.004)
    # interfering async low-priority co-tenant (floods the device queue)
    lo = TaskSpec(TaskKey("lo"), priority=5,
                  kernels=[TraceKernel(KernelID("lo/k"), 0.003, 0.0002)] * 60,
                  max_inflight=16)
    pd = profile_tasks([hi, lo], T=5, jitter=0.0, measurement_overhead=0.0)
    reps = {m: SimScheduler([hi, lo], m, pd, jitter=0.0).run()
            for m in (Mode.SHARING, Mode.FIKIT, Mode.PREEMPT)}
    return hi, lo, reps


def test_sim_preempt_protects_high_priority(sim_scenario):
    """High-priority JCT under PREEMPT <= under SHARING (the async
    co-tenant inflates SHARING), and stays near solo."""
    hi, lo, reps = sim_scenario
    assert reps[Mode.PREEMPT].jct(0) <= reps[Mode.SHARING].jct(0)
    # near-solo: delayed at most by the one kernel already running at
    # arrival plus queued-at-arrival work drained at the boundary
    assert reps[Mode.PREEMPT].jct(0) < hi.solo_jct * 1.5
    assert reps[Mode.SHARING].jct(0) > hi.solo_jct * 1.5


def test_sim_preempt_low_priority_completes(sim_scenario):
    """No starvation: the parked low-priority task completes once the
    high-priority tasks drain, and every kernel ran exactly once."""
    hi, lo, reps = sim_scenario
    rep = reps[Mode.PREEMPT]
    assert rep.results[1].completion > 0
    lo_execs = sorted(e.seq for e in rep.timeline if e.task == 1)
    assert lo_execs == list(range(len(lo.kernels)))
    # delayed vs sharing, but bounded: it resumes right after hi drains
    assert rep.jct(1) <= reps[Mode.SHARING].jct(1) + hi.solo_jct * 2


def test_sim_preempt_never_fills(sim_scenario):
    _, _, reps = sim_scenario
    assert reps[Mode.PREEMPT].fills == 0
    assert reps[Mode.FIKIT].fills > 0     # same scenario DOES fill in FIKIT


def test_sim_preempt_no_lo_kernel_inside_hi_window(sim_scenario):
    """While the high-priority task is active no NEW low-priority kernel
    starts (at most the pre-arrival backlog finishes: kernel boundaries)."""
    hi, lo, reps = sim_scenario
    rep = reps[Mode.PREEMPT]
    hi_start = min(e.start for e in rep.timeline if e.task == 0)
    hi_end = rep.results[0].completion
    # backlog launched before hi arrived may still run; anything started
    # after the backlog drains must be hi's
    backlog_end = max((e.end for e in rep.timeline
                       if e.task == 1 and e.start < hi_start), default=0.0)
    intruders = [e for e in rep.timeline
                 if e.task == 1 and backlog_end < e.start < hi_end]
    assert intruders == []


# ---------------------------------------------------------------------------
# Wall-clock engine
# ---------------------------------------------------------------------------
def _sleep_segments(name, n, dur, host_gap=0.0):
    def fn(state):
        time.sleep(dur)
        return state
    hw = (lambda s: (time.sleep(host_gap), s)[1]) if host_gap else None
    return [Segment(f"{name}{i}", fn, host_work=hw) for i in range(n)]


def _async_flood(engine, key, priority, instance, n, dur, inflight=6):
    """CUDA-stream-style async client: keeps up to ``inflight`` kernels
    submitted ahead of their completions (the stream window), issuing the
    rest as slots free up. Returns (futures, drain_fn)."""
    engine.task_begin(instance, key, priority)
    futs = []
    window = threading.Semaphore(inflight)

    def feeder():
        for i in range(n):
            window.acquire()
            req = KernelRequest(task_key=key, kernel_id=KernelID(f"lo/k{i}"),
                                priority=priority, task_instance=instance,
                                seq_index=i,
                                payload=lambda d=dur: time.sleep(d))
            fut = engine.submit(req)
            fut.add_done_callback(lambda _f: window.release())
            futs.append(fut)

    feed = threading.Thread(target=feeder)
    feed.start()

    def drain():
        feed.join(timeout=30)
        for f in list(futs):
            f.result(timeout=30)
        engine.task_end(instance)
    return futs, drain


def _run_wallclock(mode):
    key_hi, key_lo = TaskKey("hi"), TaskKey("lo")
    segs_hi = _sleep_segments("hi", 5, 0.002, host_gap=0.004)
    with WallClockEngine(mode) as eng:
        futs, drain = _async_flood(eng, key_lo, priority=5, instance=9001,
                                   n=25, dur=0.003)
        time.sleep(0.006)                  # let the flood hit the device
        hi = HookClient(eng, key_hi, 0, segs_hi)
        _, hi_jct = hi.run("x")
        drain()
        recs = eng.records()
    return hi_jct, recs


def test_wallclock_preempt_beats_sharing():
    """High-priority JCT under PREEMPT <= under SHARING against the same
    interfering async low-priority flood; the flood still completes."""
    hi_share, recs_share = _run_wallclock(Mode.SHARING)
    hi_pre, recs_pre = _run_wallclock(Mode.PREEMPT)
    assert hi_pre <= hi_share
    # sharing ran ~75ms of low-priority work ahead of hi; preempt parks it
    solo = 5 * 0.002 + 4 * 0.004
    assert hi_share > solo * 1.8
    assert hi_pre < hi_share * 0.8
    # no starvation: every low-priority kernel executed in both modes
    for recs in (recs_share, recs_pre):
        assert len([r for r in recs if r.req.task_key.process == "lo"]) == 25
        assert len([r for r in recs if r.req.task_key.process == "hi"]) == 5


def test_wallclock_preempt_defers_lo_behind_hi():
    """Under PREEMPT the low-priority kernels that ran while the
    high-priority task was active are only the pre-arrival backlog."""
    _, recs = _run_wallclock(Mode.PREEMPT)
    hi_recs = [r for r in recs if r.req.task_key.process == "hi"]
    lo_recs = [r for r in recs if r.req.task_key.process == "lo"]
    hi_start, hi_end = hi_recs[0].start, hi_recs[-1].end
    started_inside = [r for r in lo_recs if hi_start < r.start < hi_end]
    # kernel-boundary preemption: at most the pre-arrival stream window
    # (6 in-flight submits already past the scheduler) runs inside hi's
    # span — with the rest of the flood parked, hi's own gaps stay idle
    assert len(started_inside) <= 6
    # stream order is preserved for the flood
    seqs = [r.req.seq_index for r in lo_recs]
    assert seqs == sorted(seqs)


def test_wallclock_preempt_equal_priority_shares():
    """Equal priority under PREEMPT degenerates to FIFO sharing (case C):
    neither task parks the other."""
    key_a, key_b = TaskKey("a"), TaskKey("b")
    with WallClockEngine(Mode.PREEMPT) as eng:
        ca = HookClient(eng, key_a, 3, _sleep_segments("a", 4, 0.002))
        cb = HookClient(eng, key_b, 3, _sleep_segments("b", 4, 0.002))
        res = {}
        ta = threading.Thread(target=lambda: res.setdefault("a", ca.run("x")))
        tb = threading.Thread(target=lambda: res.setdefault("b", cb.run("x")))
        ta.start(); tb.start()
        ta.join(); tb.join()
        assert eng.policy.queued == 0
    assert "a" in res and "b" in res
