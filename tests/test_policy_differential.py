"""Differential tests: SimScheduler vs a bare FikitPolicy under a virtual
clock must make IDENTICAL scheduling decisions.

``SimScheduler`` is a thin driver over ``repro.core.policy.FikitPolicy``.
To prove the driver adds no scheduling behavior of its own, this module
re-implements the client/device event model *independently* (closures over
a heap instead of the sim's string-dispatched events), drives the same
scenarios through both, and asserts the two policies produced identical
decision traces — launch order, fill decisions, queue parks, gap
open/close, and holder transitions.

A second differential axis guards the O(log n) fast path: the indexed
``best_prio_fit`` + cached holder election (``reference=False``, the
default) must produce traces identical to the O(n) reference oracle
(``reference=True``: linear-scan BestPrioFit, holder re-elected per probe)
on randomized scenarios — 100 seeds x {FIKIT, PREEMPT} = 200 cases, with
durations drawn from a small discrete set so predicted-duration TIES are
common (the tie-break is where an indexed structure most easily diverges
from a scan).

A third axis covers the non-FIFO queue disciplines the same way: ``sjf``
(successor search over the duration index) and ``edf`` (deadline index +
deadline tie-breaks) each run 200 randomized deadline-tagged cases against
their O(n) reference scans. The FIFO default needs no new cases — the
original 200 run it unchanged, which IS the bit-identity guarantee.

A fourth axis pins the online measurement loop's OFF state: a simulator
with the subsystem wired-but-disabled (``OnlineConfig(enabled=False)``)
must be byte-identical in traces and timelines to one with no subsystem
at all, on randomized (jittered, deadline-tagged, multi-device) scenarios.

Also hosts the policy invariant tests:
- fillers never come from a priority level above (numerically below) the
  holder's;
- ``fills_in_flight`` never exceeds ``pipeline_depth``;
- overshoot accounting is non-negative;
- FIFO order within one priority-queue level (releases preserve park
  order);
- per-task stream order: a task's kernels reach the device in seq order.
"""
import heapq
import itertools
import random

import pytest

from repro.core.interference import (COMPUTE_BOUND, MEMORY_BOUND,
                                     InterferenceModel)
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig
from repro.core.policy import FikitPolicy, Mode
from repro.core.scheduler import SimScheduler, profile_tasks
from repro.core.task import KernelRequest, TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# Independent virtual-clock driver
# ---------------------------------------------------------------------------
class VirtualHarness:
    """Event-driven client+device model over a bare FikitPolicy.

    Deliberately written against the policy's public API only, with its
    own event structure, so it cannot share a driver bug with
    SimScheduler. No jitter, exact durations."""

    def __init__(self, tasks, mode, profiled, pipeline_depth=2,
                 discipline="fifo", reference=False, interference=None):
        self.tasks = tasks
        self.now = 0.0
        self.device_free = 0.0
        self._heap = []
        self._tick = itertools.count()
        self.launch_order = []               # (task, seq, filler)
        self._issued = [0] * len(tasks)
        self._done = [0] * len(tasks)
        self._parked_issue = [None] * len(tasks)
        self.policy = FikitPolicy(mode, profiled,
                                  pipeline_depth=pipeline_depth,
                                  clock=lambda: self.now,
                                  launch=self._to_device,
                                  discipline=discipline,
                                  reference=reference,
                                  interference=interference)

    def _at(self, t, fn):
        heapq.heappush(self._heap, (t, next(self._tick), fn))

    def run(self):
        for ti, spec in enumerate(self.tasks):
            self._at(spec.arrival, lambda ti=ti: self._arrive(ti))
        while self._heap:
            self.now, _, fn = heapq.heappop(self._heap)
            fn()
        return self

    # ---- client model
    def _arrive(self, ti):
        spec = self.tasks[ti]
        if self.policy.task_begin(ti, spec.key, spec.priority,
                                  arrival=spec.arrival):
            self._try_issue(ti, 0)

    def _try_issue(self, ti, ki):
        spec = self.tasks[ti]
        if ki >= len(spec.kernels):
            return
        if self._issued[ti] - self._done[ti] >= spec.max_inflight:
            self._parked_issue[ti] = ki
            return
        self._issue(ti, ki)

    def _issue(self, ti, ki):
        spec = self.tasks[ti]
        self._issued[ti] += 1
        k = spec.kernels[ki]
        if spec.max_inflight > 1 and ki + 1 < len(spec.kernels):
            self._at(self.now + k.gap_after,
                     lambda: self._try_issue(ti, ki + 1))
        self.policy.submit(KernelRequest(
            task_key=spec.key, kernel_id=k.kid, priority=spec.priority,
            task_instance=ti, seq_index=ki, submit_time=self.now,
            payload=k.duration, deadline=spec.deadline))

    # ---- serial device model
    def _to_device(self, req, filler):
        start = max(self.now, self.device_free)
        end = start + float(req.payload)
        self.device_free = end
        self.launch_order.append((req.task_instance, req.seq_index, filler))
        self._at(end, lambda: self._kernel_done(req, filler))

    def _kernel_done(self, req, filler):
        ti, ki = req.task_instance, req.seq_index
        spec = self.tasks[ti]
        self._done[ti] += 1
        if filler:
            self.policy.fill_complete()
        last = ki == len(spec.kernels) - 1
        if last:
            for nxt in self.policy.task_end(ti):
                self._try_issue(nxt, 0)
        elif spec.max_inflight == 1:
            self._at(self.now + spec.kernels[ki].gap_after,
                     lambda: self._try_issue(ti, ki + 1))
        elif self._parked_issue[ti] is not None:
            nxt, self._parked_issue[ti] = self._parked_issue[ti], None
            self._issue(ti, nxt)
        self.policy.kernel_end(ti, spec.kernels[ki].kid, last=last,
                               actual_gap=spec.kernels[ki].gap_after)


# ---------------------------------------------------------------------------
# Scenarios: sync + async clients, >= 3 priority levels, staggered arrivals
# ---------------------------------------------------------------------------
def k(name, dur, gap=0.0):
    return TraceKernel(KernelID(name), dur, gap)


def scenario_gap_fill():
    """Sync high-prio with big gaps + sync low-prio: classic FIKIT fill."""
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.006)] * 10),
        TaskSpec(TaskKey("lo"), 5, [k("lo/a", 0.003, 0.0005)] * 12,
                 arrival=0.001),
    ]


def scenario_three_tiers():
    """3 priority levels; async device-bound bottom tier."""
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.005)] * 8),
        TaskSpec(TaskKey("mid"), 2, [k("mid/a", 0.001, 0.002)] * 10,
                 arrival=0.002),
        TaskSpec(TaskKey("lo"), 7, [k("lo/a", 0.004, 0.0001)] * 14,
                 arrival=0.0005, max_inflight=4),
    ]


def scenario_churn():
    """Equal-priority pair + late high-prio arrival + async floods; tests
    holder hand-off, equal-prio FIFO, and release-on-done."""
    return [
        TaskSpec(TaskKey("a"), 3, [k("a/x", 0.002, 0.001)] * 9),
        TaskSpec(TaskKey("b"), 3, [k("b/x", 0.0015, 0.0008)] * 9,
                 arrival=0.0002),
        TaskSpec(TaskKey("boss"), 1, [k("boss/x", 0.001, 0.004)] * 6,
                 arrival=0.01),
        TaskSpec(TaskKey("bulk"), 9, [k("bulk/x", 0.0025, 0.0001)] * 16,
                 arrival=0.004, max_inflight=8),
    ]


SCENARIOS = {
    "gap_fill": scenario_gap_fill,
    "three_tiers": scenario_three_tiers,
    "churn": scenario_churn,
}


def _profiles(tasks):
    return profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)


# ---------------------------------------------------------------------------
# Randomized scenarios for the indexed-vs-oracle differential
# ---------------------------------------------------------------------------
# durations from a small discrete grid -> frequent predicted-duration ties
# across tasks, stressing the index's FIFO tie-break against the scan's
_DUR_GRID = [0.0005, 0.001, 0.0015, 0.002, 0.003, 0.004, 0.006]
_GAP_GRID = [0.0, 0.0003, 0.001, 0.0025, 0.005, 0.008]
# deadlines from a grid too (relative to arrival), None included: EDF's
# undated-falls-back-to-FIFO path and deadline TIES both get exercised
_DEADLINE_GRID = [None, 0.004, 0.008, 0.008, 0.02, 0.05]


def random_tasks(rng, deadlines=False, classes=False):
    n = rng.randint(2, 5)
    specs = []
    for t in range(n):
        nk = rng.randint(2, 12)
        kid = KernelID(f"svc{t}/k")
        # one class per kid (classes ARE per kernel identity): None keeps
        # the kernel unclassified -> compute-bound default in scoring
        kc = (rng.choice([COMPUTE_BOUND, MEMORY_BOUND, None])
              if classes else None)
        kernels = [TraceKernel(kid, rng.choice(_DUR_GRID),
                               rng.choice(_GAP_GRID), kclass=kc)
                   for _ in range(nk)]
        arrival = rng.choice([0.0, 0.0005, 0.002, 0.006, 0.012])
        rel_dl = rng.choice(_DEADLINE_GRID) if deadlines else None
        specs.append(TaskSpec(
            TaskKey(f"svc{t}"), rng.randint(0, 9), kernels,
            arrival=arrival,
            max_inflight=rng.choice([1, 1, 1, 4, 8]),
            deadline=None if rel_dl is None else arrival + rel_dl))
    return specs


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("seed", range(100))
def test_indexed_fast_path_matches_reference_oracle(seed, mode):
    """Indexed best_prio_fit + cached holder vs the O(n) reference scan +
    per-probe election: identical traces and device launch order."""
    rng = random.Random(seed * 7919 + (0 if mode is Mode.FIKIT else 1))
    tasks = random_tasks(rng)
    pd = _profiles(tasks)
    fast = VirtualHarness(tasks, mode, pd, reference=False).run()
    ref = VirtualHarness(tasks, mode, pd, reference=True).run()
    assert fast.policy.trace == ref.policy.trace
    assert fast.launch_order == ref.launch_order
    assert fast.policy.fill_count == ref.policy.fill_count
    # the fast path also agrees with SimScheduler end-to-end
    sim = SimScheduler(tasks, mode, pd, jitter=0.0)
    sim.run()
    assert sim.policy.trace == fast.policy.trace


@pytest.mark.parametrize("discipline", ["sjf", "edf"])
@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("seed", range(100))
def test_discipline_fast_path_matches_reference_oracle(seed, mode,
                                                       discipline):
    """Each non-FIFO queue discipline's indexed path (successor/deadline
    bisects + index-driven pops) vs its O(n) reference scan (scan-selected
    BestPrioFit + scan-selected pops, holder re-elected per probe):
    identical traces and device launch order on deadline-tagged, tie-heavy
    randomized scenarios — 100 seeds x {FIKIT, PREEMPT} = 200 cases per
    discipline. The ROADMAP's rule for touching decision logic: every new
    discipline extends THIS suite."""
    rng = random.Random(seed * 104729 + (0 if mode is Mode.FIKIT else 1)
                        + (0 if discipline == "sjf" else 500))
    tasks = random_tasks(rng, deadlines=True)
    pd = _profiles(tasks)
    fast = VirtualHarness(tasks, mode, pd, discipline=discipline,
                          reference=False).run()
    ref = VirtualHarness(tasks, mode, pd, discipline=discipline,
                         reference=True).run()
    assert fast.policy.trace == ref.policy.trace
    assert fast.launch_order == ref.launch_order
    assert fast.policy.fill_count == ref.policy.fill_count
    # the fast path also agrees with SimScheduler end-to-end (deadlines
    # ride KernelRequest through the placement pass-through unchanged)
    sim = SimScheduler(tasks, mode, pd, jitter=0.0,
                       queue_discipline=discipline)
    sim.run()
    assert sim.policy.trace == fast.policy.trace


# ---------------------------------------------------------------------------
# Differential: online measurement OFF is bit-identical to no subsystem
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("seed", range(30))
def test_online_off_is_bit_identical(seed, mode):
    """The online measurement loop's standing contract: ``online=None``
    (nothing built) and ``online=OnlineConfig(enabled=False)`` (subsystem
    wired through placement/policy but disabled) produce byte-identical
    decision traces and device timelines on randomized scenarios. The
    observation plumbing — start/end riding every kernel_end, the
    cold-start-capable ProfiledData — must cost zero decisions when off."""
    rng = random.Random(seed * 65537 + (0 if mode is Mode.FIKIT else 1))
    tasks = random_tasks(rng, deadlines=True)
    pd_a = _profiles(tasks)
    pd_b = _profiles(tasks)
    base = SimScheduler(tasks, mode, pd_a, jitter=0.02, seed=seed)
    rep_a = base.run()
    wired = SimScheduler(tasks, mode, pd_b, jitter=0.02, seed=seed,
                         online=OnlineConfig(enabled=False))
    rep_b = wired.run()
    assert wired.online is not None            # subsystem IS constructed
    assert base.policy.trace == wired.policy.trace
    assert [e.__dict__ for e in rep_a.timeline] == \
        [e.__dict__ for e in rep_b.timeline]
    assert wired.online.observations == 0      # and never observed
    assert not pd_b.cold_start                 # nor flipped cold start
    assert rep_b.online_stats is None


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("seed", range(10))
def test_online_off_matches_across_devices(seed, mode):
    """Same contract through the multi-device placement path (per-device
    buffers exist, observe() still never runs)."""
    rng = random.Random(seed * 52361 + (0 if mode is Mode.FIKIT else 1))
    tasks = random_tasks(rng)
    pd_a = _profiles(tasks)
    pd_b = _profiles(tasks)
    rep_a = SimScheduler(tasks, mode, pd_a, jitter=0.0, devices=3).run()
    rep_b = SimScheduler(tasks, mode, pd_b, jitter=0.0, devices=3,
                         online=OnlineConfig(enabled=False)).run()
    assert [e.__dict__ for e in rep_a.timeline] == \
        [e.__dict__ for e in rep_b.timeline]
    assert rep_a.steals == rep_b.steals


# ---------------------------------------------------------------------------
# Differential: interference OFF is bit-identical to no model at all
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("seed", range(50))
def test_interference_off_is_bit_identical(seed, mode):
    """The interference model's standing contract: ``interference=None``
    (nothing built) and a wired-but-disabled model
    (``InterferenceModel(enabled=False)``) produce byte-identical
    decision traces and device timelines on randomized class-tagged
    scenarios — the class plumbing (kclass on profiles, per-class queue
    sub-indexes, the holder-class gap bookkeeping) must cost zero
    decisions when off. 50 seeds x {FIKIT, PREEMPT} = 100 cases."""
    rng = random.Random(seed * 65537 + (2 if mode is Mode.FIKIT else 3))
    tasks = random_tasks(rng, deadlines=True, classes=True)
    pd_a = _profiles(tasks)
    pd_b = _profiles(tasks)
    base = SimScheduler(tasks, mode, pd_a, jitter=0.02, seed=seed)
    rep_a = base.run()
    wired = SimScheduler(tasks, mode, pd_b, jitter=0.02, seed=seed,
                         interference=InterferenceModel(enabled=False))
    rep_b = wired.run()
    assert wired.interference is not None       # model IS constructed
    assert base.policy.trace == wired.policy.trace
    assert [e.__dict__ for e in rep_a.timeline] == \
        [e.__dict__ for e in rep_b.timeline]


# ---------------------------------------------------------------------------
# Differential: interference ON — indexed per-class search vs O(n) scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("discipline", ["fifo", "sjf", "edf"])
@pytest.mark.parametrize("seed", range(40))
def test_interference_fast_path_matches_reference_oracle(seed, discipline):
    """With an ENABLED interference model and random per-pair
    coefficients, the indexed per-class selection (``_Level.cindex``
    bisects) must make bit-identical decisions to the O(n) reference
    scan's tightened-limit walk — for every queue discipline, on
    class-tagged, tie-heavy randomized scenarios."""
    rng = random.Random(seed * 15485863
                        + {"fifo": 0, "sjf": 1, "edf": 2}[discipline])
    tasks = random_tasks(rng, deadlines=(discipline == "edf"),
                         classes=True)
    pd = _profiles(tasks)
    coeffs = {(h, f): round(rng.uniform(1.0, 2.0), 3)
              for h in (COMPUTE_BOUND, MEMORY_BOUND)
              for f in (COMPUTE_BOUND, MEMORY_BOUND)}
    model = InterferenceModel(coeffs)
    fast = VirtualHarness(tasks, Mode.FIKIT, pd, discipline=discipline,
                          reference=False, interference=model).run()
    ref = VirtualHarness(tasks, Mode.FIKIT, pd, discipline=discipline,
                         reference=True, interference=model).run()
    assert fast.policy.trace == ref.policy.trace
    assert fast.launch_order == ref.launch_order
    assert fast.policy.fill_count == ref.policy.fill_count
    # the fast path also agrees with SimScheduler end-to-end
    sim = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                       queue_discipline=discipline, interference=model)
    sim.run()
    assert sim.policy.trace == fast.policy.trace


# ---------------------------------------------------------------------------
# Differential: identical decision traces
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_sim_and_policy_traces_identical(name, mode):
    tasks = SCENARIOS[name]()
    pd = _profiles(tasks)
    sim = SimScheduler(tasks, mode, pd, jitter=0.0)
    sim.run()
    harness = VirtualHarness(tasks, mode, pd).run()

    assert sim.policy.trace == harness.policy.trace

    # the assertions below are implied by trace equality; keep them
    # explicit so a failure names the divergent dimension directly
    def pick(trace, kinds):
        return [e for e in trace if e[0] in kinds]

    launches = ("launch", "fill", "release", "drain")
    assert pick(sim.policy.trace, launches) == \
        pick(harness.policy.trace, launches), "launch order diverged"
    assert pick(sim.policy.trace, ("fill",)) == \
        pick(harness.policy.trace, ("fill",)), "fill decisions diverged"
    assert pick(sim.policy.trace, ("holder",)) == \
        pick(harness.policy.trace, ("holder",)), "holder transitions diverged"

    # and the sim's device timeline agrees with the harness's launch order
    sim_order = [(e.task, e.seq, e.filler) for e in sim.timeline]
    assert sim_order == harness.launch_order


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_fikit_fills_preempt_does_not(name):
    tasks = SCENARIOS[name]()
    pd = _profiles(tasks)
    pre = SimScheduler(tasks, Mode.PREEMPT, pd, jitter=0.0).run()
    assert pre.fills == 0
    if name == "gap_fill":
        fik = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0).run()
        assert fik.fills > 0


# ---------------------------------------------------------------------------
# Invariants (checked on every scenario x mode via the trace)
# ---------------------------------------------------------------------------
def _run_sim(name, mode, pipeline_depth=2):
    tasks = SCENARIOS[name]()
    pd = _profiles(tasks)
    sim = SimScheduler(tasks, mode, pd, pipeline_depth=pipeline_depth,
                       jitter=0.0)
    rep = sim.run()
    return tasks, sim, rep


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_invariant_fill_below_holder_priority(name, mode):
    """A filler always comes from a strictly lower priority level than the
    holder that opened the gap (its own requests launch directly)."""
    tasks, sim, _ = _run_sim(name, mode)
    holder = None
    for e in sim.policy.trace:
        if e[0] == "holder":
            holder = e[1]
        elif e[0] == "fill":
            assert holder is not None
            assert tasks[e[1]].priority > tasks[holder].priority


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("depth", [1, 2, 3])
def test_invariant_fills_in_flight_bounded(name, depth):
    """fills_in_flight <= pipeline_depth at every decision point."""
    tasks = SCENARIOS[name]()
    pd = _profiles(tasks)
    max_seen = 0

    class Probe(VirtualHarness):
        def _to_device(self, req, filler):
            nonlocal max_seen
            max_seen = max(max_seen, self.policy.fills_in_flight)
            super()._to_device(req, filler)

    h = Probe(tasks, Mode.FIKIT, pd, pipeline_depth=depth).run()
    assert 0 < len(h.launch_order)
    assert max_seen <= depth
    assert h.policy.fills_in_flight == 0          # all fills drained


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_invariant_overshoot_nonnegative(name, mode):
    _, sim, rep = _run_sim(name, mode)
    assert rep.overshoot_time >= 0.0
    assert sim.policy.overshoot_time == rep.overshoot_time


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_invariant_stream_order(name, mode):
    """Each task's kernels reach the device in seq order (fillers must not
    reorder a stream)."""
    tasks, sim, rep = _run_sim(name, mode)
    per_task = {}
    for e in rep.timeline:
        per_task.setdefault(e.task, []).append(e.seq)
    for ti, seqs in per_task.items():
        assert seqs == sorted(seqs), f"task {ti} reordered: {seqs}"
        assert seqs == list(range(len(tasks[ti].kernels)))


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
def test_invariant_fifo_within_level(mode):
    """Requests parked at ONE priority level are released in park order."""
    tasks = scenario_churn()
    pd = _profiles(tasks)
    sim = SimScheduler(tasks, mode, pd, jitter=0.0)
    sim.run()
    parked, released = [], []
    for e in sim.policy.trace:
        if e[0] == "queue" and tasks[e[1]].priority == 9:
            parked.append((e[1], e[2]))
        elif e[0] in ("release", "drain") and tasks[e[1]].priority == 9:
            released.append((e[1], e[2]))
    # every level-9 request that was parked and later released (not
    # filled) keeps FIFO order
    released_set = [p for p in parked if p in released]
    assert released_set == [r for r in released if r in parked]


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
def test_ring_sink_records_same_suffix_as_list(mode):
    """The ring trace sink must record exactly what the list sink records
    (modulo capacity): a full-capacity ring equals the list trace, and an
    undersized ring holds precisely the list trace's suffix. This puts the
    ring path under the same oracle as the default sink."""
    tasks = scenario_churn()
    pd = _profiles(tasks)
    ref = SimScheduler(tasks, mode, pd, jitter=0.0, trace="list")
    ref.run()
    full = list(ref.policy.trace)
    assert full, "scenario produced no decisions"

    # default "ring" capacity (4096) far exceeds the scenario: identical
    ring = SimScheduler(tasks, mode, pd, jitter=0.0, trace="ring")
    ring.run()
    assert list(ring.policy.trace) == full

    # a deliberately tiny ring keeps exactly the most recent decisions
    cap = max(4, len(full) // 3)
    tiny = SimScheduler(tasks, mode, pd, jitter=0.0, trace=cap)
    tiny.run()
    assert list(tiny.policy.trace) == full[-cap:]


def test_holder_election_order():
    """Holder = (priority, arrival, instance) lexicographic minimum."""
    pd = _profiles(scenario_three_tiers())
    events = []
    pol = FikitPolicy(Mode.FIKIT, pd, clock=lambda: 0.0,
                      launch=lambda req, filler: events.append(req))
    assert pol.holder() is None
    pol.task_begin(0, TaskKey("lo"), 5, arrival=0.0)
    assert pol.holder() == 0
    pol.task_begin(1, TaskKey("hi"), 0, arrival=1.0)
    assert pol.holder() == 1                      # priority dominates
    pol.task_begin(2, TaskKey("hi2"), 0, arrival=0.5)
    assert pol.holder() == 2                      # earlier arrival wins tie
    pol.task_end(2)
    assert pol.holder() == 1
    pol.task_end(1)
    assert pol.holder() == 0
    transitions = [e for e in pol.trace if e[0] == "holder"]
    assert transitions == [("holder", 0), ("holder", 1), ("holder", 2),
                           ("holder", 1), ("holder", 0)]
