"""Wall-clock engine + hook-client integration tests (real threads, tiny
sleep-based kernels so tests are fast and robust)."""
import threading
import time

import pytest

from repro.core.client import HookClient, Segment
from repro.core.executor import WallClockEngine
from repro.core.profiler import ProfiledData, Profiler
from repro.core.scheduler import Mode
from repro.core.task import TaskKey

pytestmark = pytest.mark.fast


def sleep_segments(name, n, dur, host_gap=0.0):
    def fn(state):
        time.sleep(dur)
        return state
    hw = (lambda s: (time.sleep(host_gap), s)[1]) if host_gap else None
    return [Segment(f"{name}{i}", fn, host_work=hw) for i in range(n)]


def test_engine_runs_and_records():
    key = TaskKey("svc")
    with WallClockEngine(Mode.SHARING) as eng:
        cl = HookClient(eng, key, 0, sleep_segments("s", 4, 0.002))
        _, jct = cl.run("state")
    recs = eng.records()
    assert len(recs) == 4
    assert jct >= 0.008
    # serial device: no overlapping intervals
    recs = sorted(recs, key=lambda r: r.start)
    for a, b in zip(recs, recs[1:]):
        assert b.start >= a.end - 1e-9


def test_measurement_produces_profile():
    key = TaskKey("svc")
    prof = Profiler(key)
    with WallClockEngine(Mode.EXCLUSIVE) as eng:
        cl = HookClient(eng, key, 0,
                        sleep_segments("m", 3, 0.004, host_gap=0.003))
        for _ in range(3):
            cl.measure_run("state", prof)
    stats = prof.statistics()
    assert stats.runs == 3
    assert len(stats.SK) == 3
    for v in stats.SK.values():
        assert 0.003 < v < 0.02          # ~4ms measured
    for v in stats.SG.values():
        assert v > 0.002                 # host gap visible as device idle


def test_exclusive_serializes_tasks():
    key_a, key_b = TaskKey("a"), TaskKey("b")
    order = []

    def seg(name):
        def fn(state):
            order.append(name)
            time.sleep(0.003)
            return state
        return [Segment(name + str(i), fn) for i in range(3)]

    with WallClockEngine(Mode.EXCLUSIVE) as eng:
        ca = HookClient(eng, key_a, 0, seg("a"))
        cb = HookClient(eng, key_b, 0, seg("b"))
        ta = threading.Thread(target=lambda: ca.run("x"))
        tb = threading.Thread(target=lambda: cb.run("x"))
        ta.start()
        time.sleep(0.005)
        tb.start()
        ta.join(); tb.join()
    # no interleaving: all of one task before the other
    joined = "".join(order)
    assert joined in ("aaabbb", "bbbaaa")


def test_fikit_mode_prioritizes_and_fills():
    key_hi, key_lo = TaskKey("hi"), TaskKey("lo")
    segs_hi = sleep_segments("hi", 5, 0.002, host_gap=0.006)
    segs_lo = sleep_segments("lo", 8, 0.002)

    # profile both
    pd = ProfiledData()
    for key, segs in ((key_hi, segs_hi), (key_lo, segs_lo)):
        prof = Profiler(key)
        with WallClockEngine(Mode.EXCLUSIVE) as eng:
            cl = HookClient(eng, key, 0, segs)
            for _ in range(3):
                cl.measure_run("x", prof)
        pd.load(prof.statistics())

    with WallClockEngine(Mode.FIKIT, pd) as eng:
        hi = HookClient(eng, key_hi, 0, segs_hi)
        lo = HookClient(eng, key_lo, 5, segs_lo)
        res = {}
        tl = threading.Thread(
            target=lambda: res.setdefault("lo", lo.run("x")[1]))
        th = threading.Thread(
            target=lambda: res.setdefault("hi", hi.run("x")[1]))
        tl.start()
        time.sleep(0.004)
        th.start()
        th.join(); tl.join()
        fills = eng.fill_count
    solo_hi = 5 * 0.002 + 4 * 0.006
    # high-priority stays near its solo JCT (some fills may overshoot)
    assert res["hi"] < solo_hi * 2.2
    assert fills > 0                     # low kernels ran inside hi's gaps
    assert res["lo"] > 0


def test_multi_device_threads_spread_and_steal():
    """devices=2: two real device threads. A pinned discipline co-locates
    hi+lo on device 0 (lo parks behind the hi holder) and sends tiny to
    device 1; when tiny retires, device 1 goes idle and must steal the
    fully-parked lo — across threads, with stream order preserved."""
    from repro.core.kernel_id import KernelID
    from repro.core.task import KernelRequest

    def pin(layer, instance, key, priority, arrival):
        return 1 if key.process == "tiny" else 0

    def sleeper(dur):
        def call():
            time.sleep(dur)
        return call

    def reqs_for(key, prio, inst, n, dur):
        return [KernelRequest(task_key=key, kernel_id=KernelID(f"{key.process}/k"),
                              priority=prio, task_instance=inst, seq_index=i,
                              payload=sleeper(dur)) for i in range(n)]

    key_hi, key_lo, key_tiny = TaskKey("hi"), TaskKey("lo"), TaskKey("tiny")
    with WallClockEngine(Mode.FIKIT, devices=2, discipline=pin) as eng:
        # tiny FIRST: it must occupy device 1, otherwise lo's first parked
        # submit already finds device 1 idle and steals immediately
        eng.task_begin(3, key_tiny, 9)
        tiny_futs = [eng.submit(r)
                     for r in reqs_for(key_tiny, 9, 3, 1, 0.02)]
        eng.task_begin(1, key_hi, 0)
        hi_futs = [eng.submit(r) for r in reqs_for(key_hi, 0, 1, 4, 0.02)]
        eng.task_begin(2, key_lo, 5)         # parks behind the hi holder
        lo_futs = [eng.submit(r) for r in reqs_for(key_lo, 5, 2, 2, 0.003)]
        assert eng.steal_count == 0          # both devices busy: no steal
        for f in tiny_futs:
            f.result(timeout=5)
        eng.task_end(3)                      # device 1 idle -> steal lo
        assert eng.steal_count == 1          # synchronous under the lock
        for f in lo_futs:                    # stolen work actually runs
            f.result(timeout=5)
        eng.task_end(2)
        for f in hi_futs:
            f.result(timeout=5)
        eng.task_end(1)
        recs = eng.records()
    by_task = {}
    for r in recs:
        by_task.setdefault(r.req.task_instance, []).append(r)
    # lo migrated: both kernels ran on device 1, in seq order
    assert [r.device for r in by_task[2]] == [1, 1]
    lo_sorted = sorted(by_task[2], key=lambda r: r.start)
    assert [r.req.seq_index for r in lo_sorted] == [0, 1]
    # hi stayed on device 0 and was never blocked behind lo
    assert all(r.device == 0 for r in by_task[1])
    # per-device serial execution
    for d in (0, 1):
        spans = sorted((r.start, r.end) for r in recs if r.device == d)
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-9
