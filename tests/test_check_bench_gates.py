"""Unit tests for scripts/check_bench_gates.py error surfaces: a gated
BENCH json that is missing or malformed must produce a NAMED, actionable
failure line (which bench, what to re-run) — never a raw traceback."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from scripts.check_bench_gates import (ALL_GATED, DEFAULT_REQUIRED,  # noqa: E402
                                       main, run_gates)

pytestmark = pytest.mark.fast

GOOD_RECOVERY = {
    "smoke": True,
    "recovery_sweep": {"per_job_us": {"50": 60.0, "200": 55.0},
                       "growth_vs_smallest": 0.92, "size_ratio": 4.0},
    "cancel_storm": {"hi_jct_ratio_vs_no_storm": 1.0},
}

TOL = {"recovery": {"max_recovery_us_per_job": 2000.0,
                    "max_recovery_growth": 3.0,
                    "max_cancel_storm_hi_jct_ratio": 1.05}}


def _setup(tmp_path, payload):
    tol = tmp_path / "gates.json"
    tol.write_text(json.dumps(TOL))
    if payload is not None:
        (tmp_path / "BENCH_recovery.json").write_text(payload)
    return tol


def test_passing_payload(tmp_path, capsys):
    tol = _setup(tmp_path, json.dumps(GOOD_RECOVERY))
    assert run_gates({"recovery"}, repo=tmp_path, tolerances_path=tol) == 0
    out = capsys.readouterr().out
    assert "ok   recovery" in out


def test_missing_required_bench_is_named_and_actionable(tmp_path, capsys):
    tol = _setup(tmp_path, None)
    assert run_gates({"recovery"}, repo=tmp_path, tolerances_path=tol) == 1
    out = capsys.readouterr().out
    assert "FAIL recovery" in out
    assert "BENCH_recovery.json missing" in out
    assert "benchmarks.run --only recovery" in out       # how to fix it
    assert "Traceback" not in out


def test_missing_optional_bench_is_skipped(tmp_path, capsys):
    tol = _setup(tmp_path, None)
    assert run_gates(set(), repo=tmp_path, tolerances_path=tol) == 0
    assert "skip recovery" in capsys.readouterr().out


def test_malformed_json_is_named_not_traceback(tmp_path, capsys):
    tol = _setup(tmp_path, '{"recovery_sweep": {truncated mid-wri')
    assert run_gates({"recovery"}, repo=tmp_path, tolerances_path=tol) == 1
    out = capsys.readouterr().out
    assert "FAIL recovery" in out
    assert "not valid JSON" in out
    assert "benchmarks.run --only recovery" in out
    assert "Traceback" not in out


def test_missing_field_is_named_not_traceback(tmp_path, capsys):
    broken = dict(GOOD_RECOVERY)
    del broken["cancel_storm"]
    tol = _setup(tmp_path, json.dumps(broken))
    assert run_gates({"recovery"}, repo=tmp_path, tolerances_path=tol) == 1
    out = capsys.readouterr().out
    assert "FAIL recovery" in out and "malformed" in out
    assert "Traceback" not in out


def test_regressing_payload_fails_gate(tmp_path, capsys):
    bad = json.loads(json.dumps(GOOD_RECOVERY))
    bad["cancel_storm"]["hi_jct_ratio_vs_no_storm"] = 2.0
    tol = _setup(tmp_path, json.dumps(bad))
    assert run_gates({"recovery"}, repo=tmp_path, tolerances_path=tol) == 1
    out = capsys.readouterr().out
    assert "FAIL recovery" in out and "disturbance" in out


GOOD_SERVING = {
    "smoke": True,
    "overload": {"priority_inversions": 0},
    "hi_p99_overload_ratio": 4.2,
    "hi_goodput_overload": 0.97,
    "shed_ordering_ok": True,
    "conservation_ok": True,
    "admission_off_trace_identical": True,
}

SERVING_TOL = {"serving_load": {"max_hi_p99_overload_ratio": 15.0,
                                "min_hi_goodput": 0.9,
                                "require_shed_ordering": True,
                                "require_conservation": True,
                                "require_admission_off_trace_identical":
                                    True}}


def _setup_serving(tmp_path, payload):
    tol = tmp_path / "gates.json"
    tol.write_text(json.dumps(SERVING_TOL))
    (tmp_path / "BENCH_serving_load.json").write_text(json.dumps(payload))
    return tol


def test_serving_load_passing_payload(tmp_path, capsys):
    tol = _setup_serving(tmp_path, GOOD_SERVING)
    assert run_gates({"serving_load"}, repo=tmp_path,
                     tolerances_path=tol) == 0
    assert "ok   serving_load" in capsys.readouterr().out


@pytest.mark.parametrize("field,value,needle", [
    ("hi_p99_overload_ratio", 40.0, "p99 bounded"),
    ("hi_goodput_overload", 0.5, "goodput floor"),
    ("shed_ordering_ok", False, "shed ordering"),
    ("conservation_ok", False, "conservation"),
    ("admission_off_trace_identical", False, "bit-identical"),
])
def test_serving_load_regressions_fail_their_gate(tmp_path, capsys,
                                                  field, value, needle):
    bad = json.loads(json.dumps(GOOD_SERVING))
    bad[field] = value
    tol = _setup_serving(tmp_path, bad)
    assert run_gates({"serving_load"}, repo=tmp_path,
                     tolerances_path=tol) == 1
    out = capsys.readouterr().out
    assert "FAIL serving_load" in out and needle in out


def test_serving_load_is_gated_by_default():
    assert "serving_load" in DEFAULT_REQUIRED


def test_main_rejects_unknown_required_name(capsys):
    assert main(["--require", "no_such_bench"]) == 2
    assert "unknown benchmark" in capsys.readouterr().out


def test_recovery_is_gated_by_default():
    assert "recovery" in DEFAULT_REQUIRED
    assert set(DEFAULT_REQUIRED) <= set(ALL_GATED)
