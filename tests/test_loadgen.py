"""Load generator (``repro.serving.loadgen``): arrival synthesis and
open-loop replay.

Extracted from ``test_admission_plane.py`` (where the two original
tests rode along with the plane tests) plus edge cases the original
coverage skipped: zero-rate windows, empty schedules, diurnal thinning
bounds, ``merge_schedules`` stability on ties, and seed determinism —
the contract ``repro.sim.workload`` builds its fleet traces on.
"""
import random

import pytest

from repro.core.scheduler import Mode
from repro.serving import ServingSystem
from repro.serving.loadgen import (Arrival, diurnal_arrivals,
                                   merge_schedules, poisson_arrivals,
                                   replay)
from test_admission_plane import _FakeSvc

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# synthesis (moved from test_admission_plane.py)
# ---------------------------------------------------------------------------
def test_poisson_and_diurnal_arrival_synthesis():
    rng = random.Random(7)
    svc = _FakeSvc()
    p = poisson_arrivals(1000.0, 1.0, svc, "gold", rng)
    assert 800 < len(p) < 1200                 # ~1000 +/- noise
    assert all(0 <= a.t < 1.0 for a in p)
    d = diurnal_arrivals(1000.0, 1.0, svc, "bronze", rng, depth=0.9)
    assert 700 < len(d) < 1300
    # first-half vs second-half asymmetry: sin modulation is visible
    first = sum(1 for a in d if a.t < 0.5)
    assert first > len(d) - first
    with pytest.raises(ValueError, match="depth"):
        diurnal_arrivals(1.0, 1.0, svc, "x", rng, depth=1.5)
    merged = merge_schedules(p, d)
    assert len(merged) == len(p) + len(d)
    assert all(merged[i].t <= merged[i + 1].t
               for i in range(len(merged) - 1))


def test_open_loop_replay_against_real_system():
    rng = random.Random(3)
    svc = _FakeSvc()
    sched = poisson_arrivals(2000.0, 0.05, svc, "silver", rng)
    assert sched, "seeded schedule must not be empty"
    with ServingSystem(Mode.FIKIT, admission=True) as sys_:
        rep = replay(sys_.admission, sched, speed=1.0)
        assert rep.offered == len(sched)
        for t in rep.tickets:
            assert t.result(timeout=10) is not None
        st = sys_.status()["admission"]["classes"]["silver"]
        assert st["offered"] == len(sched)
        assert st["offered"] == (st["admitted"] + st["rejected"]
                                 + st["shed"] + st["requeued"])


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------
def test_zero_rate_windows_yield_empty_schedules():
    rng = random.Random(1)
    assert poisson_arrivals(0.0, 10.0, "svc", "q", rng) == []
    assert diurnal_arrivals(0.0, 10.0, "svc", "q", rng) == []
    # zero-length window likewise
    assert poisson_arrivals(100.0, 0.0, "svc", "q", rng) == []
    assert diurnal_arrivals(100.0, 0.0, "svc", "q", rng) == []


def test_empty_schedule_merge_and_replay():
    assert merge_schedules() == []
    assert merge_schedules([], []) == []
    one = [Arrival(0.5, "svc", "q")]
    assert merge_schedules([], one, []) == one
    with ServingSystem(Mode.FIKIT, admission=True) as sys_:
        rep = replay(sys_.admission, [], speed=10.0)
        assert rep.offered == 0 and rep.tickets == []


def test_diurnal_thinning_bounds():
    """Thinning can only REMOVE arrivals from the peak-rate stream: every
    arrival stays inside the window, the count is bounded by a generous
    peak-rate envelope, and invalid depths are rejected either side."""
    rng = random.Random(11)
    base, duration, depth = 500.0, 2.0, 0.75
    d = diurnal_arrivals(base, duration, "svc", "q", rng, depth=depth)
    assert all(0.0 <= a.t < duration for a in d)
    assert [a.t for a in d] == sorted(a.t for a in d)
    peak_expected = base * (1.0 + depth) * duration
    assert len(d) < peak_expected * 1.5
    # average intensity is base, so the thinned count sits near base *
    # duration, well under the un-thinned peak stream
    assert len(d) < base * (1.0 + depth) * duration * 0.9
    for bad in (-0.1, 1.0, 2.0):
        with pytest.raises(ValueError, match="depth"):
            diurnal_arrivals(base, duration, "svc", "q", rng, depth=bad)
    # depth=0 degenerates to homogeneous Poisson at base rate
    flat = diurnal_arrivals(base, duration, "svc", "q",
                            random.Random(2), depth=0.0)
    assert 0.7 * base * duration < len(flat) < 1.3 * base * duration


def test_merge_schedules_is_stable_on_ties():
    """Equal-time arrivals keep schedule order, then within-schedule
    order (list.sort stability over concatenation) — replay tapes with
    simultaneous arrivals stay deterministic."""
    a = [Arrival(0.0, "a0", "qa"), Arrival(1.0, "a1", "qa"),
         Arrival(1.0, "a2", "qa")]
    b = [Arrival(0.0, "b0", "qb"), Arrival(1.0, "b1", "qb")]
    merged = merge_schedules(a, b)
    assert [x.service for x in merged] == ["a0", "b0", "a1", "a2", "b1"]
    # merging is input-order sensitive only for ties
    swapped = merge_schedules(b, a)
    assert [x.service for x in swapped] == ["b0", "a0", "b1", "a1", "a2"]


def test_schedules_are_seed_deterministic():
    p1 = poisson_arrivals(300.0, 1.0, "svc", "q", random.Random(42))
    p2 = poisson_arrivals(300.0, 1.0, "svc", "q", random.Random(42))
    assert p1 == p2
    d1 = diurnal_arrivals(300.0, 1.0, "svc", "q", random.Random(42),
                          depth=0.5)
    d2 = diurnal_arrivals(300.0, 1.0, "svc", "q", random.Random(42),
                          depth=0.5)
    assert d1 == d2
    assert [a.t for a in p1] != [a.t for a in d1]  # distinct draws
