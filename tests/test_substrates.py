"""Substrate tests: data pipeline, optimizer, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticTextPipeline
from repro.optim.adamw import adamw_init, adamw_update, global_norm, schedule

pytestmark = pytest.mark.fast


def test_pipeline_deterministic_and_shaped():
    p1 = SyntheticTextPipeline(1000, batch=4, seq=32, seed=7)
    p2 = SyntheticTextPipeline(1000, batch=4, seq=32, seed=7)
    b1, b2 = next(p1), next(p2)
    assert b1.tokens.shape == (4, 32)
    assert np.array_equal(b1.tokens, b2.tokens)
    assert np.array_equal(b1.labels[:, :-1], b1.tokens[:, 1:])
    assert b1.tokens.min() >= 0 and b1.tokens.max() < 1000
    b3 = next(p1)
    assert not np.array_equal(b1.tokens, b3.tokens)


def test_pipeline_prefetch_thread():
    p = SyntheticTextPipeline(500, batch=2, seq=16, seed=1).start()
    seen = [next(p) for _ in range(5)]
    p.stop()
    assert len({b.tokens.tobytes() for b in seen}) == 5


def test_adamw_decreases_quadratic():
    params = {"w": jnp.full((8,), 5.0)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, opt, _ = adamw_update(grads, opt, params, lr=0.2,
                                      weight_decay=0.0, warmup=1)
    assert float(loss(params)) < 1.0


def test_adamw_clipping_and_schedule():
    g = {"w": jnp.full((4,), 100.0)}
    assert float(global_norm(g)) == pytest.approx(200.0)
    s = schedule(jnp.asarray(0, jnp.int32).astype(jnp.float32) * 0 + 50,
                 base_lr=1.0, warmup=100)
    assert float(s) == pytest.approx(0.5)   # mid-warmup


def test_adamw_init_on_shape_structs():
    sds = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    opt = adamw_init(sds)
    assert isinstance(opt.mu["w"], jax.ShapeDtypeStruct)
    assert opt.mu["w"].dtype == jnp.float32


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
              "d": jnp.asarray(7, jnp.int32)},
    }
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, tree, step=42)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    loaded, step = load_checkpoint(path, like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, {"a": jnp.ones((2,))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"a": jnp.ones((3,))})
