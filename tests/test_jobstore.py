"""Unit tests for the durable job store (repro.core.jobstore): schema,
write-ahead completion contiguity, recovery plans, profile snapshots
(EMA counters included), the operator control queue, and cold reopen."""
import os
import sqlite3

import pytest

from repro.core.jobstore import (CANCELLED, DONE, PAUSED, RUNNING,
                                 DuplicateCompletion, JobStore,
                                 StreamOrderViolation, UnknownJob,
                                 coerce_store, spec_from_record,
                                 spec_to_obj)
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig, OnlineMeasurement
from repro.core.profiler import ProfiledData
from repro.core.scheduler import profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast


def k(name, dur, gap=0.0, kclass=None):
    return TraceKernel(KernelID(name), dur, gap, kclass=kclass)


def spec(n=4, process="svc", prio=3, **kw):
    return TaskSpec(TaskKey(process), prio,
                    [k(f"{process}/a", 0.002, 0.001)] * n, **kw)


# ------------------------------------------------------------------ schema
def test_memory_and_file_backends_share_schema(tmp_path):
    for store in (JobStore.memory(), JobStore(str(tmp_path / "j.db"))):
        with store:
            jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=2)
            assert store.job(jid).state == RUNNING
            assert store.watermark(jid) == 0


def test_file_store_persists_across_reopen(tmp_path):
    path = str(tmp_path / "jobs.db")
    with JobStore(path) as store:
        jid = store.record_submit(None, TaskKey("svc", ("x",)), 2,
                                  n_kernels=3, deadline=0.5,
                                  spec=spec_to_obj(spec(3)))
        store.record_completion(jid, 0)
    with JobStore(path) as store:
        rec = store.job(jid)
        assert rec.key == TaskKey("svc", ("x",))
        assert (rec.priority, rec.n_kernels, rec.deadline) == (2, 3, 0.5)
        assert rec.completed == 1 and rec.remaining == 2
        assert rec.spec is not None


def test_unknown_job_raises():
    with JobStore.memory() as store:
        with pytest.raises(UnknownJob):
            store.job(99)
        with pytest.raises(UnknownJob):
            store.record_state(99, DONE)


def test_record_state_rejects_unknown_state():
    with JobStore.memory() as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=1)
        with pytest.raises(ValueError, match="unknown job state"):
            store.record_state(jid, "exploded")


def test_resubmit_upsert_keeps_row_and_completions():
    with JobStore.memory() as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=4,
                                  spec=spec_to_obj(spec(4)))
        store.record_completion(jid, 0)
        store.record_state(jid, PAUSED)
        # recovery re-submission: same id advances state only
        again = store.record_submit(jid, TaskKey("a"), 0, n_kernels=4)
        assert again == jid
        rec = store.job(jid)
        assert rec.state == RUNNING
        assert rec.completed == 1           # completions survived
        assert rec.spec is not None         # original spec survived


# -------------------------------------------------- write-ahead contiguity
def test_completion_watermark_contiguous():
    with JobStore.memory() as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=3)
        assert store.record_completion(jid, 0) == 1
        assert store.record_completion(jid, 1) == 2
        assert store.completions(jid) == [0, 1]
        assert store.watermark(jid) == 2


def test_duplicate_completion_is_structural_error():
    with JobStore.memory() as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=3)
        store.record_completion(jid, 0)
        with pytest.raises(DuplicateCompletion, match="run twice"):
            store.record_completion(jid, 0)


def test_stream_order_violation_detected():
    with JobStore.memory() as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=3)
        with pytest.raises(StreamOrderViolation, match="stream order"):
            store.record_completion(jid, 2)


def test_reset_completions_rewinds_watermark():
    with JobStore.memory() as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=3)
        store.record_completion(jid, 0)
        store.reset_completions(jid)
        assert store.watermark(jid) == 0
        store.record_completion(jid, 0)     # re-run allowed from scratch


# ---------------------------------------------------------- recovery plan
def test_spec_round_trip_and_suffix():
    s = TaskSpec(TaskKey("svc"), 4,
                 [k("svc/a", 0.002, 0.001, kclass="memory"),
                  k("svc/b", 0.003, 0.0),
                  k("svc/c", 0.001, 0.002)],
                 max_inflight=2, deadline=1.5)
    with JobStore.memory() as store:
        jid = store.record_submit(None, s.key, s.priority,
                                  n_kernels=3, spec=spec_to_obj(s),
                                  deadline=s.deadline)
        store.record_completion(jid, 0)
        rebuilt = spec_from_record(store.job(jid))
    assert rebuilt.key == s.key and rebuilt.priority == 4
    assert len(rebuilt.kernels) == 2        # suffix from the watermark on
    assert rebuilt.kernels[0].kid == s.kernels[1].kid
    assert rebuilt.kernels[0].kclass is None
    assert rebuilt.max_inflight == 2 and rebuilt.deadline == 1.5
    assert rebuilt.arrival == 0.0           # resumes immediately


def test_recovery_plan_skips_terminal_paused_and_specless():
    with JobStore.memory() as store:
        live = store.record_submit(None, TaskKey("live"), 0, n_kernels=4,
                                   spec=spec_to_obj(spec(4, "live")))
        store.record_completion(live, 0)
        done = store.record_submit(None, TaskKey("done"), 0, n_kernels=1,
                                   spec=spec_to_obj(spec(1, "done")))
        store.record_completion(done, 0)
        store.record_state(done, DONE)
        gone = store.record_submit(None, TaskKey("gone"), 0, n_kernels=2,
                                   spec=spec_to_obj(spec(2, "gone")))
        store.record_state(gone, CANCELLED)
        slept = store.record_submit(None, TaskKey("zzz"), 0, n_kernels=2,
                                    spec=spec_to_obj(spec(2, "zzz")))
        store.record_state(slept, PAUSED)
        store.record_submit(None, TaskKey("wc"), 0, n_kernels=2)  # no spec

        specs, ids, bases = store.recovery_plan()
        assert ids == [live] and bases == [1]
        assert len(specs[0].kernels) == 3

        _, ids_p, _ = store.recovery_plan(include_paused=True)
        assert ids_p == [live, slept]

        incomplete = {r.job_id for r in store.incomplete_jobs()}
        assert incomplete == {live, 5}      # wall-clock job included here


# ---------------------------------------------------------------- profiles
def test_profile_snapshot_round_trip_with_online_state():
    specs = [spec(4, "svc")]
    pd = profile_tasks(specs, T=3, jitter=0.0, measurement_overhead=0.0)
    online = OnlineMeasurement(pd, OnlineConfig(epoch_observations=2))
    key, kid = TaskKey("svc"), specs[0].kernels[0].kid
    for i in range(4):
        online.observe(0, 1, key, kid, i * 0.01, i * 0.01 + 0.004,
                       last=(i == 3))
    online.commit()
    assert online.commits > 0
    with JobStore.memory() as store:
        assert store.load_profiles() is None
        store.snapshot_profiles(pd)
        loaded = store.load_profiles()
    prof, orig = loaded.get(key), pd.get(key)
    assert prof.predict_duration(kid) == \
        pytest.approx(orig.predict_duration(kid))
    assert prof.online_observations == orig.online_observations > 0
    assert prof.obs_count == orig.obs_count
    assert prof.ema_alpha == orig.ema_alpha


def test_profile_snapshot_overwrites_single_row():
    with JobStore.memory() as store:
        store.snapshot_profiles(ProfiledData())
        store.snapshot_profiles(ProfiledData())
        n = store._db.execute("SELECT COUNT(*) FROM profiles").fetchone()
        assert n[0] == 1


# ---------------------------------------------------------------- controls
def test_control_queue_fifo_and_consume_once():
    with JobStore.memory() as store:
        store.request_control("cancel", 3)
        store.request_control("resume", 3, arg="1")
        store.request_control("drain")
        assert store.pop_controls() == [("cancel", 3, None),
                                        ("resume", 3, "1"),
                                        ("drain", None, None)]
        assert store.pop_controls() == []   # consumed exactly once


def test_control_queue_rejects_unknown_verb():
    with JobStore.memory() as store:
        with pytest.raises(ValueError, match="unknown control verb"):
            store.request_control("explode")


# ------------------------------------------------------------------ coerce
def test_coerce_store(tmp_path):
    assert coerce_store(None) is None
    s = JobStore.memory()
    assert coerce_store(s) is s
    s.close()
    path = tmp_path / "x.db"
    opened = coerce_store(os.fspath(path))
    assert isinstance(opened, JobStore) and path.exists()
    opened.close()
    with pytest.raises(TypeError):
        coerce_store(42)


def test_checkpoint_truncates_wal(tmp_path):
    path = str(tmp_path / "j.db")
    with JobStore(path) as store:
        jid = store.record_submit(None, TaskKey("a"), 0, n_kernels=1)
        store.record_completion(jid, 0)
        store.checkpoint()
        wal = path + "-wal"
        assert (not os.path.exists(wal)) or os.path.getsize(wal) == 0
    # the folded main file is a complete database on its own
    db = sqlite3.connect(path)
    assert db.execute("SELECT COUNT(*) FROM completions").fetchone()[0] == 1
    db.close()
