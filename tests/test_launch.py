"""Launch-layer tests: step builders (reduced scale), sharding specs,
HLO cost extraction with trip-count correction."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import InputShape, get_config
from repro.launch.hlo_cost import (bytes_accessed_corrected,
                                   collective_bytes_corrected,
                                   cost_analysis_dict,
                                   dot_flops_corrected)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import default_grad_accum, make_step
from repro.sharding import specs as sh

pytestmark = pytest.mark.slow


SMALL = {
    "train": InputShape("t", 32, 4, "train"),
    "prefill": InputShape("p", 64, 2, "prefill"),
    "decode": InputShape("d", 64, 2, "decode"),
}


@pytest.mark.parametrize("arch", ["qwen3-4b", "llama4-scout-17b-a16e",
                                  "mamba2-2.7b"])
@pytest.mark.parametrize("kind", list(SMALL))
def test_make_step_compiles_reduced(arch, kind):
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh()
    with mesh:
        jitted, args = make_step(cfg, mesh, SMALL[kind])
        compiled = jitted.lower(*args).compile()
    assert cost_analysis_dict(compiled).get("flops", 0) > 0


def test_train_step_executes_and_updates():
    cfg = get_config("stablelm-1.6b").reduced()
    mesh = make_host_mesh()
    shape = SMALL["train"]
    from repro.models import api
    from repro.optim.adamw import adamw_init
    with mesh:
        jitted, _ = make_step(cfg, mesh, shape)
        params = api.build_params(cfg, jax.random.key(0))
        opt = adamw_init(params)
        tokens = jnp.zeros((shape.global_batch, shape.seq_len), jnp.int32)
        labels = jnp.ones_like(tokens)
        p0 = jax.tree.leaves(params)[0].copy()
        new_params, new_opt, metrics = jitted(params, opt, tokens, labels)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_opt.step) == 1
    assert not jnp.array_equal(p0, jax.tree.leaves(new_params)[0])


def test_param_specs_divisibility_guard():
    cfg = get_config("qwen3-4b")      # kv_heads=8, not divisible by 16
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    from repro.models import api
    params = api.build_params(cfg, key=None)
    specs = sh.param_specs(params, mesh)
    # structure matches exactly
    jax.tree.map(lambda a, b: None, params, specs)
    wk = specs["layers"]["attn"]["wk"]
    # on a 1-sized axis everything divides; the guard is exercised via the
    # 16x16 production mesh in the dry-run (kv=8 -> replicated there)
    assert len(wk) == 4


def test_grad_accum_heuristic_monotone():
    mesh = make_host_mesh()
    big = get_config("deepseek-v2-236b")
    small = get_config("stablelm-1.6b")
    t = InputShape("t", 4096, 256, "train")
    assert default_grad_accum(big, mesh, t) >= \
        default_grad_accum(small, mesh, t)


def test_hlo_cost_trip_count_correction():
    """A scanned matmul must be counted trip-count times."""
    n, m, k, trips = 64, 64, 64, 10
    w = jnp.ones((m, k), jnp.float32)

    @jax.jit
    def f(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    compiled = f.lower(jnp.ones((n, m), jnp.float32)).compile()
    hlo = compiled.as_text()
    flops = dot_flops_corrected(hlo)
    expect = 2 * n * m * k * trips
    assert flops == pytest.approx(expect, rel=0.01), (flops, expect)
    # cost_analysis undercounts by the trip count (the bug we correct)
    raw = cost_analysis_dict(compiled).get("flops", 0)
    assert raw <= expect / 2
    assert bytes_accessed_corrected(hlo) > 0


def test_collective_bytes_corrected_counts_psum():
    # single-device: no collectives expected -> empty dict, no crash
    @jax.jit
    def f(a):
        return a * 2
    hlo = f.lower(jnp.ones((4,))).compile().as_text()
    assert collective_bytes_corrected(hlo) == {}
