"""Lifecycle verbs (cancel / pause / resume / drain) on both drivers,
plus the serving-system usage guards.

Sim side: verbs scripted at exact kernel boundaries via ``FaultPlan``
controls (deterministic), asserting the conservation record in the store
and that verbs never break the remaining workload. Wall-clock side: a
cancelled client's parked Future unblocks with ``JobCancelled``, pause
buffers submits until resume, drain refuses new tasks, and the
engine/system usage guards raise clear errors instead of hanging.
"""
import threading
import time

import pytest

from faultutils import ONLINE, assert_conserved, build_sim, k
from repro.core.executor import JobCancelled, WallClockEngine
from repro.core.faults import FaultPlan
from repro.core.jobstore import (CANCELLED, DONE, PAUSED, JobStore)
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler
from repro.core.task import KernelRequest, TaskKey, TaskSpec
from repro.serving import ServingSystem

pytestmark = pytest.mark.fast


def pair_specs():
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.005)] * 5),
        TaskSpec(TaskKey("lo"), 5, [k("lo/a", 0.0015, 0.0004)] * 7,
                 arrival=0.001),
    ]


# ---------------------------------------------------------------------------
# simulator: scripted verbs at exact kernel boundaries
# ---------------------------------------------------------------------------
def test_sim_cancel_storm_spares_other_tasks():
    """Cancel the low task mid-run: hi completes untouched, lo keeps a
    contiguous completion PREFIX and a terminal ``cancelled`` state."""
    specs = pair_specs()
    with JobStore.memory() as store:
        sim = build_sim(specs, Mode.FIKIT, store=store,
                        fault_plan=FaultPlan(controls={3: [("cancel", 1)]}))
        rep = sim.run()
        assert 1 in sim.cancelled
        assert_conserved(store, specs, cancelled_keys=("lo",))
        lo = store.job(sim.job_ids[1])
        assert lo.state == CANCELLED
        assert lo.completed < 7                  # the purge cut the stream
        assert rep.jct(0) > 0                    # hi ran to completion
        assert sim._done_k[0] == 5


def test_sim_cancel_before_arrival():
    """Cancelling a task that never arrived: it never runs, its job row
    is terminal-cancelled from the first write."""
    specs = pair_specs()
    with JobStore.memory() as store:
        sim = build_sim(specs, Mode.FIKIT, store=store)
        assert sim.cancel(1) == []               # nothing queued yet
        sim.run()
        assert_conserved(store, specs, cancelled_keys=("lo",))
        assert store.job(sim.job_ids[1]).completed == 0
        assert store.recovery_plan() == ([], [], [])


def test_sim_cancel_idempotent():
    specs = pair_specs()
    sim = build_sim(specs, Mode.FIKIT,
                    fault_plan=FaultPlan(controls={2: [("cancel", 1)],
                                                  4: [("cancel", 1)]}))
    sim.run()                                    # second cancel is a no-op
    assert sim.cancel(1) == []


def test_sim_pause_resume_roundtrip():
    """Pause at one boundary, resume at a later one: everything still
    completes, and the store saw the paused interlude."""
    specs = pair_specs()
    states = []
    with JobStore.memory() as store:
        sim = build_sim(specs, Mode.FIKIT, store=store,
                        fault_plan=FaultPlan(controls={
                            2: [("pause", 1)],
                            6: [("resume", 1)]}))
        orig_record = store.record_state

        def spy(job_id, state, at=None):
            states.append(state)
            orig_record(job_id, state, at=at)
        store.record_state = spy
        sim.run()
        assert_conserved(store, specs)
    assert PAUSED in states and states.index(PAUSED) < states.index(DONE)


def test_sim_pause_holder_releases_device():
    """Pausing the gap HOLDER must hand the device to someone else —
    the lo task keeps completing while hi is paused."""
    specs = pair_specs()
    with JobStore.memory() as store:
        sim = build_sim(specs, Mode.FIKIT, store=store,
                        fault_plan=FaultPlan(controls={
                            1: [("pause", 0)],
                            6: [("resume", 0)]}))
        sim.run()
        assert_conserved(store, specs)           # nobody deadlocked


def test_sim_unresumed_pause_survives_restart():
    """A pause with no resume: the run ends with the job PAUSED in the
    store; recovery skips it by default and resumes it on request."""
    specs = pair_specs()
    with JobStore.memory() as store:
        sim = build_sim(specs, Mode.FIKIT, store=store,
                        fault_plan=FaultPlan(controls={2: [("pause", 1)]}))
        sim.run()
        assert store.job(sim.job_ids[1]).state == PAUSED
        assert store.job(sim.job_ids[0]).state == DONE
        specs_d, ids_d, _ = store.recovery_plan()
        assert ids_d == []                       # paused stays paused
        rec = SimScheduler.recover(store, Mode.FIKIT, include_paused=True,
                                   online=ONLINE)
        rec.run()
        assert_conserved(store, specs)


def test_sim_cross_device_resume_migrates():
    """pause + resume(device=) is the migration primitive: the resumed
    task's remaining kernels run on the target device."""
    from faultutils import profiles
    specs = pair_specs()
    sim = SimScheduler(specs, Mode.FIKIT, profiled=profiles(specs),
                       devices=2, discipline="round_robin",
                       fault_plan=FaultPlan(controls={
                           2: [("pause", 1)],
                           5: [("resume", 1, 1)]}))
    rep = sim.run()
    assert sim._done_k == [5, 7]                 # all kernels ran
    lo_devices = {kx.device for kx in rep.timeline if kx.task == 1}
    assert 1 in lo_devices                       # migrated onto device 1


def test_sim_exclusive_pause_raises():
    specs = pair_specs()
    sim = build_sim(specs, Mode.EXCLUSIVE,
                    fault_plan=FaultPlan(controls={1: [("pause", 0)]}))
    with pytest.raises(ValueError, match="EXCLUSIVE"):
        sim.run()


def test_sim_pause_unknown_task_raises():
    specs = pair_specs()
    sim = build_sim(specs, Mode.FIKIT)
    with pytest.raises(ValueError, match="cancelled or not yet arrived"):
        sim.pause(0)                             # before arrival
    with pytest.raises(ValueError, match="not paused"):
        sim.resume(0)


# ---------------------------------------------------------------------------
# wall-clock engine: verbs under real threads
# ---------------------------------------------------------------------------
def _req(key, inst, seq, payload, priority=5):
    return KernelRequest(task_key=key, kernel_id=KernelID(f"{key.process}/k"),
                         priority=priority, task_instance=inst,
                         seq_index=seq, payload=payload)


def test_wallclock_cancel_unblocks_parked_client():
    """A request parked behind a busy holder gets ``JobCancelled`` on its
    Future when the task is cancelled — the client unblocks instead of
    hanging; post-cancel submits fail fast."""
    hold = threading.Event()
    hi_key, lo_key = TaskKey("hi"), TaskKey("lo")
    with WallClockEngine(Mode.FIKIT) as eng:
        eng.task_begin(1, hi_key, 0)
        blocking = eng.submit(_req(hi_key, 1, 0,
                                   lambda: hold.wait(5), priority=0))
        eng.task_begin(2, lo_key, 5)
        parked = eng.submit(_req(lo_key, 2, 0, lambda: None))
        purged = eng.cancel(2)
        assert purged == 1
        with pytest.raises(JobCancelled):
            parked.result(timeout=5)
        late = eng.submit(_req(lo_key, 2, 1, lambda: None))
        with pytest.raises(JobCancelled):        # fail fast, never queued
            late.result(timeout=5)
        eng.task_end(2)                          # tolerated, not spurious
        hold.set()
        blocking.result(timeout=5)
        eng.task_end(1)
        assert not eng.placement._device_of     # nothing left behind


def test_wallclock_pause_buffers_until_resume():
    key = TaskKey("svc")
    with WallClockEngine(Mode.FIKIT) as eng:
        eng.task_begin(1, key, 3)
        assert eng.pause(1) is True              # nothing in flight
        fut = eng.submit(_req(key, 1, 0, lambda: "ran"))
        time.sleep(0.05)
        assert not fut.done()                    # buffered, not launched
        assert eng.resume(1) == 0
        out, _, _ = fut.result(timeout=5)
        assert out == "ran"
        eng.task_end(1)


def test_wallclock_drain_refuses_new_tasks():
    key = TaskKey("svc")
    with WallClockEngine(Mode.FIKIT) as eng:
        eng.task_begin(1, key, 0)
        eng.submit(_req(key, 1, 0, lambda: None)).result(timeout=5)
        eng.task_end(1)
        assert eng.drain(timeout=5) is True
        with pytest.raises(RuntimeError, match="draining"):
            eng.task_begin(2, key, 0)


def test_wallclock_engine_usage_guards():
    eng = WallClockEngine(Mode.FIKIT)
    with pytest.raises(RuntimeError, match="before WallClockEngine.start"):
        eng.submit(_req(TaskKey("x"), 1, 0, lambda: None))
    eng.start()
    eng.stop()
    eng.stop()                                   # idempotent
    with pytest.raises(RuntimeError, match="after WallClockEngine.stop"):
        eng.task_begin(1, TaskKey("x"), 0)
    with pytest.raises(RuntimeError, match="cannot restart"):
        eng.start()


def test_wallclock_stop_with_inflight_flushes_online_once():
    """Satellite stress: stop() racing in-flight kernels must not
    deadlock, and must flush the pending online epoch EXACTLY once
    (a second stop() is a no-op). Watchdog-guarded."""
    from repro.core.online import OnlineConfig
    cfg = OnlineConfig(epoch_observations=10**9, epoch_seconds=10**9)
    key = TaskKey("svc")
    eng = WallClockEngine(Mode.FIKIT, online=cfg).start()
    eng.task_begin(1, key, 0)
    first = eng.submit(_req(key, 1, 0, lambda: time.sleep(0.002)))
    for i in range(1, 6):                        # keep the device busy
        eng.submit(_req(key, 1, i, lambda: time.sleep(0.002)))
    first.result(timeout=5)                      # >= 1 observation banked

    done = threading.Event()

    def stopper():
        eng.stop()
        eng.stop()                               # idempotent second stop
        done.set()
    t = threading.Thread(target=stopper, daemon=True)
    t.start()
    t.join(timeout=10)
    assert done.is_set(), "stop() deadlocked with in-flight kernels"
    stats = eng.online.stats()
    assert stats["observations"] >= 1
    assert stats["commits"] == 1                 # flushed exactly once


# ---------------------------------------------------------------------------
# serving system: usage guards (satellite regressions)
# ---------------------------------------------------------------------------
class _FakeSvc:
    """Duck-typed InferenceService: fake payloads, no models, no JAX."""

    class _Seg:
        def __init__(self, name):
            self.name = name
            self.fn = lambda state: state
            self.host_work = None

        def kernel_id(self, state):
            return KernelID(self.name)

    class _Svc:
        def __init__(self, segs):
            self.segments = segs

        def make_input(self):
            return 0

    def __init__(self, name="fake", priority=0, n=3):
        self.key = TaskKey(name)
        self.priority = priority
        self.svc = self._Svc([self._Seg(f"{name}/s{i}") for i in range(n)])

    def client(self, engine, identify=True):
        from repro.core.client import HookClient
        return HookClient(engine, self.key, self.priority,
                          self.svc.segments, identify=identify)


def test_serving_invoke_before_start_raises():
    sys_ = ServingSystem(Mode.FIKIT)
    with pytest.raises(RuntimeError, match="before start"):
        sys_.invoke(_FakeSvc())
    with pytest.raises(RuntimeError, match="outside"):
        sys_.invoke_concurrent([("x", _FakeSvc(), 1, 0.0, 0.0)])


def test_serving_invoke_after_stop_raises_and_stop_is_idempotent():
    sys_ = ServingSystem(Mode.FIKIT)
    sys_.start()
    assert sys_.invoke(_FakeSvc(), n=2) is not None
    sys_.stop()
    sys_.stop()                                  # idempotent, no error
    with pytest.raises(RuntimeError, match="after stop"):
        sys_.invoke(_FakeSvc())
    with pytest.raises(RuntimeError, match="outside"):
        sys_.invoke_concurrent([("x", _FakeSvc(), 1, 0.0, 0.0)])
    # a fresh start serves again after the stopped interlude
    sys_.start()
    try:
        assert len(sys_.invoke(_FakeSvc(), n=1)) == 1
    finally:
        sys_.stop()


def test_serving_ops_plane_end_to_end_with_store():
    """Invoke under a store: job rows reach DONE with full watermarks;
    cancel through the system unblocks and counts the invocation."""
    svc = _FakeSvc(n=4)
    with JobStore.memory() as store:
        with ServingSystem(Mode.FIKIT, jobstore=store) as sys_:
            jcts = sys_.invoke(svc, n=2)
            assert len(jcts) == 2
            jobs = store.jobs(states=(DONE,))
            assert len(jobs) == 2
            for j in jobs:
                assert store.completions(j.job_id) == list(range(4))
            st = sys_.status()
            assert st["by_state"] == {DONE: 2}


# ---------------------------------------------------------------------------
# serving system: concurrency-bug sweep regressions (this PR's satellites)
# ---------------------------------------------------------------------------
class _FaultySvc(_FakeSvc):
    """A fake service whose middle segment always raises."""

    def __init__(self):
        super().__init__(name="faulty", n=3)

        def boom(state):
            raise RuntimeError("injected payload fault")
        self.svc.segments[1].fn = boom


def test_invoke_concurrent_reraises_runner_exception():
    """Regression: a failing plan used to die silently in its runner
    thread — its name simply missing from the result dict, so callers
    crashed later on a bare KeyError. The first plan-order exception
    must propagate out of invoke_concurrent itself."""
    with ServingSystem(Mode.FIKIT) as sys_:
        with pytest.raises(RuntimeError, match="injected payload fault"):
            sys_.invoke_concurrent([
                ("ok", _FakeSvc(), 1, 0.0, 0.0),
                ("bad", _FaultySvc(), 1, 0.0, 0.0),
            ])


def test_poller_counts_rejected_controls_and_stays_alive():
    """Regression: unapplicable operator verbs were swallowed by a bare
    except/pass. They must now be counted (rejected_controls in
    status()) while the poller keeps serving later valid verbs."""
    with JobStore.memory() as store:
        with ServingSystem(Mode.FIKIT, jobstore=store) as sys_:
            store.request_control("cancel", 99999)      # unknown job
            deadline = time.monotonic() + 5
            while (sys_.rejected_controls == 0
                   and time.monotonic() < deadline):
                time.sleep(0.01)
            st = sys_.status()
            assert st["rejected_controls"] == 1
            assert st["poller_deaths"] == 0
            assert st["poller_alive"]


def test_poller_death_is_counted_and_logged(caplog):
    """Regression: a REAL bug in a verb handler (not an unapplicable
    verb) used to vanish into the bare except. It must now log, count
    into poller_deaths, and surface via status()."""
    with JobStore.memory() as store:
        with ServingSystem(Mode.FIKIT, jobstore=store) as sys_:
            def broken_cancel(job_id):
                raise OSError("store exploded mid-cancel")
            sys_.cancel = broken_cancel
            with caplog.at_level("ERROR", logger="repro.serving.engine"):
                store.request_control("cancel", 1)
                deadline = time.monotonic() + 5
                while (sys_.poller_deaths == 0
                       and time.monotonic() < deadline):
                    time.sleep(0.01)
                st = sys_.status()
            assert st["poller_deaths"] == 1
            assert not st["poller_alive"]
            assert any("poller died" in r.message for r in caplog.records)


def test_wedged_poller_cannot_race_final_checkpoint(caplog):
    """Regression: stop() joined the poller with a timeout and then
    checkpointed the store ANYWAY — a wedged verb handler could still be
    writing snapshot_profiles against a store mid-checkpoint. A timed-out
    join must now skip the final snapshot with a warning."""
    release = threading.Event()
    entered = threading.Event()
    with JobStore.memory() as store:
        sys_ = ServingSystem(Mode.FIKIT, jobstore=store)
        sys_.start()
        try:
            def slow_cancel(job_id):
                entered.set()
                release.wait(10)          # deliberately-wedged handler
                raise ValueError("late")
            sys_.cancel = slow_cancel
            sys_._poll_join_timeout = 0.05
            snaps = []
            real_snap = store.snapshot_profiles
            store.snapshot_profiles = \
                lambda p: (snaps.append(1), real_snap(p))[1]
            store.request_control("cancel", 1)
            assert entered.wait(5), "poller never consumed the verb"
            with caplog.at_level("WARNING", logger="repro.serving.engine"):
                sys_.stop()               # join times out: poller wedged
            assert snaps == [], "final snapshot raced a wedged poller"
            assert any("skipping the final profile snapshot" in r.message
                       for r in caplog.records)
        finally:
            release.set()
