"""End-to-end serving tests: real reduced models through the full
measurement -> sharing lifecycle under every mode."""
import statistics as st

import pytest

from repro.config import get_config
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig
from repro.core.scheduler import Mode
from repro.core.task import KernelRequest, TaskKey
from repro.serving import InferenceService, ServingSystem

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def services():
    hi = InferenceService(get_config("qwen3-4b").reduced(), priority=0,
                          batch=1, seq=24, host_gap=0.002)
    lo = InferenceService(get_config("mamba2-2.7b").reduced(), priority=5,
                          batch=2, seq=24)
    return hi, lo


@pytest.mark.parametrize("mode", [Mode.SHARING, Mode.FIKIT])
def test_lifecycle_measure_then_share(services, mode):
    hi, lo = services
    with ServingSystem(mode, measure_runs=3) as sys_:
        jm_hi = sys_.onboard(hi)
        sys_.onboard(lo)
        assert len(jm_hi) == 3 and all(j > 0 for j in jm_hi)
        assert hi.key in sys_.profiles
        prof = sys_.profiles.get(hi.key)
        # segments: embed + 2 layers (same kernel id) + head = 3 unique ids
        assert len(prof.unique_ids) == 3
        assert prof.runs == 3
        res = sys_.invoke_concurrent([
            ("hi", hi, 3, 0.0, 0.005),
            ("lo", lo, 3, 0.0, 0.0),
        ])
        assert len(res["hi"]) == 3 and len(res["lo"]) == 3
        assert all(j > 0 for j in res["hi"] + res["lo"])


def test_online_measure_serves_cold_service(services):
    """With online_measure on, the LOW service is never onboarded: it
    starts cold (no profile) yet serves fine, its SK/SG profile is
    learned from live observations, and the stats expose the loop."""
    hi, lo = services
    with ServingSystem(Mode.FIKIT, measure_runs=3,
                       online_measure=True) as sys_:
        sys_.onboard(hi)
        lo.svc.warmup()                      # compile, but NO onboarding
        assert lo.key not in sys_.profiles
        res = sys_.invoke_concurrent([
            ("hi", hi, 3, 0.0, 0.005),
            ("lo", lo, 3, 0.0, 0.0),
        ])
        assert len(res["hi"]) == 3 and len(res["lo"]) == 3
        live = sys_.online_stats
        assert live is not None and live["observations"] > 0
    final = sys_.online_stats                # post-stop flush snapshot
    assert final["observations"] >= live["observations"]
    assert final["commits"] >= 1
    # the cold service's profile was learned online
    prof = sys_.profiles.get(lo.key)
    assert prof is not None
    assert prof.online_observations > 0
    assert all(v > 0 for v in prof.SK.values())
    assert sys_.profiles.cold_start


@pytest.mark.fast
def test_restart_clears_stale_online_stats():
    """A stopped system caches its final (post-flush) online stats; a
    restart must clear that snapshot so ``online_stats`` reflects the
    NEW engine instead of serving the previous run's leftovers (fake
    payloads, no models needed)."""
    cfg = OnlineConfig(epoch_observations=10**9, epoch_seconds=10**9)
    sys_ = ServingSystem(Mode.FIKIT, online_measure=cfg)
    sys_.start()
    first_engine = sys_.engine
    key = TaskKey("svc")
    first_engine.task_begin(1, key, 0)
    for i in range(3):
        req = KernelRequest(task_key=key, kernel_id=KernelID("svc/k"),
                            priority=0, task_instance=1, seq_index=i,
                            payload=lambda: None)
        first_engine.submit(req).result(timeout=5)
    first_engine.task_end(1)
    sys_.stop()
    assert sys_.online_stats["observations"] == 3    # final snapshot
    # restart: the cached snapshot must not mask the new engine's stats
    sys_.start()
    try:
        assert sys_.engine is not first_engine
        assert sys_.online_stats["observations"] == 0
    finally:
        sys_.stop()
    assert sys_.online_stats["observations"] == 0    # fresh final snapshot


def test_fikit_sharing_produces_fills_or_priority(services):
    """Under FIKIT with a persistent low-priority stream, the engine either
    fills gaps or serializes by priority — and the device never idles
    forever (everything completes)."""
    hi, lo = services
    with ServingSystem(Mode.FIKIT, measure_runs=3) as sys_:
        sys_.onboard(hi)
        sys_.onboard(lo)
        res = sys_.invoke_concurrent([
            ("hi", hi, 4, 0.0, 0.01),
            ("lo", lo, 4, 0.0, 0.0),
        ])
        assert len(res["hi"]) == 4
        assert len(res["lo"]) == 4
        # priority: mean high-priority JCT below mean low-priority JCT
        # is typical but timing-dependent; assert both finite + recorded
        assert st.mean(res["hi"]) > 0
        assert sys_.engine.device_busy_time() > 0
