"""Hypothesis property tests on the FIKIT system's invariants."""
import heapq
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.fikit import best_prio_fit, fikit_procedure
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig
from repro.core.placement import DISCIPLINES
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import KernelRequest, TaskKey, TaskSpec, TraceKernel
from repro.serving.admission import AdmissionPlane, QoSClass


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------
durations = st.floats(min_value=1e-4, max_value=0.05, allow_nan=False)
gaps = st.floats(min_value=0.0, max_value=0.05, allow_nan=False)


@st.composite
def queue_entries(draw):
    n = draw(st.integers(1, 20))
    entries = []
    for i in range(n):
        prio = draw(st.integers(0, 9))
        dur = draw(durations)
        entries.append((f"t{i}", prio, dur))
    return entries


def build(entries):
    pd = ProfiledData()
    qs = PriorityQueues()
    for name, prio, dur in entries:
        key = TaskKey(name)
        kid = KernelID(name + "_k")
        prof = TaskProfile(key=key, runs=1)
        prof.SK[kid] = dur
        pd.load(prof)
        qs.push(KernelRequest(task_key=key, kernel_id=kid, priority=prio))
    return pd, qs


# ---------------------------------------------------------------------------
# Algorithm 2 invariants
# ---------------------------------------------------------------------------
@given(queue_entries(), st.floats(min_value=1e-4, max_value=0.2))
@settings(max_examples=200, deadline=None)
def test_best_prio_fit_invariants(entries, idle):
    pd, qs = build(entries)
    n0 = len(qs)
    req, dur = best_prio_fit(qs, idle, pd)
    if req is None:
        # nothing fits: verify no entry fits
        assert all(not (d < idle) for _, _, d in entries) or all(
            d >= idle for _, _, d in entries)
        assert len(qs) == n0
    else:
        fits = [(p, d) for _, p, d in entries if d < idle]
        best_prio = min(p for p, _ in fits)
        # selected kernel is from the highest priority level with any fit
        assert req.priority == best_prio
        # and is the longest fitting one at that level
        best_dur = max(d for p, d in fits if p == best_prio)
        assert math.isclose(dur, best_dur, rel_tol=1e-12)
        assert dur < idle
        assert len(qs) == n0 - 1


@given(queue_entries(), st.floats(min_value=1e-3, max_value=0.5))
@settings(max_examples=100, deadline=None)
def test_fikit_procedure_never_exceeds_gap(entries, idle):
    pd, qs = build(entries)
    launched = []
    fikit_procedure(qs, TaskKey("hi"), KernelID("x"), idle, pd,
                    launch=launched.append)
    total = sum(pd.predict_duration(r.task_key, r.kernel_id)
                for r in launched)
    # with exact predictions, scheduled fill work never exceeds the gap
    assert total <= idle + 1e-12
    # greedy exhaustion: nothing left fits the remaining gap
    rem = idle - total
    nxt, d = best_prio_fit(qs, rem, pd)
    assert nxt is None


# ---------------------------------------------------------------------------
# Queue-discipline invariants (SJF / EDF pops and fills)
# ---------------------------------------------------------------------------
@st.composite
def discipline_queue(draw, discipline):
    """A populated PriorityQueues under ``discipline``, plus the bound
    profile. Multi-kernel streams included so head-only eligibility is
    exercised; deadlines drawn with Nones and ties."""
    pd = ProfiledData()
    qs = PriorityQueues(profiled=pd, discipline_by_level=discipline)
    n = draw(st.integers(1, 15))
    for i in range(n):
        key = TaskKey(f"t{i}")
        kid = KernelID(f"t{i}_k")
        prof = TaskProfile(key=key, runs=1)
        prof.SK[kid] = draw(st.sampled_from([0.001, 0.002, 0.004, 0.008]))
        pd.load(prof)
        prio = draw(st.integers(0, 9))
        dl = draw(st.sampled_from([None, 0.1, 0.2, 0.2, 0.5]))
        for seq in range(draw(st.integers(1, 3))):
            qs.push(KernelRequest(task_key=key, kernel_id=kid,
                                  priority=prio, task_instance=i,
                                  seq_index=seq, deadline=dl))
    return pd, qs


def _level_heads(qs, priority):
    """Stream heads parked at ``priority`` (the pop/fill-eligible set)."""
    seen = set()
    heads = []
    for req in qs[priority]:
        stream = (req.task_key, req.task_instance)
        if stream not in seen:
            seen.add(stream)
            heads.append(req)
    return heads


@given(discipline_queue("sjf"))
@settings(max_examples=150, deadline=None)
def test_sjf_pop_is_minimal_predicted_duration_among_heads(case):
    """Every SJF pop releases a stream head with MINIMAL predicted SK
    duration among the heads of the highest non-empty level."""
    pd, qs = case
    while True:
        top = qs.highest_nonempty()
        if top is None:
            break
        heads = _level_heads(qs, top)
        popped = qs.pop_highest()
        assert popped.priority == top
        min_dur = min(pd.predict_duration(h.task_key, h.kernel_id)
                      for h in heads)
        assert pd.predict_duration(popped.task_key, popped.kernel_id) \
            == min_dur


@given(discipline_queue("edf"))
@settings(max_examples=150, deadline=None)
def test_edf_pop_leaves_no_earlier_deadline_head(case):
    """After every EDF pop, no head remaining at that level has a strictly
    earlier deadline (undated == +inf, so undated pops only once no dated
    head remains)."""
    _, qs = case
    while True:
        top = qs.highest_nonempty()
        if top is None:
            break
        popped = qs.pop_highest()
        popped_dl = popped.deadline if popped.deadline is not None \
            else math.inf
        for head in _level_heads(qs, top):
            hdl = head.deadline if head.deadline is not None else math.inf
            assert hdl >= popped_dl


@given(discipline_queue("sjf"),
       st.floats(min_value=1e-4, max_value=0.02))
@settings(max_examples=150, deadline=None)
def test_sjf_fill_is_shortest_fitting_head(case, idle):
    """An SJF gap fill selects the SHORTEST profiled fitting head from the
    highest level containing one."""
    pd, qs = case
    req, dur = best_prio_fit(qs, idle, pd)
    if req is None:
        return
    assert dur < idle
    # no level above the selected one held a fitting head, and at the
    # selected level nothing fitting is shorter
    for p in range(req.priority):
        assert all(not (-1.0 < pd.predict_duration(h.task_key, h.kernel_id)
                        < idle) for h in _level_heads(qs, p))
    at_level = [pd.predict_duration(h.task_key, h.kernel_id)
                for h in _level_heads(qs, req.priority)]
    fitting = [d for d in at_level if -1.0 < d < idle]
    assert all(dur <= d for d in fitting)


@given(discipline_queue("edf"),
       st.floats(min_value=1e-4, max_value=0.02))
@settings(max_examples=150, deadline=None)
def test_edf_fill_longest_fit_earliest_deadline_tie(case, idle):
    """An EDF gap fill keeps the paper's longest-fit criterion; among
    remaining equal-duration heads at that level none has a strictly
    earlier deadline than the selected one."""
    pd, qs = case
    req, dur = best_prio_fit(qs, idle, pd)
    if req is None:
        return
    sel_dl = req.deadline if req.deadline is not None else math.inf
    at_level = [(pd.predict_duration(h.task_key, h.kernel_id),
                 h.deadline if h.deadline is not None else math.inf)
                for h in _level_heads(qs, req.priority)]
    fitting = [(d, dl) for d, dl in at_level if -1.0 < d < idle]
    assert all(d <= dur for d, _ in fitting)          # longest fit
    assert all(dl >= sel_dl for d, dl in fitting if d == dur)


# ---------------------------------------------------------------------------
# Simulator invariants
# ---------------------------------------------------------------------------
@st.composite
def task_specs(draw):
    n_tasks = draw(st.integers(1, 4))
    specs = []
    for t in range(n_tasks):
        nk = draw(st.integers(1, 12))
        prio = draw(st.integers(0, 9))
        kid = KernelID(f"svc{t}_k")
        kernels = [TraceKernel(kid, draw(durations), draw(gaps))
                   for _ in range(nk)]
        arrival = draw(st.floats(min_value=0, max_value=0.05))
        inflight = draw(st.sampled_from([1, 1, 1, 8]))
        specs.append(TaskSpec(TaskKey(f"svc{t}"), prio, kernels,
                              arrival=arrival, max_inflight=inflight))
    return specs


def _check_conservation(specs, rep):
    # every kernel executed exactly once
    for ti, spec in enumerate(specs):
        execs = [k for k in rep.timeline if k.task == ti]
        assert len(execs) == len(spec.kernels)
        assert sorted(k.seq for k in execs) == list(range(len(spec.kernels)))
    # device serial: intervals never overlap
    tl = sorted(rep.timeline, key=lambda k: k.start)
    for a, b in zip(tl, tl[1:]):
        assert b.start >= a.end - 1e-12
    # all tasks completed
    for r in rep.results:
        assert r.completion >= r.arrival


@given(task_specs(), st.sampled_from(list(Mode)))
@settings(max_examples=80, deadline=None)
def test_sim_conservation_all_modes(specs, mode):
    pd = profile_tasks(specs, T=3, measurement_overhead=0.0)
    rep = SimScheduler(specs, mode, pd).run()
    _check_conservation(specs, rep)


@given(task_specs())
@settings(max_examples=50, deadline=None)
def test_sim_deterministic(specs):
    pd = profile_tasks(specs, T=2, measurement_overhead=0.0)
    r1 = SimScheduler(specs, Mode.FIKIT, pd, jitter=0.03, seed=7).run()
    r2 = SimScheduler(specs, Mode.FIKIT, pd, jitter=0.03, seed=7).run()
    assert [k.__dict__ for k in r1.timeline] == \
        [k.__dict__ for k in r2.timeline]


@given(task_specs())
@settings(max_examples=50, deadline=None)
def test_exclusive_jct_equals_solo_for_first(specs):
    """In EXCLUSIVE mode the earliest-arriving task runs unobstructed: a
    synchronous client hits exactly its solo JCT; an async client can only
    beat it (host gaps overlap device execution)."""
    pd = ProfiledData()
    rep = SimScheduler(specs, Mode.EXCLUSIVE, pd).run()
    first = min(range(len(specs)), key=lambda i: (specs[i].arrival, i))
    if specs[first].max_inflight == 1:
        assert math.isclose(rep.jct(first), specs[first].solo_jct,
                            rel_tol=1e-9, abs_tol=1e-12)
    else:
        assert rep.jct(first) <= specs[first].solo_jct + 1e-12


# ---------------------------------------------------------------------------
# Multi-device placement invariants
# ---------------------------------------------------------------------------
@st.composite
def placement_cases(draw):
    """Arbitrary task/priority mixes x device counts x disciplines."""
    specs = draw(task_specs())
    devices = draw(st.integers(1, 4))
    discipline = draw(st.sampled_from(sorted(DISCIPLINES)))
    steal = draw(st.booleans())
    mode = draw(st.sampled_from([Mode.FIKIT, Mode.PREEMPT, Mode.SHARING]))
    return specs, devices, discipline, steal, mode


@given(placement_cases())
@settings(max_examples=80, deadline=None)
def test_placement_request_accounting(case):
    """At EVERY event of a multi-device run, per task:

        queued + in_flight + completed == submitted

    and the run terminates with nothing stranded on any device (no parked
    request left behind by a steal, no fill slot leaked)."""
    specs, devices, discipline, steal, mode = case
    pd = profile_tasks(specs, T=2, measurement_overhead=0.0)
    sim = SimScheduler(specs, mode, pd, devices=devices,
                       discipline=discipline, steal=steal)
    for i, t in enumerate(sim.tasks):
        sim._push(t.arrival, "arrival", (i,))
    while sim._heap:
        sim.now, _, kind, payload = heapq.heappop(sim._heap)
        getattr(sim, "_on_" + kind)(*payload)
        for ti in range(len(specs)):
            issued = sim._issued[ti]
            done = sim._done_k[ti]
            queued = sim.placement.queued_of(ti)
            inflight = sim.placement.inflight_of(ti)
            assert queued + inflight + done == issued, (
                f"task {ti}: queued={queued} inflight={inflight} "
                f"done={done} != submitted={issued}")
    # terminated: every kernel ran, nothing parked, no fill slot leaked
    for ti, spec in enumerate(specs):
        assert sim._done_k[ti] == len(spec.kernels), \
            f"task {ti} stranded with {sim._done_k[ti]} done"
    assert sim.placement.queued == 0
    for pol in sim.placement.policies:
        assert pol.fills_in_flight == 0
        assert not pol.active


@given(placement_cases())
@settings(max_examples=50, deadline=None)
def test_placement_conservation_and_serial_devices(case):
    """Every kernel executes exactly once on exactly one device; each
    device timeline is serial; per-task intervals never overlap even
    across steals; all tasks complete."""
    specs, devices, discipline, steal, mode = case
    pd = profile_tasks(specs, T=2, measurement_overhead=0.0)
    rep = SimScheduler(specs, mode, pd, devices=devices,
                       discipline=discipline, steal=steal).run()
    for ti, spec in enumerate(specs):
        execs = sorted((k.start, k.end, k.seq) for k in rep.timeline
                       if k.task == ti)
        assert [e[2] for e in execs] == list(range(len(spec.kernels)))
        for (s0, e0, _), (s1, e1, _) in zip(execs, execs[1:]):
            assert s1 >= e0 - 1e-12, f"task {ti} overlapped across devices"
    for d in range(devices):
        spans = sorted((k.start, k.end) for k in rep.timeline
                       if k.device == d)
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-12, f"device {d} not serial"
    for r in rep.results:
        assert r.completion >= r.arrival


@given(task_specs(), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_placement_deterministic(specs, devices):
    """Same seed + same placement config -> identical timelines."""
    pd = profile_tasks(specs, T=2, measurement_overhead=0.0)
    r1 = SimScheduler(specs, Mode.FIKIT, pd, devices=devices,
                      jitter=0.02, seed=11).run()
    r2 = SimScheduler(specs, Mode.FIKIT, pd, devices=devices,
                      jitter=0.02, seed=11).run()
    assert [k.__dict__ for k in r1.timeline] == \
        [k.__dict__ for k in r2.timeline]
    assert r1.steals == r2.steals


# ---------------------------------------------------------------------------
# Online measurement invariants
# ---------------------------------------------------------------------------
@st.composite
def online_cases(draw):
    """Random workloads x online tunings x modes x device counts."""
    specs = draw(task_specs())
    epoch_n = draw(st.sampled_from([1, 4, 16, 64]))
    alpha = draw(st.sampled_from([0.1, 0.25, 0.5, 1.0]))
    cold = draw(st.booleans())
    profiled = draw(st.booleans())      # start warm (offline profile) or cold
    devices = draw(st.integers(1, 3))
    mode = draw(st.sampled_from([Mode.FIKIT, Mode.PREEMPT]))
    return specs, epoch_n, alpha, cold, profiled, devices, mode


@given(online_cases())
@settings(max_examples=60, deadline=None)
def test_online_epoch_commits_preserve_invariants(case):
    """With the online loop ON — any epoch size, any alpha, cold start on
    or off, warm or empty initial profile — every safety invariant still
    holds: conservation (each kernel exactly once, serial devices), fill
    strictly below the holder's priority at fill time, and per-task
    stream order. Epoch commits may CHANGE decisions; they may never
    break these."""
    specs, epoch_n, alpha, cold, profiled, devices, mode = case
    pd = profile_tasks(specs, T=2, measurement_overhead=0.0) if profiled \
        else ProfiledData()
    cfg = OnlineConfig(epoch_observations=epoch_n, ema_alpha=alpha,
                       cold_start=cold)
    sim = SimScheduler(specs, mode, pd, jitter=0.02, seed=13,
                       devices=devices, online=cfg)
    rep = sim.run()
    if devices == 1:
        _check_conservation(specs, rep)
    for ti, spec in enumerate(specs):
        execs = sorted((k.start, k.end, k.seq) for k in rep.timeline
                       if k.task == ti)
        assert [e[2] for e in execs] == list(range(len(spec.kernels)))
    for pol in sim.placement.policies:
        holder = None
        for e in pol.trace:
            if e[0] == "holder":
                holder = e[1]
            elif e[0] == "fill":
                assert holder is not None
                assert specs[e[1]].priority > specs[holder].priority
    assert rep.online_stats is not None
    assert rep.online_stats["observations"] == sum(len(s.kernels)
                                                   for s in specs)
    assert rep.online_stats["pending_observations"] == 0  # final flush


@given(online_cases())
@settings(max_examples=30, deadline=None)
def test_online_runs_deterministic(case):
    """Same seed + same online config -> identical timelines AND identical
    learned profiles (the loop adds no hidden nondeterminism)."""
    specs, epoch_n, alpha, cold, profiled, devices, mode = case
    reps = []
    pds = []
    for _ in range(2):
        pd = profile_tasks(specs, T=2, measurement_overhead=0.0) \
            if profiled else ProfiledData()
        cfg = OnlineConfig(epoch_observations=epoch_n, ema_alpha=alpha,
                           cold_start=cold)
        reps.append(SimScheduler(specs, mode, pd, jitter=0.03, seed=29,
                                 devices=devices, online=cfg).run())
        pds.append(pd)
    assert [k.__dict__ for k in reps[0].timeline] == \
        [k.__dict__ for k in reps[1].timeline]
    assert reps[0].online_stats == reps[1].online_stats
    for spec in specs:
        kid = spec.kernels[0].kid
        assert pds[0].predict_duration_raw(spec.key, kid) == \
            pds[1].predict_duration_raw(spec.key, kid)


@given(task_specs(), st.sampled_from([4, 16]))
@settings(max_examples=40, deadline=None)
def test_online_learns_every_kernel_from_empty(specs, epoch_n):
    """From an EMPTY profile with jitter 0, every executed kernel ends up
    with a committed SK entry, its value bracketed by the true durations
    observed for that KernelID (EMA of batch means can never leave the
    sample range), and observation counters account for every kernel."""
    pd = ProfiledData()
    rep = SimScheduler(specs, Mode.FIKIT, pd, jitter=0.0,
                       online=OnlineConfig(epoch_observations=epoch_n)).run()
    assert rep.online_stats["observations"] == sum(len(s.kernels)
                                                  for s in specs)
    for spec in specs:
        durs_by_kid = {}
        for tk in spec.kernels:
            durs_by_kid.setdefault(tk.kid, []).append(tk.duration)
        for kid, durs in durs_by_kid.items():
            got = pd.predict_duration_raw(spec.key, kid)
            assert min(durs) - 1e-12 <= got <= max(durs) + 1e-12, \
                (spec.key, kid, got, durs)
        prof = pd.get(spec.key)
        assert prof is not None
        assert prof.online_observations == len(spec.kernels)


@given(task_specs())
@settings(max_examples=50, deadline=None)
def test_fikit_prioritizes_highest(specs):
    """With exact profiles and feedback, the unique highest-priority,
    first-arriving task's JCT under FIKIT stays within overhead-2 bounds:
    each own-gap can be overrun by at most pipeline_depth filler kernels
    (non-preemptible, already queued)."""
    pd = profile_tasks(specs, T=3, measurement_overhead=0.0)
    rep = SimScheduler(specs, Mode.FIKIT, pd, pipeline_depth=1).run()
    holder = min(range(len(specs)),
                 key=lambda i: (specs[i].priority, specs[i].arrival, i))
    # every other task's kernels are bounded in duration by their SK; the
    # holder can be delayed per gap by at most ONE filler (depth=1) plus
    # any task that arrived before it (bounded-latency, not starvation)
    others_max = max((k.duration for i, s in enumerate(specs) if i != holder
                      for k in s.kernels), default=0.0)
    n_gaps = len(specs[holder].kernels)
    bound = specs[holder].solo_jct + (n_gaps + 1) * others_max \
        + sum(s.solo_jct for i, s in enumerate(specs)
              if i != holder and s.arrival <= specs[holder].arrival) + 1e-9
    assert rep.jct(holder) <= bound


# ---------------------------------------------------------------------------
# Ops-plane cancellation conservation
# ---------------------------------------------------------------------------
@st.composite
def cancel_cases(draw):
    """Random workload + a random storm of scripted cancels at random
    kernel boundaries (possibly several at one boundary, possibly
    targeting tasks already done or not yet arrived)."""
    specs = draw(task_specs())
    n_boundaries = sum(len(s.kernels) for s in specs)
    n_cancels = draw(st.integers(1, min(3, len(specs))))
    victims = draw(st.lists(st.integers(0, len(specs) - 1),
                            min_size=n_cancels, max_size=n_cancels,
                            unique=True))
    controls = {}
    for v in victims:
        b = draw(st.integers(0, max(0, n_boundaries - 1)))
        controls.setdefault(b, []).append(("cancel", v))
    return specs, controls, set(victims)


@given(cancel_cases(), st.sampled_from([Mode.FIKIT, Mode.PREEMPT]))
@settings(max_examples=60, deadline=None)
def test_cancellation_conservation(case, mode):
    """Under any cancel storm: every executed kernel executed exactly
    once; a cancelled task's executions are a contiguous stream PREFIX;
    non-cancelled tasks complete fully; and the store's durable record
    agrees with the device timeline kernel-for-kernel."""
    from repro.core.faults import FaultPlan
    from repro.core.jobstore import DONE as _DONE
    from repro.core.jobstore import JobStore

    specs, controls, victims = case
    pd = profile_tasks(specs, T=3, measurement_overhead=0.0)
    with JobStore.memory() as store:
        sim = SimScheduler(specs, mode, pd, jobstore=store,
                           fault_plan=FaultPlan(controls=controls))
        rep = sim.run()
        for ti, spec in enumerate(specs):
            execs = sorted(k.seq for k in rep.timeline if k.task == ti)
            assert len(set(execs)) == len(execs)      # never twice
            recorded = store.completions(sim.job_ids[ti])
            state = store.job(sim.job_ids[ti]).state
            if ti in sim.cancelled:
                # contiguous prefix, conservation across the purge:
                # executed + never-launched == submitted
                assert execs == list(range(len(execs)))
                assert len(execs) <= len(spec.kernels)
                assert state == "cancelled"
            else:
                assert execs == list(range(len(spec.kernels)))
                assert state == _DONE
            # the durable record and the timeline agree kernel-for-kernel
            # (completion rows may trail executions by the in-flight
            # kernels a cancel let finish; never the other way)
            assert recorded == execs
        # device-serial invariant survives the storm
        tl = sorted(rep.timeline, key=lambda k: k.start)
        for a, b in zip(tl, tl[1:]):
            assert b.start >= a.end - 1e-12


# ---------------------------------------------------------------------------
# admission plane invariants (the serving front door)
# ---------------------------------------------------------------------------
class _PlaneClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class _PlaneStub:
    """Synchronous engine stand-in: each admitted group completes at
    once with a fixed JCT, advancing the plane's fake clock — dispatch
    is fully deterministic, no threads."""

    def __init__(self, clock, jct=0.5):
        self.clock = clock
        self.jct = jct

    def _invoke_async(self, service, on_done, deadline=None):
        self.clock.t += self.jct
        on_done(self.jct, None)
        return 0


class _PlaneSvc:
    def __init__(self, name):
        self.key = TaskKey(name)
        self.priority = 0


_PLANE_SVCS = ("s0", "s1", "s2")


@st.composite
def admission_scenarios(draw):
    n_classes = draw(st.integers(1, 4))
    classes = tuple(
        QoSClass(f"c{i}", priority=draw(st.integers(0, 9)),
                 queue_limit=draw(st.integers(1, 6)),
                 max_batch=draw(st.integers(1, 4)))
        for i in range(n_classes))
    max_inflight = draw(st.integers(1, 4))
    primed = {s: draw(st.one_of(st.none(), st.floats(0.1, 4.0)))
              for s in _PLANE_SVCS}
    ops = draw(st.lists(st.one_of(
        st.tuples(st.just("submit"), st.integers(0, n_classes - 1),
                  st.sampled_from(_PLANE_SVCS),
                  st.one_of(st.none(),
                            st.floats(0.01, 3.0, allow_nan=False))),
        st.just(("pump",))), min_size=1, max_size=60))
    return classes, max_inflight, primed, ops


@given(admission_scenarios())
@settings(max_examples=150, deadline=None)
def test_admission_conservation_and_shed_ordering(scenario):
    """Under any interleaving of submits (random class/service/deadline)
    and dispatch passes: per-class conservation holds (offered ==
    admitted + rejected + shed + requeued; admitted == completed +
    failed + cancelled), every ticket resolves exactly once, and the
    shed-ordering invariant is structural — no request is shed or
    admitted while any strictly-higher class has queued work, and the
    plane's priority_inversions counter stays 0."""
    classes, max_inflight, primed, ops = scenario
    clock = _PlaneClock()
    plane = AdmissionPlane(_PlaneStub(clock), classes,
                           max_inflight=max_inflight, clock=clock,
                           dispatcher=False, record_events=True)
    svcs = {n: _PlaneSvc(n) for n in _PLANE_SVCS}
    for name, jct in primed.items():
        if jct is not None:
            plane.note_latency(svcs[name], jct)
    tickets = []
    for op in ops:
        if op[0] == "submit":
            _, ci, sname, dl = op
            tickets.append(plane.submit(svcs[sname], classes[ci].name,
                                        deadline=dl))
        else:
            plane.pump()
    plane.stop()                        # leftovers resolve REQUEUED

    stats = plane.stats()
    assert all(t.done for t in tickets)           # resolved exactly once
    for s in stats["classes"].values():
        assert s["offered"] == (s["admitted"] + s["rejected"]
                                + s["shed"] + s["requeued"])
        assert s["admitted"] == (s["completed"] + s["failed"]
                                 + s["cancelled"])
        assert s["queued"] == 0
    assert len(tickets) == sum(s["offered"]
                               for s in stats["classes"].values())
    # shed ordering: strict-priority dispatch means every admit AND
    # every shed happened with zero requests queued in any higher class
    assert stats["priority_inversions"] == 0
    for e in plane.events:
        if e[1] == "admit":
            assert e[4] == 0          # (seq, "admit", cls, n, higher_queued)
        elif e[1] == "shed":
            assert e[4] == 0          # (seq, "shed", cls, why, higher_queued)


# ---------------------------------------------------------------------------
# workload generator (repro.sim.workload)
# ---------------------------------------------------------------------------
from repro.sim.workload import (hyperperiod_ms, periodic_taskset,  # noqa: E402
                                poisson_trace, release_jobs,
                                uunifast_discard)


@given(st.integers(1, 40), st.floats(0.1, 0.95), st.integers(0, 2**31))
@settings(max_examples=100, deadline=None)
def test_uunifast_sums_to_target_each_share_valid(n, frac, seed):
    total = frac * n                    # always feasible (< n)
    utils = uunifast_discard(n, total, seed)
    assert len(utils) == n
    assert math.isclose(sum(utils), total, rel_tol=0, abs_tol=1e-9)
    assert all(0.0 < u <= 1.0 for u in utils)
    # seed-deterministic
    assert uunifast_discard(n, total, seed) == utils


@given(st.integers(2, 25), st.floats(0.2, 0.9), st.integers(0, 2**31),
       st.booleans())
@settings(max_examples=50, deadline=None)
def test_taskset_schedules_sorted_and_seed_deterministic(n, frac, seed,
                                                         sporadic):
    ts = periodic_taskset(n, frac * n, seed=seed)
    assert ts == periodic_taskset(n, frac * n, seed=seed)
    jobs = release_jobs(ts, sporadic=sporadic)
    assert [j.arrival for j in jobs] == sorted(j.arrival for j in jobs)
    jobs2 = release_jobs(ts, sporadic=sporadic)
    assert [(j.key, j.arrival, j.deadline) for j in jobs] \
        == [(j.key, j.arrival, j.deadline) for j in jobs2]
    # every job's kernels are the task's own (shared, not re-synthesized)
    by_key = {t.key: t for t in ts.tasks}
    for j in jobs:
        assert tuple(j.kernels) == by_key[j.key].kernels


@given(st.integers(2, 25), st.floats(0.2, 0.9), st.integers(0, 2**31))
@settings(max_examples=50, deadline=None)
def test_hyperperiod_divisible_by_every_period(n, frac, seed):
    ts = periodic_taskset(n, frac * n, seed=seed)
    h = ts.hyperperiod_ms
    assert h == hyperperiod_ms([t.period_ms for t in ts.tasks]) > 0
    for t in ts.tasks:
        assert h % t.period_ms == 0


@given(st.integers(2, 15), st.floats(0.2, 0.8), st.integers(0, 2**31),
       st.floats(0.1, 2.0))
@settings(max_examples=50, deadline=None)
def test_sporadic_interarrivals_respect_minimum_separation(n, frac, seed,
                                                           slack):
    ts = periodic_taskset(n, frac * n, seed=seed)
    jobs = release_jobs(ts, cycles=2, sporadic=True, sporadic_slack=slack)
    arrivals = {}
    for j in jobs:
        arrivals.setdefault(j.key, []).append(j.arrival)
    for t in ts.tasks:
        arr = arrivals.get(t.key, [])
        for a, b in zip(arr, arr[1:]):
            assert b - a >= t.period_s - 1e-12


@given(st.floats(1.0, 200.0), st.integers(0, 2**31),
       st.floats(1e-3, 0.1))
@settings(max_examples=50, deadline=None)
def test_arrival_trace_sorted_deterministic_deadlines_absolute(rate, seed,
                                                               rel_dl):
    tpl = TaskSpec(TaskKey("svc"), 0,
                   [TraceKernel(KernelID("svc_k"), 1e-3, 1e-4)])
    jobs = poisson_trace(tpl, rate, duration=1.0, seed=seed,
                         deadline=rel_dl)
    assert [j.arrival for j in jobs] == sorted(j.arrival for j in jobs)
    assert jobs == poisson_trace(tpl, rate, duration=1.0, seed=seed,
                                 deadline=rel_dl)
    for j in jobs:
        assert 0.0 <= j.arrival < 1.0
        assert math.isclose(j.deadline, j.arrival + rel_dl)
        assert j.kernels is tpl.kernels
