"""Differential + invariant tests for the multi-device placement layer.

Two pinned guarantees:

1. K=1 equivalence — a ``PlacementLayer`` with one device is decision-
   trace-identical to a bare ``FikitPolicy`` on every scenario the policy
   differential suite uses, in both FIKIT and PREEMPT modes. The placement
   layer may add NOTHING at K=1: same trace tuples, same launch order,
   same fill count. (Because both engines now drive the policy through the
   placement layer, the 200 randomized cases in
   ``test_policy_differential.py`` pin this too; here the bare policy and
   the K=1 layer are compared head-to-head.)

2. K>1 global invariants — 100+ randomized multi-device cases (random
   tasks x priorities x device counts x disciplines) must satisfy, at
   every point of the run:

   - no request lost or duplicated: every kernel of every task executes
     exactly ONCE, across all devices;
   - per-task stream order is preserved across steals: a task's kernels
     start in seq order and never overlap, no matter how many times the
     task migrates;
   - at most one holder per device, and a task is active on exactly one
     device at a time (an instance never appears in two policies' active
     sets);
   - fill-below-holder per device: a filler launched on a device comes
     from a strictly lower priority level than that device's holder;
   - per-device serial execution: one device never runs two kernels at
     once.
"""
import heapq
import itertools
import random

import pytest

from repro.core.placement import DISCIPLINES, PlacementLayer
from repro.core.policy import Mode
from repro.core.scheduler import SimScheduler
from repro.core.task import KernelRequest

from tests.test_policy_differential import (
    SCENARIOS, VirtualHarness, _profiles, k, random_tasks)
from repro.core.task import TaskKey, TaskSpec

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# Independent virtual-clock driver over a PlacementLayer (K serial devices)
# ---------------------------------------------------------------------------
class PlacementHarness:
    """Event-driven client + K-device model over a ``PlacementLayer``.

    Mirrors ``VirtualHarness`` (same independent client model) but drives
    the placement layer, with one serial virtual timeline per device.
    After EVERY event it checks the cross-device structural invariants, so
    a violation is caught at the decision that caused it, not at the end.
    """

    def __init__(self, tasks, mode, profiled, devices=1,
                 discipline="least_loaded", steal=True, pipeline_depth=2):
        self.tasks = tasks
        self.devices = devices
        self.now = 0.0
        self.device_free = [0.0] * devices
        self._heap = []
        self._tick = itertools.count()
        self.launch_order = []               # (task, seq, filler, device)
        self.exec_log = []                   # (task, seq, start, end, device)
        self._issued = [0] * len(tasks)
        self._done = [0] * len(tasks)
        self._parked_issue = [None] * len(tasks)
        self.placement = PlacementLayer(devices, mode, profiled,
                                        discipline=discipline, steal=steal,
                                        pipeline_depth=pipeline_depth,
                                        clock=lambda: self.now,
                                        launch=self._to_device,
                                        threadsafe=False)

    def _at(self, t, fn):
        heapq.heappush(self._heap, (t, next(self._tick), fn))

    def run(self):
        for ti, spec in enumerate(self.tasks):
            self._at(spec.arrival, lambda ti=ti: self._arrive(ti))
        while self._heap:
            self.now, _, fn = heapq.heappop(self._heap)
            fn()
            self._check_structural_invariants()
        return self

    # ---- structural invariants, checked after every event
    def _check_structural_invariants(self):
        seen = {}
        for d, pol in enumerate(self.placement.policies):
            # the holder is one of the device's active tasks (or None)
            h = pol.holder()
            assert h is None or h in pol.active, \
                f"device {d}: holder {h} not active there"
            for inst in pol.active:
                assert inst not in seen, \
                    f"instance {inst} active on devices {seen[inst]} and {d}"
                seen[inst] = d
        # placement's routing map agrees with the policies' active sets
        for inst, d in seen.items():
            assert self.placement.device_of(inst) == d

    # ---- client model (identical to VirtualHarness's)
    def _arrive(self, ti):
        spec = self.tasks[ti]
        if self.placement.task_begin(ti, spec.key, spec.priority,
                                     arrival=spec.arrival):
            self._try_issue(ti, 0)

    def _try_issue(self, ti, ki):
        spec = self.tasks[ti]
        if ki >= len(spec.kernels):
            return
        if self._issued[ti] - self._done[ti] >= spec.max_inflight:
            self._parked_issue[ti] = ki
            return
        self._issue(ti, ki)

    def _issue(self, ti, ki):
        spec = self.tasks[ti]
        self._issued[ti] += 1
        kern = spec.kernels[ki]
        if spec.max_inflight > 1 and ki + 1 < len(spec.kernels):
            self._at(self.now + kern.gap_after,
                     lambda: self._try_issue(ti, ki + 1))
        self.placement.submit(KernelRequest(
            task_key=spec.key, kernel_id=kern.kid, priority=spec.priority,
            task_instance=ti, seq_index=ki, submit_time=self.now,
            payload=kern.duration))

    # ---- K serial device model
    def _to_device(self, device, req, filler):
        start = max(self.now, self.device_free[device])
        end = start + float(req.payload)
        self.device_free[device] = end
        self.launch_order.append((req.task_instance, req.seq_index, filler,
                                  device))
        self.exec_log.append((req.task_instance, req.seq_index, start, end,
                              device))
        self._at(end, lambda: self._kernel_done(req, filler, device))

    def _kernel_done(self, req, filler, device):
        ti, ki = req.task_instance, req.seq_index
        spec = self.tasks[ti]
        self._done[ti] += 1
        if filler:
            self.placement.fill_complete(device)
        last = ki == len(spec.kernels) - 1
        if last:
            for nxt in self.placement.task_end(ti):
                self._try_issue(nxt, 0)
        elif spec.max_inflight == 1:
            self._at(self.now + spec.kernels[ki].gap_after,
                     lambda: self._try_issue(ti, ki + 1))
        elif self._parked_issue[ti] is not None:
            nxt, self._parked_issue[ti] = self._parked_issue[ti], None
            self._issue(ti, nxt)
        self.placement.kernel_end(ti, spec.kernels[ki].kid, last=last,
                                  actual_gap=spec.kernels[ki].gap_after)


# ---------------------------------------------------------------------------
# (a) K=1: placement layer is trace-identical to a bare FikitPolicy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("discipline", sorted(DISCIPLINES))
def test_k1_placement_identical_to_bare_policy(name, mode, discipline):
    tasks = SCENARIOS[name]()
    pd = _profiles(tasks)
    bare = VirtualHarness(tasks, mode, pd).run()
    placed = PlacementHarness(tasks, mode, pd, devices=1,
                              discipline=discipline).run()
    pol = placed.placement.policies[0]
    assert list(pol.trace) == list(bare.policy.trace)
    assert [(t, s, f) for t, s, f, _ in placed.launch_order] == \
        bare.launch_order
    assert pol.fill_count == bare.policy.fill_count
    assert placed.placement.steal_count == 0
    # and no placement-only trace kinds ever appear at K=1
    assert not any(e[0] in ("attach", "detach") for e in pol.trace)


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_k1_simscheduler_matches_placement_harness(name, mode):
    """SimScheduler (placement-backed) and the independent placement
    harness agree end-to-end at K=1."""
    tasks = SCENARIOS[name]()
    pd = _profiles(tasks)
    sim = SimScheduler(tasks, mode, pd, jitter=0.0, devices=1)
    sim.run()
    placed = PlacementHarness(tasks, mode, pd, devices=1).run()
    assert list(sim.policy.trace) == \
        list(placed.placement.policies[0].trace)


# ---------------------------------------------------------------------------
# (b) randomized multi-device invariants
# ---------------------------------------------------------------------------
def _assert_global_invariants(tasks, h: PlacementHarness):
    # no request lost or duplicated; every kernel runs exactly once
    per_task = {}
    for ti, seq, start, end, device in h.exec_log:
        per_task.setdefault(ti, []).append((start, end, seq, device))
    for ti, spec in enumerate(tasks):
        execs = sorted(per_task.get(ti, []))
        assert [e[2] for e in execs] == list(range(len(spec.kernels))), \
            f"task {ti}: lost/duplicated/reordered kernels"
        # stream order across steals: starts ordered by seq AND disjoint
        for (s0, e0, *_), (s1, e1, *_) in zip(execs, execs[1:]):
            assert s1 >= e0 - 1e-12, f"task {ti}: overlapping kernels"
    # per-device serial execution
    for d in range(h.devices):
        spans = sorted((x[2], x[3]) for x in h.exec_log if x[4] == d)
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-12, f"device {d} overlapped"
    # fill-below-holder per device (trace-level, like the K=1 suite)
    for d, pol in enumerate(h.placement.policies):
        holder = None
        for e in pol.trace:
            if e[0] == "holder":
                holder = e[1]
            elif e[0] == "fill":
                assert holder is not None
                assert tasks[e[1]].priority > tasks[holder].priority, \
                    f"device {d}: filler from at-or-above holder level"
    # drained: nothing parked, nothing in flight, all policies empty
    assert h.placement.queued == 0
    for pol in h.placement.policies:
        assert pol.fills_in_flight == 0
        assert not pol.active


_DISCIPLINE_NAMES = sorted(DISCIPLINES)


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("seed", range(60))
def test_multi_device_invariants_randomized(seed, mode):
    """120 randomized cases: random task mixes over 2-4 devices, rotating
    placement disciplines, steal enabled."""
    rng = random.Random(seed * 60013 + (0 if mode is Mode.FIKIT else 1))
    tasks = random_tasks(rng)
    pd = _profiles(tasks)
    devices = rng.choice([2, 2, 3, 4])
    discipline = _DISCIPLINE_NAMES[seed % len(_DISCIPLINE_NAMES)]
    h = PlacementHarness(tasks, mode, pd, devices=devices,
                         discipline=discipline).run()
    _assert_global_invariants(tasks, h)


@pytest.mark.parametrize("seed", range(20))
def test_multi_device_invariants_no_steal(seed):
    """Steal disabled: the same invariants must hold (stealing is an
    optimization, never a correctness requirement)."""
    rng = random.Random(seed * 104729 + 7)
    tasks = random_tasks(rng)
    pd = _profiles(tasks)
    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                         steal=False).run()
    assert h.placement.steal_count == 0
    _assert_global_invariants(tasks, h)


# ---------------------------------------------------------------------------
# directed steal behavior
# ---------------------------------------------------------------------------
def _steal_scenario():
    """hi holds device 0 with big gaps; lo co-located behind it parks; a
    tiny task occupies device 1 and retires early -> device 1 goes idle
    while device 0 is backlogged -> lo must be stolen."""
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.0001)] * 20),
        TaskSpec(TaskKey("lo"), 5, [k("lo/a", 0.003, 0.0005)] * 8,
                 arrival=0.001),
        TaskSpec(TaskKey("tiny"), 9, [k("tiny/a", 0.001, 0.0001)] * 2,
                 arrival=0.0005),
    ]


def _pin(layer, instance, key, priority, arrival):
    """Custom discipline: hi+lo on device 0, tiny on device 1."""
    return 1 if key.process == "tiny" else 0


def test_steal_rescues_parked_task():
    tasks = _steal_scenario()
    pd = _profiles(tasks)
    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                         discipline=_pin).run()
    assert h.placement.steal_count >= 1
    # the migration left a detach/attach pair across the device traces
    assert any(e == ("detach", 1) for e in h.placement.policies[0].trace)
    assert any(e == ("attach", 1) for e in h.placement.policies[1].trace)
    # lo finished strictly earlier than it would have without stealing
    ns = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                          discipline=_pin, steal=False).run()
    done = {ti: max(e[3] for e in h.exec_log if e[0] == ti)
            for ti in range(len(tasks))}
    done_ns = {ti: max(e[3] for e in ns.exec_log if e[0] == ti)
               for ti in range(len(tasks))}
    assert done[1] < done_ns[1], "steal did not improve the parked task"
    _assert_global_invariants(tasks, h)
    _assert_global_invariants(tasks, ns)


def test_steal_fires_when_task_becomes_fully_parked():
    """Regression: a task whose last in-flight kernel completes while the
    rest of its stream is parked becomes stealable at that *kernel_end*,
    not only at some task_end. Here lo is holder first and launches a few
    kernels, hi takes over (lo's tail parks), and tiny retires on device 1
    while lo still has kernels in flight — so the task_end steal check
    must skip lo. Once lo's in-flight work drains, device 1 has long been
    idle and lo must migrate instead of waiting out hi's entire stream."""
    tasks = [
        TaskSpec(TaskKey("lo"), 5, [k("lo/a", 0.004, 0.0001)] * 6,
                 max_inflight=8),
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.0001)] * 20,
                 arrival=0.0003),
        TaskSpec(TaskKey("tiny"), 9, [k("tiny/a", 0.001, 0.0001)] * 2,
                 arrival=0.0),
    ]
    pd = _profiles(tasks)

    def pin(layer, instance, key, priority, arrival):
        return 1 if key.process == "tiny" else 0

    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                         discipline=pin).run()
    assert h.placement.steal_count >= 1, \
        "lo never stolen after its in-flight kernels drained"
    lo_done = max(e[3] for e in h.exec_log if e[0] == 0)
    hi_done = max(e[3] for e in h.exec_log if e[0] == 1)
    assert lo_done < hi_done, "stolen task should beat the foreign holder"
    _assert_global_invariants(tasks, h)


def test_steal_never_moves_inflight_work():
    """A stolen task's kernels never overlap across devices: the kernel
    intervals of every task are disjoint even in steal-heavy runs."""
    rng = random.Random(20260730)
    for _ in range(10):
        tasks = random_tasks(rng)
        pd = _profiles(tasks)
        h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                             discipline="round_robin").run()
        _assert_global_invariants(tasks, h)


# ---------------------------------------------------------------------------
# disciplines
# ---------------------------------------------------------------------------
def test_round_robin_spreads_tasks():
    tasks = [TaskSpec(TaskKey(f"t{i}"), 5, [k(f"t{i}/a", 0.001)])
             for i in range(4)]
    pd = _profiles(tasks)
    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=4,
                         discipline="round_robin", steal=False).run()
    assert sorted({e[4] for e in h.exec_log}) == [0, 1, 2, 3]


def test_priority_affinity_banding():
    tasks = [
        TaskSpec(TaskKey("p0"), 0, [k("p0/a", 0.001)]),
        TaskSpec(TaskKey("p4"), 4, [k("p4/a", 0.001)]),
        TaskSpec(TaskKey("p5"), 5, [k("p5/a", 0.001)]),
        TaskSpec(TaskKey("p9"), 9, [k("p9/a", 0.001)]),
    ]
    pd = _profiles(tasks)
    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                         discipline="priority_affinity", steal=False).run()
    dev = {e[0]: e[4] for e in h.exec_log}
    assert dev[0] == 0 and dev[1] == 0      # priorities 0-4 -> device 0
    assert dev[2] == 1 and dev[3] == 1      # priorities 5-9 -> device 1


def test_least_loaded_prefers_empty_device():
    tasks = [
        TaskSpec(TaskKey("big"), 5, [k("big/a", 0.01, 0.0001)] * 4),
        TaskSpec(TaskKey("late"), 5, [k("late/a", 0.001)], arrival=0.001),
    ]
    pd = _profiles(tasks)
    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2,
                         steal=False).run()
    dev = {e[0]: e[4] for e in h.exec_log}
    assert dev[0] != dev[1], "late task should land on the empty device"


def test_spurious_kernel_end_after_purge_is_clamped():
    """A duplicate/late kernel_end for an already-purged instance must be
    tolerated and counted, not KeyError (it would kill a wall-clock device
    thread) — the placement analog of FikitPolicy.fill_complete's clamp."""
    tasks = [TaskSpec(TaskKey("t"), 5, [k("t/a", 0.001, 0.0001)] * 2)]
    pd = _profiles(tasks)
    h = PlacementHarness(tasks, Mode.FIKIT, pd, devices=2).run()
    pl = h.placement
    assert pl.device_of(0) is None                 # purged after retirement
    pl.kernel_end(0, tasks[0].kernels[-1].kid, last=True)   # duplicate
    assert pl.spurious_kernel_completions == 1
    pl.kernel_end(99, tasks[0].kernels[0].kid)     # never-seen instance
    assert pl.spurious_kernel_completions == 2
    assert pl.task_end(0) == []                    # duplicate retirement
    assert pl.spurious_task_ends == 1


def test_unknown_discipline_rejected():
    with pytest.raises(ValueError):
        PlacementLayer(2, Mode.FIKIT, discipline="nope",
                       launch=lambda d, r, f: None)
    with pytest.raises(ValueError):
        PlacementLayer(0, Mode.FIKIT, launch=lambda d, r, f: None)


def test_k1_sim_multi_device_report_fields():
    """SimReport carries device metadata; K=1 aggregate utilization is
    unchanged from the pre-placement definition."""
    tasks = _steal_scenario()
    pd = _profiles(tasks)
    rep1 = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0).run()
    assert rep1.devices == 1 and rep1.steals == 0
    assert rep1.per_device_utilization() == [rep1.utilization()]
    rep2 = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0, devices=2).run()
    assert rep2.devices == 2
    assert len(rep2.per_device_utilization()) == 2
    assert rep2.makespan <= rep1.makespan + 1e-12
