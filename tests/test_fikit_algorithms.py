"""Unit tests for Algorithm 1 (FIKIT) and Algorithm 2 (BestPrioFit) —
pseudocode-level semantics from the paper (Figs 9, 10)."""
import pytest

from repro.core.fikit import EPSILON, best_prio_fit, fikit_procedure
from repro.core.kernel_id import KernelID
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.task import KernelRequest, TaskKey

pytestmark = pytest.mark.fast


def make_profiled(entries):
    """entries: {task_name: {kernel_name: (dur, gap)}}"""
    pd = ProfiledData()
    for tname, kernels in entries.items():
        key = TaskKey(tname)
        prof = TaskProfile(key=key, runs=1)
        for kname, (dur, gap) in kernels.items():
            kid = KernelID(kname)
            prof.SK[kid] = dur
            prof.SG[kid] = gap
        pd.load(prof)
    return pd


def req(tname, kname, prio):
    return KernelRequest(task_key=TaskKey(tname), kernel_id=KernelID(kname),
                         priority=prio)


def test_best_prio_fit_prefers_higher_priority():
    pd = make_profiled({"t1": {"k1": (0.005, 0)}, "t2": {"k2": (0.009, 0)}})
    qs = PriorityQueues()
    qs.push(req("t1", "k1", 3))     # higher priority, shorter
    qs.push(req("t2", "k2", 7))     # lower priority, longer (better fit!)
    got, dur = best_prio_fit(qs, idle_time=0.010, profiled=pd)
    # paper: priority dominates — scan stops at the first level with a fit
    assert got.task_key.process == "t1"
    assert dur == pytest.approx(0.005)
    assert len(qs) == 1             # selected request dequeued


def test_best_prio_fit_longest_within_level():
    pd = make_profiled({"a": {"k": (0.002, 0)}, "b": {"k": (0.006, 0)},
                        "c": {"k": (0.004, 0)}})
    qs = PriorityQueues()
    for t in ("a", "b", "c"):
        qs.push(req(t, "k", 5))
    got, dur = best_prio_fit(qs, idle_time=0.007, profiled=pd)
    assert got.task_key.process == "b"          # longest that fits
    assert dur == pytest.approx(0.006)


def test_best_prio_fit_respects_idle_time():
    pd = make_profiled({"a": {"k": (0.010, 0)}})
    qs = PriorityQueues()
    qs.push(req("a", "k", 5))
    got, dur = best_prio_fit(qs, idle_time=0.005, profiled=pd)
    assert got is None and dur == -1
    assert len(qs) == 1                          # nothing dequeued


def test_best_prio_fit_skips_unprofiled():
    pd = ProfiledData()                          # no profiles at all
    qs = PriorityQueues()
    qs.push(req("a", "k", 5))
    got, dur = best_prio_fit(qs, idle_time=1.0, profiled=pd)
    assert got is None                           # predicted -1 never fits


def test_fikit_procedure_fills_until_exhausted():
    pd = make_profiled({"lo": {"k": (0.003, 0)}, "hi": {"kh": (0.002, 0.011)}})
    qs = PriorityQueues()
    for _ in range(5):
        qs.push(req("lo", "k", 5))
    launched = []
    out = fikit_procedure(qs, TaskKey("hi"), KernelID("kh"), idle_time=-1,
                          profiled=pd, launch=launched.append)
    # gap 0.011 fits three 0.003 kernels (0.009), a 4th would exceed 0.002
    assert len(out) == 3 == len(launched)
    assert len(qs) == 2


def test_fikit_procedure_skips_small_gaps():
    pd = make_profiled({"lo": {"k": (0.00001, 0)}})
    qs = PriorityQueues()
    qs.push(req("lo", "k", 5))
    out = fikit_procedure(qs, TaskKey("hi"), KernelID("kh"),
                          idle_time=EPSILON / 2, profiled=pd,
                          launch=lambda r: None)
    assert out == [] and len(qs) == 1


def test_fikit_procedure_feedback_early_stop():
    pd = make_profiled({"lo": {"k": (0.003, 0)}})
    qs = PriorityQueues()
    for _ in range(5):
        qs.push(req("lo", "k", 5))
    remaining = iter([0.004, 0.0])   # after the 1st fill the gap is over

    out = fikit_procedure(qs, TaskKey("hi"), KernelID("kh"), idle_time=0.1,
                          profiled=pd, launch=lambda r: None,
                          remaining_gap=lambda: next(remaining))
    assert len(out) == 1             # early-stopped despite predicted 0.1


def test_priority_queue_scan_order():
    qs = PriorityQueues()
    qs.push(req("a", "k", 9))
    qs.push(req("b", "k", 0))
    qs.push(req("c", "k", 4))
    assert qs.pop_highest().task_key.process == "b"
    assert qs.pop_highest().task_key.process == "c"
    assert qs.pop_highest().task_key.process == "a"
    assert qs.pop_highest() is None


def test_priority_bounds():
    from repro.core.task import Priority
    with pytest.raises(ValueError):
        Priority(10)
    with pytest.raises(ValueError):
        Priority(-1)
    assert int(Priority(0)) == 0
