"""Per-architecture smoke tests: instantiate a REDUCED variant of each
assigned architecture (2 layers, d_model<=512, <=4 experts), run one forward
pass AND one train step on CPU, assert output shapes + no NaNs.

Also checks decode-vs-forward consistency (the serving path is exact w.r.t.
the teacher-forced path, up to fp32 noise; top-1 MoE routing is excluded
from the tight bound because argmax flips are discontinuous).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import get_config
from repro.configs import ARCH_IDS
from repro.models import api

pytestmark = pytest.mark.slow

BATCH, SEQ = 2, 32


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_config(request.param).reduced()
    params = api.build_params(cfg, jax.random.key(0))
    batch = api.make_batch(cfg, BATCH, SEQ)
    return cfg, params, batch


def test_reduced_limits(arch):
    cfg, _, _ = arch
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


def test_forward_shapes_no_nans(arch):
    cfg, params, batch = arch
    logits, aux = api.forward(params, batch, cfg)
    assert logits.shape[0] == BATCH
    assert logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == SEQ
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


def test_train_step_no_nans(arch):
    cfg, params, batch = arch
    labels = api.batch_labels(cfg, batch)

    def loss(p):
        logits, aux = api.forward(p, batch, cfg)
        return api.loss_fn(logits, labels, aux)

    val, grads = jax.value_and_grad(loss)(params)
    assert jnp.isfinite(val)
    flat = jax.tree.leaves(grads)
    assert flat, "no grads"
    for g in flat:
        assert not bool(jnp.isnan(g).any())
    # one SGD step moves the loss
    new_params = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                              params, grads)
    val2 = loss(new_params)
    assert jnp.isfinite(val2)


def test_prefill_decode_consistency(arch):
    cfg, params, batch = arch
    if cfg.family == "moe":
        # Expert-capacity drops depend on the routed token count, so the
        # teacher-forced reference (T = B*S tokens) can drop a late token's
        # expert contribution that single-token decode (T = B) keeps — a
        # discontinuous dispatch artifact, not a decode bug (observed on
        # deepseek-v2: the dropped assignment is exactly the compared last
        # token of batch row 1). Compare with dropless capacity so the
        # equivalence being tested is well-defined.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    logits_full, _ = api.forward(params, batch, cfg)
    if cfg.family in ("encdec", "vlm"):
        head, tokens = batch
        pre = (head, tokens[:, :-1])
    else:
        tokens = batch
        pre = tokens[:, :-1]
    last_tok = tokens[:, -1:]
    lg_p, caches = api.prefill(params, pre, cfg, extra_capacity=4)
    # position of the last token in the (possibly patch-prefixed) stream
    last_idx = logits_full.shape[1] - 1
    pos = last_idx  # decode positions count patches too (vlm)
    lg_d, _ = api.decode_step(params, last_tok, pos, caches, cfg)
    want_p = logits_full[:, last_idx - 1]
    want_d = logits_full[:, last_idx]
    tol = 5e-4
    if cfg.family == "moe" and cfg.top_k == 1:
        tol = 0.5  # top-1 argmax flips are discontinuous in fp32
    assert float(jnp.max(jnp.abs(lg_p[:, 0] - want_p))) < tol
    assert float(jnp.max(jnp.abs(lg_d[:, 0] - want_d))) < tol


def test_decode_steps_advance(arch):
    cfg, params, batch = arch
    caches = api.init_decode_caches(cfg, BATCH, 64)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    prev = None
    for pos in range(3):
        logits, caches = api.decode_step(params, tok, pos, caches, cfg)
        assert logits.shape == (BATCH, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits[:, :, :64], axis=-1).astype(jnp.int32)
        if prev is not None:
            assert not jnp.array_equal(prev, logits) or pos == 0
        prev = logits
