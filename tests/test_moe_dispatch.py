"""MoE capacity-dispatch correctness: the sort+scatter expert computation
must match a brute-force dense-dispatch reference when capacity is ample,
and drop (not corrupt) overflow tokens when it is not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.models.layers import Maker
from repro.models.moe import _capacity, _moe_ffn_block, moe_ffn_build

pytestmark = pytest.mark.fast


def make(cfg, key=0):
    return moe_ffn_build(Maker(jax.random.key(key), cfg.dtype), cfg)


def brute_force(x2, p, cfg):
    """Dense reference: every token through its top-k experts."""
    logits = x2.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    T, D = x2.shape
    y = jnp.zeros((T, D), jnp.float32)
    for t in range(T):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu((x2[t] @ p["w1"][e]).astype(jnp.float32))
            h = h * (x2[t] @ p["w3"][e]).astype(jnp.float32)
            y = y.at[t].add(float(gates[t, j]) * (h @ p["w2"][e].astype(jnp.float32)))
    return y


@pytest.mark.parametrize("seed", [0, 1])
def test_dispatch_matches_brute_force(seed):
    cfg = ModelConfig(name="t", family="moe", d_model=16, num_experts=4,
                      top_k=2, moe_d_ff=32, capacity_factor=8.0,
                      dtype="float32")
    p = make(cfg, seed)
    x2 = jax.random.normal(jax.random.key(seed + 10), (12, 16), jnp.float32)
    y, aux = _moe_ffn_block(x2, p, cfg, 0, cfg.num_experts,
                            p["w1"], p["w3"], p["w2"])
    ref = brute_force(x2, p, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_partial_expert_ranges_sum_to_full():
    """Expert-parallel split: contributions of two half-ranges sum to the
    full-range output (the shard_map psum identity)."""
    cfg = ModelConfig(name="t", family="moe", d_model=16, num_experts=4,
                      top_k=2, moe_d_ff=32, capacity_factor=8.0,
                      dtype="float32")
    p = make(cfg)
    x2 = jax.random.normal(jax.random.key(3), (10, 16), jnp.float32)
    full, _ = _moe_ffn_block(x2, p, cfg, 0, 4, p["w1"], p["w3"], p["w2"])
    lo, _ = _moe_ffn_block(x2, p, cfg, 0, 2, p["w1"][:2], p["w3"][:2],
                           p["w2"][:2])
    hi, _ = _moe_ffn_block(x2, p, cfg, 2, 2, p["w1"][2:], p["w3"][2:],
                           p["w2"][2:])
    np.testing.assert_allclose(np.asarray(lo + hi), np.asarray(full),
                               rtol=2e-4, atol=2e-5)


def test_overflow_drops_not_corrupts():
    """With capacity 8 (the floor) and concentrated routing, overflow
    tokens contribute zero rather than wrong values."""
    cfg = ModelConfig(name="t", family="moe", d_model=8, num_experts=2,
                      top_k=1, moe_d_ff=16, capacity_factor=0.01,
                      dtype="float32")
    p = make(cfg)
    # force all tokens to expert 0: positive inputs x a positive column
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    T = 24
    x2 = jnp.abs(jax.random.normal(jax.random.key(4), (T, 8),
                                   jnp.float32)) + 0.1
    C = _capacity(T, cfg)
    y, _ = _moe_ffn_block(x2, p, cfg, 0, 2, p["w1"], p["w3"], p["w2"])
    # exactly C tokens processed (nonzero rows), the rest dropped to zero
    nonzero = int(jnp.sum(jnp.any(jnp.abs(y) > 1e-9, axis=-1)))
    assert nonzero == min(C, T)
