"""Crash-recovery sweeps and the wired-but-disabled differential.

Three layers of proof for the durable ops plane:

1. **In-process soft-crash sweep** — for EVERY global kernel boundary in
   three scenarios x {FIKIT, PREEMPT}, inject ``InjectedCrash`` against a
   file store, re-open the store COLD, ``SimScheduler.recover``, run to
   completion, and assert conservation: zero requests lost, zero
   duplicated, stream order contiguous per job.
2. **Subprocess kill-and-restart** — sampled boundaries hard-crash a real
   child process via ``os._exit(86)`` (no handlers, no flush — the
   SIGKILL stand-in), then a fresh process recovers from the store file.
3. **Differential contract** — randomized scenarios run store-absent
   vs store-attached + inert ``FaultPlan``: decision traces, timelines,
   and fill counts must be BIT-IDENTICAL (the store only observes).
"""
import json
import random
import subprocess
import sys
from pathlib import Path

import pytest

from faultutils import (ONLINE, SCENARIOS, SWEEP_MODES, assert_conserved,
                        build_sim, crash_then_recover, profiles,
                        total_kernels)
from repro.core.faults import CRASH_EXIT, FaultPlan, InjectedCrash
from repro.core.jobstore import DONE, JobStore
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler
from repro.core.task import TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# 1. every-boundary soft-crash sweep (in-process, cold store reopen)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", SWEEP_MODES)
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_crash_at_every_kernel_boundary_recovers(scenario, mode, tmp_path):
    specs = SCENARIOS[scenario]()
    n = total_kernels(specs)
    for boundary in range(n):
        path = str(tmp_path / f"{scenario}_{mode.value}_{boundary}.db")
        store, rec = crash_then_recover(scenario, mode, boundary, path)
        with store:
            assert_conserved(store, specs)
        # the recovered run resumed the suffix, not the whole stream:
        # kernels it re-executed + kernels committed pre-crash == total
        resumed = sum(len(t.kernels) for t in rec.tasks)
        assert resumed == n - (boundary + 1)


def test_crash_before_any_boundary_recovers_full_run(tmp_path):
    """Crash at boundary 0: exactly one completion is durable (the
    write-ahead record precedes the crash at its own boundary)."""
    specs = SCENARIOS["pair"]()
    store, _ = crash_then_recover("pair", Mode.FIKIT, 0,
                                  str(tmp_path / "b0.db"))
    with store:
        assert_conserved(store, specs)


def test_recovered_run_retains_online_learned_sksg(tmp_path):
    """The profile snapshot rides the online epoch commits, so a crash
    after the first commit recovers with refined SK/SG — not the offline
    profile, not a cold start."""
    path = str(tmp_path / "skg.db")
    specs = SCENARIOS["churn"]()
    with JobStore(path) as store:
        sim = build_sim(specs, Mode.FIKIT, store=store,
                        fault_plan=FaultPlan(crash_at=12))
        with pytest.raises(InjectedCrash):
            sim.run()
        assert sim.online.commits > 0
    with JobStore(path) as store:
        snap = store.load_profiles()
        assert snap is not None
        learned = sum(p.online_observations
                      for p in (snap.get(s.key) for s in specs)
                      if p is not None)
        assert learned > 0
        rec = SimScheduler.recover(store, Mode.FIKIT, online=ONLINE)
        carried = sum(p.online_observations
                      for p in (rec.profiled.get(s.key) for s in specs)
                      if p is not None)
        assert carried == learned      # resumed WITH the learned state
        rec.run()
        assert_conserved(store, specs)


def test_recover_after_clean_run_is_a_noop(tmp_path):
    path = str(tmp_path / "clean.db")
    specs = SCENARIOS["pair"]()
    with JobStore(path) as store:
        build_sim(specs, Mode.FIKIT, store=store).run()
    with JobStore(path) as store:
        assert_conserved(store, specs)
        rec = SimScheduler.recover(store, Mode.FIKIT)
        assert rec.tasks == []         # nothing incomplete
        rec.run()
        assert_conserved(store, specs)


# ---------------------------------------------------------------------------
# 2. subprocess kill-and-restart (hard crash: os._exit, cold process)
# ---------------------------------------------------------------------------
def _child(args, tmp_path):
    return subprocess.run(
        [sys.executable, str(REPO / "tests" / "faultutils.py"), *args],
        cwd=str(REPO), capture_output=True, text=True, timeout=120,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": str(tmp_path)})


@pytest.mark.parametrize("mode", SWEEP_MODES)
@pytest.mark.parametrize("scenario", ["pair", "tiers"])
def test_kill_and_restart_subprocess(scenario, mode, tmp_path):
    specs = SCENARIOS[scenario]()
    n = total_kernels(specs)
    for boundary in (1, n // 2, n - 2):
        db = str(tmp_path / f"kill_{scenario}_{mode.value}_{boundary}.db")
        dead = _child(["run", scenario, mode.value, db,
                       "--crash-at", str(boundary)], tmp_path)
        assert dead.returncode == CRASH_EXIT, dead.stderr
        back = _child(["recover", scenario, mode.value, db], tmp_path)
        assert back.returncode == 0, back.stderr
        summary = json.loads(back.stdout)
        assert len(summary["done"]) == len(specs)
        with JobStore(db) as store:
            assert_conserved(store, specs)


def test_subprocess_clean_run_then_recover_noop(tmp_path):
    db = str(tmp_path / "clean.db")
    first = _child(["run", "pair", "fikit", db], tmp_path)
    assert first.returncode == 0, first.stderr
    again = _child(["recover", "pair", "fikit", db], tmp_path)
    assert again.returncode == 0, again.stderr
    with JobStore(db) as store:
        assert_conserved(store, SCENARIOS["pair"]())


# ---------------------------------------------------------------------------
# 3. wired-but-disabled differential: the store only OBSERVES
# ---------------------------------------------------------------------------
_DUR = [0.0005, 0.001, 0.0015, 0.002, 0.003, 0.004]
_GAP = [0.0, 0.0003, 0.001, 0.0025, 0.005]


def _random_tasks(rng):
    specs = []
    for t in range(rng.randint(2, 5)):
        kid = KernelID(f"svc{t}/k")
        kernels = [TraceKernel(kid, rng.choice(_DUR), rng.choice(_GAP))
                   for _ in range(rng.randint(2, 10))]
        specs.append(TaskSpec(
            TaskKey(f"svc{t}"), rng.randint(0, 9), kernels,
            arrival=rng.choice([0.0, 0.0005, 0.002, 0.008]),
            max_inflight=rng.choice([1, 1, 1, 4])))
    return specs


@pytest.mark.parametrize("mode", SWEEP_MODES)
@pytest.mark.parametrize("seed", range(20))
def test_store_attached_runs_trace_identical(seed, mode):
    """No faults + attached store (+ inert FaultPlan) vs no store at all:
    decision traces, device timelines, and fill counts are bit-identical
    — recording never changes a scheduling decision."""
    rng = random.Random(seed * 6151 + (0 if mode is Mode.FIKIT else 1))
    tasks = _random_tasks(rng)
    online = seed % 2 == 0             # alternate the online loop too
    # fresh ProfiledData per run: the online loop mutates it in place
    kw = lambda: dict(profiled=profiles(tasks),  # noqa: E731
                      online=ONLINE if online else None)

    plain = SimScheduler(tasks, mode, **kw())
    rep_plain = plain.run()

    with JobStore.memory() as store:
        wired = SimScheduler(tasks, mode, jobstore=store,
                             fault_plan=FaultPlan(), **kw())
        rep_wired = wired.run()
        assert wired.fault_plan.inert
        # and the observing store is a complete conservation record
        assert_conserved(store, tasks)
        assert len(store.jobs(states=(DONE,))) == len(tasks)

    assert plain.policy.trace == wired.policy.trace
    assert rep_plain.timeline == rep_wired.timeline
    assert plain.policy.fill_count == wired.policy.fill_count
    assert [r.jct for r in rep_plain.results] == \
        [r.jct for r in rep_wired.results]
