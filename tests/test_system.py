"""End-to-end behaviour tests for the paper's system: the three headline
FIKIT properties on a deterministic two-service scenario (paper Fig 2)."""
import pytest

from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast


@pytest.fixture(scope="module")
def scenario():
    # A: high-priority interactive service with large inter-kernel gaps
    A = TaskSpec(TaskKey("svcA"), priority=0,
                 kernels=[TraceKernel(KernelID("A/k"), 0.002, 0.005)] * 20)
    # B: low-priority device-bound batch service (async client)
    B = TaskSpec(TaskKey("svcB"), priority=5,
                 kernels=[TraceKernel(KernelID("B/k"), 0.003, 0.0002)] * 60,
                 max_inflight=16)
    profiled = profile_tasks([A, B], T=10, jitter=0.03)
    reports = {m: SimScheduler([A, B], m, profiled, jitter=0.03,
                               seed=7).run() for m in Mode}
    return A, B, reports


def test_fikit_protects_high_priority(scenario):
    """Paper metric 1: JCT_A(FIKIT)/JCT_A(solo) ~ 1, far below sharing."""
    A, B, reports = scenario
    fikit = reports[Mode.FIKIT].jct(0)
    share = reports[Mode.SHARING].jct(0)
    assert fikit / A.solo_jct < 1.15          # near-solo under FIKIT
    assert share / A.solo_jct > 1.5           # inflated under sharing
    assert share / fikit > 1.5                # the headline speedup


def test_fikit_advances_low_priority_in_gaps(scenario):
    """Paper metric 3: B progresses during A (gap fills), beating
    exclusive mode."""
    A, B, reports = scenario
    assert reports[Mode.FIKIT].fills > 0
    assert reports[Mode.FIKIT].jct(1) < reports[Mode.EXCLUSIVE].jct(1)


def test_fikit_maximizes_utilization(scenario):
    """FIKIT fills the device's idle time: utilization strictly above both
    baselines for this gap-heavy scenario."""
    _, _, reports = scenario
    u = {m: reports[m].utilization() for m in Mode}
    assert u[Mode.FIKIT] >= u[Mode.SHARING] - 1e-9
    assert u[Mode.FIKIT] > u[Mode.EXCLUSIVE]
