"""Shared fixtures for the fault-injection / crash-recovery suite.

Three tiny multi-task scenarios (12-18 kernels => 12-18 global kernel
boundaries each), conservation assertion helpers, and a subprocess entry
point so the kill-and-restart tests can hard-crash a REAL process
(``os._exit``, the SIGKILL stand-in) and restart it cold:

    PYTHONPATH=src python tests/faultutils.py run <scenario> <mode> \
        <store.db> --crash-at 7
    PYTHONPATH=src python tests/faultutils.py recover <scenario> <mode> \
        <store.db>

``run`` exits with ``CRASH_EXIT`` (86) at the scripted boundary; a
``recover`` invocation rebuilds the simulator from the store and runs the
remaining suffix to completion, printing a JSON summary on stdout.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __name__ == "__main__":           # subprocess entry: no pytest on path
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.faults import FaultPlan, InjectedCrash  # noqa: E402
from repro.core.jobstore import DONE, JobStore  # noqa: E402
from repro.core.kernel_id import KernelID  # noqa: E402
from repro.core.online import OnlineConfig  # noqa: E402
from repro.core.scheduler import Mode, SimScheduler, profile_tasks  # noqa: E402
from repro.core.task import TaskKey, TaskSpec, TraceKernel  # noqa: E402


def k(name, dur, gap=0.0):
    return TraceKernel(KernelID(name), dur, gap)


def scenario_pair():
    """Gap-filling pair: sync high-prio with big gaps + sync low-prio.
    12 kernels -> 12 boundaries."""
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.005)] * 5),
        TaskSpec(TaskKey("lo"), 5, [k("lo/a", 0.0015, 0.0004)] * 7,
                 arrival=0.001),
    ]


def scenario_tiers():
    """Three priority tiers with an async bottom; 15 boundaries."""
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.004)] * 4),
        TaskSpec(TaskKey("mid"), 2, [k("mid/a", 0.001, 0.002)] * 5,
                 arrival=0.002),
        TaskSpec(TaskKey("lo"), 7, [k("lo/a", 0.003, 0.0001)] * 6,
                 arrival=0.0005, max_inflight=3),
    ]


def scenario_churn():
    """Equal-prio pair + late boss + async flood; 18 boundaries."""
    return [
        TaskSpec(TaskKey("a"), 3, [k("a/x", 0.002, 0.001)] * 5),
        TaskSpec(TaskKey("b"), 3, [k("b/x", 0.0015, 0.0008)] * 4,
                 arrival=0.0002),
        TaskSpec(TaskKey("boss"), 1, [k("boss/x", 0.001, 0.003)] * 3,
                 arrival=0.006),
        TaskSpec(TaskKey("bulk"), 9, [k("bulk/x", 0.0025, 0.0001)] * 6,
                 arrival=0.003, max_inflight=4),
    ]


SCENARIOS = {
    "pair": scenario_pair,
    "tiers": scenario_tiers,
    "churn": scenario_churn,
}

#: modes the recovery sweep covers (the two queued sharing modes)
SWEEP_MODES = (Mode.FIKIT, Mode.PREEMPT)

#: small epochs so the online loop commits (and the store snapshots the
#: refined profile) several times inside even these tiny scenarios
ONLINE = OnlineConfig(epoch_observations=4, epoch_seconds=0.005)


def profiles(specs):
    return profile_tasks(specs, T=3, jitter=0.0, measurement_overhead=0.0)


def total_kernels(specs):
    return sum(len(s.kernels) for s in specs)


def build_sim(specs, mode, store=None, fault_plan=None, online=True):
    return SimScheduler(specs, mode, profiled=profiles(specs),
                        jobstore=store, fault_plan=fault_plan,
                        online=ONLINE if online else None)


# ------------------------------------------------------------- assertions
def assert_conserved(store, specs, cancelled_keys=()):
    """The conservation proof: every non-cancelled job is DONE with a
    contiguous 0..n-1 completion stream — zero lost (count == n_kernels),
    zero duplicated (set size == list size), order preserved."""
    by_key = {s.key.process: s for s in specs}
    jobs = store.jobs()
    assert len(jobs) == len(specs), \
        f"store has {len(jobs)} jobs, expected {len(specs)}"
    for rec in jobs:
        spec = by_key[rec.key.process]
        seqs = store.completions(rec.job_id)
        assert len(set(seqs)) == len(seqs), \
            f"job {rec.job_id} duplicated completions: {seqs}"
        if rec.key.process in cancelled_keys:
            assert rec.state == "cancelled"
            # a cancelled job keeps a contiguous PREFIX (whatever ran
            # before the purge), never the full stream
            assert seqs == list(range(len(seqs)))
        else:
            assert rec.state == DONE, \
                f"job {rec.job_id} ({rec.key.process}) state {rec.state}"
            assert seqs == list(range(len(spec.kernels))), \
                f"job {rec.job_id} completions not contiguous: {seqs}"


def crash_then_recover(scenario, mode, boundary, store_path):
    """In-process soft-crash at ``boundary`` against a file store, then a
    COLD reopen + ``SimScheduler.recover`` run to completion. Returns the
    reopened store (caller closes) and the recovered scheduler."""
    specs = SCENARIOS[scenario]()
    with JobStore(store_path) as store:
        sim = build_sim(specs, mode, store=store,
                        fault_plan=FaultPlan(crash_at=boundary))
        try:
            sim.run()
        except InjectedCrash as e:
            assert e.boundary == boundary
        else:
            raise AssertionError(
                f"no crash fired at boundary {boundary} "
                f"({total_kernels(specs)} kernels total)")
    store = JobStore(store_path)     # cold reopen: only durable state
    rec = SimScheduler.recover(store, mode, online=ONLINE)
    rec.run()
    return store, rec


# -------------------------------------------------------- worker fleets
def seed_worker_store(store_path, scenario, qos=None):
    """Preload a store file with a scenario's specs as CLAIMABLE rows
    (state=submitted + shard keys) and a profile snapshot — what a
    worker fleet drains. ``qos`` is a shard key string or a callable
    ``spec -> key``. Returns (specs, job_ids)."""
    from repro.serving.workers import enqueue_specs
    specs = SCENARIOS[scenario]()
    with JobStore(os.fspath(store_path)) as store:
        ids = enqueue_specs(store, specs, qos=qos)
        store.snapshot_profiles(profiles(specs))
        store.checkpoint()
    return specs, ids


def spawn_worker(store_path, worker_id, *, lease=0.5, heartbeat=0.1,
                 batch=100, crash_at=None, shards=None, extra=()):
    """Launch one REAL worker subprocess (``python -m
    repro.serving.workers``) against a store file. ``crash_at`` scripts
    a hard os._exit(86) at that global kernel boundary of its first
    batch — the mid-lease death the reclamation tests need. Caller
    communicates()/waits."""
    import subprocess
    cmd = [sys.executable, "-m", "repro.serving.workers",
           "--jobstore", os.fspath(store_path), "--worker-id", worker_id,
           "--lease", str(lease), "--heartbeat", str(heartbeat),
           "--batch", str(batch)]
    if crash_at is not None:
        cmd += ["--crash-at", str(crash_at)]
    if shards:
        cmd += ["--shards", ",".join(shards)]
    cmd += list(extra)
    env = dict(os.environ)
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)


# ------------------------------------------------------- subprocess entry
def child_main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("action", choices=("run", "recover"))
    ap.add_argument("scenario", choices=sorted(SCENARIOS))
    ap.add_argument("mode", choices=[m.value for m in SWEEP_MODES])
    ap.add_argument("store")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="hard-crash (os._exit) at this kernel boundary")
    args = ap.parse_args(argv)

    mode = Mode(args.mode)
    plan = (FaultPlan(crash_at=args.crash_at, hard=True)
            if args.crash_at is not None else None)
    with JobStore(args.store) as store:
        if args.action == "run":
            specs = SCENARIOS[args.scenario]()
            sim = build_sim(specs, mode, store=store, fault_plan=plan)
        else:
            sim = SimScheduler.recover(store, mode, online=ONLINE)
            sim.fault_plan = plan
        sim.run()                    # a hard plan never returns from here
        done = [r.job_id for r in store.jobs(states=(DONE,))]
        print(json.dumps({"done": sorted(done),
                          "watermarks": {r.job_id: r.completed
                                         for r in store.jobs()}}))
    return 0


if __name__ == "__main__":
    sys.exit(child_main())
