"""Fast event core vs reference core: randomized differential suite.

The repo's differential-testing contract — every fast path keeps an O(n)
reference oracle — extended to the simulator's event loop itself.
``SimScheduler`` runs a slot-indexed, integer-coded fast event core by
default; ``SimScheduler(reference_core=True)`` runs the original
per-event string-dispatch loop. The two must be **bit-identical** in
every observable: per-device decision traces, task results, kernel
timeline, fill/steal/deadline counters and the processed-event count —
across randomized scenarios x {FIKIT, PREEMPT} x {fifo, sjf, edf} x
K in {1, 2, 4}, with the online measurement loop and the interference
model both on and off.

Also pinned here: the sharded fleet runner (``repro.sim.fleet``) against
the monolithic K-device scheduler — same traces after remapping shard-
local instance ids to global ones — and the timeline-off accounting
(``record_timeline=False`` busy accumulators vs the full timeline).
"""
from __future__ import annotations

import random

import pytest

from repro.core.interference import (COMPUTE_BOUND, MEMORY_BOUND,
                                     InterferenceModel)
from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig
from repro.core.policy import Mode
from repro.core.scheduler import SimScheduler, profile_tasks
from repro.core.task import TaskKey, TaskSpec, TraceKernel
from repro.sim.fleet import elect_devices, simulate_fleet
from repro.sim.workload import periodic_taskset, release_jobs

pytestmark = pytest.mark.fast

ENV = {(MEMORY_BOUND, MEMORY_BOUND): 1.5,
       (COMPUTE_BOUND, COMPUTE_BOUND): 1.1,
       (COMPUTE_BOUND, MEMORY_BOUND): 1.2,
       (MEMORY_BOUND, COMPUTE_BOUND): 1.05}


def _scenario(seed: int, n: int = 14):
    """A randomized task mix: mixed priorities, sync and async clients,
    partial deadline tagging, mixed kernel resource classes."""
    rng = random.Random(seed)
    kclasses = (None, COMPUTE_BOUND, MEMORY_BOUND)
    tasks = []
    for i in range(n):
        kernels = [TraceKernel(KernelID(f"s{seed}t{i}k{j}", (i,), (j,)),
                               duration=rng.uniform(1e-4, 5e-3),
                               gap_after=rng.uniform(0.0, 1e-3),
                               kclass=rng.choice(kclasses))
                   for j in range(rng.randint(1, 6))]
        arrival = rng.uniform(0.0, 0.02)
        deadline = (arrival + rng.uniform(5e-3, 5e-2)
                    if rng.random() < 0.5 else None)
        tasks.append(TaskSpec(TaskKey(f"svc{i % 5}", (i,)),
                              rng.randrange(10), kernels, arrival=arrival,
                              max_inflight=rng.choice((1, 1, 2, 4)),
                              deadline=deadline))
    return tasks


def _observables(sim: SimScheduler, report):
    return {
        "traces": [list(p.trace) for p in sim.placement.policies],
        "results": [(r.arrival, r.start, r.completion)
                    for r in report.results],
        "timeline": [(k.task, k.seq, k.start, k.end, k.filler, k.device)
                     for k in report.timeline],
        "fills": report.fills,
        "steals": report.steals,
        "overshoot": report.overshoot_time,
        "misses": (report.deadline_misses, report.deadlines_tagged),
        "events": report.events,
        "busy": report.busy,
    }


def _run(tasks, mode, *, reference, qd="fifo", K=1, profiled=None,
         jitter=0.0, seed=0, online=None, interference=None, env=None,
         steal=True):
    sim = SimScheduler(tasks, mode, profiled, jitter=jitter, seed=seed,
                       trace="list", devices=K, queue_discipline=qd,
                       steal=steal, online=online,
                       interference=interference, interference_env=env,
                       reference_core=reference)
    return _observables(sim, sim.run())


@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
@pytest.mark.parametrize("qd", ["fifo", "sjf", "edf"])
@pytest.mark.parametrize("K", [1, 2, 4])
def test_fast_core_bit_identical(mode, qd, K):
    for seed in (0, 1):
        tasks = _scenario(100 * seed + K)
        pd = profile_tasks(tasks, T=2, jitter=0.0,
                           measurement_overhead=0.0)
        kw = dict(qd=qd, K=K, profiled=pd, jitter=0.02, seed=seed)
        fast = _run(tasks, mode, reference=False, **kw)
        ref = _run(tasks, mode, reference=True, **kw)
        assert fast == ref, f"divergence: mode={mode} qd={qd} K={K}"


@pytest.mark.parametrize("K", [1, 2])
@pytest.mark.parametrize("feature", ["online", "interference", "both"])
def test_fast_core_bit_identical_with_feature_loops(K, feature):
    """The online SK/SG refinement loop and the interference model (and
    its physical environment) run inside the fast loop too — same
    observables as the reference core with each enabled."""
    for seed in (2, 3):
        tasks = _scenario(7 * seed + K, n=12)
        runs = {}
        for reference in (False, True):
            # fresh profiled data + collaborators per run: the online
            # loop COMMITS refinements into them, so sharing across the
            # two runs would hand the second one a different model
            kw = dict(K=K, seed=seed,
                      profiled=profile_tasks(tasks, T=2, jitter=0.0,
                                             measurement_overhead=0.0))
            if feature in ("online", "both"):
                kw["online"] = OnlineConfig(epoch_observations=4)
            if feature in ("interference", "both"):
                kw["interference"] = InterferenceModel(ENV)
                kw["env"] = ENV
            runs[reference] = _run(tasks, Mode.FIKIT,
                                   reference=reference, **kw)
        assert runs[False] == runs[True], \
            f"divergence: K={K} feature={feature}"


def test_reference_core_flag_is_the_original_loop():
    """Both cores count the same events and produce a report that says
    how many were processed (the fleet bench throughput numerator)."""
    tasks = _scenario(9)
    fast = _run(tasks, Mode.FIKIT, reference=False)
    ref = _run(tasks, Mode.FIKIT, reference=True)
    assert fast["events"] == ref["events"] > len(tasks)


def test_timeline_off_keeps_busy_accounting():
    """record_timeline=False drops per-kernel KernelExec rows but the
    per-device busy accumulators must equal the timeline's sums, and
    every other observable is unchanged."""
    tasks = _scenario(11)
    full_sim = SimScheduler(tasks, Mode.FIKIT, trace="list", devices=2,
                            record_timeline=True)
    full = full_sim.run()
    off_sim = SimScheduler(tasks, Mode.FIKIT, trace="list", devices=2,
                           record_timeline=False)
    off = off_sim.run()
    assert off.timeline == []
    for d in range(2):
        assert off.device_busy(d) == pytest.approx(full.device_busy(d))
    assert off.device_busy() == pytest.approx(full.device_busy())
    assert [list(p.trace) for p in off_sim.placement.policies] \
        == [list(p.trace) for p in full_sim.placement.policies]
    assert [(r.start, r.completion) for r in off.results] \
        == [(r.start, r.completion) for r in full.results]
    assert off.utilization() == pytest.approx(full.utilization())


def test_jobstore_pins_reference_core(tmp_path):
    """Ops-plane hooks only exist in the reference loop; wiring a
    jobstore must transparently select it (not crash the fast core) and
    not change scheduling decisions."""
    from repro.core.jobstore import JobStore
    tasks = _scenario(13, n=6)
    with JobStore(str(tmp_path / "jobs.db")) as store:
        sim = SimScheduler(tasks, Mode.FIKIT, trace="list",
                           jobstore=store)
        rep_store = sim.run()
    plain = SimScheduler(tasks, Mode.FIKIT, trace="list")
    rep_plain = plain.run()
    assert [(r.start, r.completion) for r in rep_store.results] \
        == [(r.start, r.completion) for r in rep_plain.results]


# ---------------------------------------------------------------------------
# Sharded fleet vs monolithic K-device scheduler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("discipline", ["round_robin", "priority_affinity"])
@pytest.mark.parametrize("mode", [Mode.FIKIT, Mode.PREEMPT])
def test_fleet_shards_bit_identical_to_monolithic(discipline, mode):
    for seed in (0, 4):
        ts = periodic_taskset(20, 5.0, seed=seed)
        jobs = release_jobs(ts)
        K = 4
        mono = SimScheduler(jobs, mode, devices=K, discipline=discipline,
                            steal=False, trace="list")
        mrep = mono.run()
        fl = simulate_fleet(jobs, mode, devices=K, discipline=discipline,
                            trace="list", record_timeline=True)
        assert fl.traces == [list(p.trace) for p in
                             mono.placement.policies]
        assert [(r.arrival, r.start, r.completion)
                for r in fl.report.results] \
            == [(r.arrival, r.start, r.completion) for r in mrep.results]
        assert sorted((k.task, k.seq, k.start, k.end, k.filler, k.device)
                      for k in fl.report.timeline) \
            == sorted((k.task, k.seq, k.start, k.end, k.filler, k.device)
                      for k in mrep.timeline)
        assert (fl.report.fills, fl.report.deadline_misses,
                fl.report.deadlines_tagged) \
            == (mrep.fills, mrep.deadline_misses, mrep.deadlines_tagged)
        assert fl.report.device_busy() == pytest.approx(mrep.device_busy())


def test_fleet_process_pool_matches_inline():
    ts = periodic_taskset(16, 4.0, seed=6)
    jobs = release_jobs(ts)
    a = simulate_fleet(jobs, Mode.FIKIT, devices=4, workers=1,
                       trace="list", record_timeline=True)
    b = simulate_fleet(jobs, Mode.FIKIT, devices=4, workers=2,
                       trace="list", record_timeline=True)
    assert a.traces == b.traces
    assert [(r.start, r.completion) for r in a.report.results] \
        == [(r.start, r.completion) for r in b.report.results]


def test_static_election_matches_placement_layer():
    """elect_devices reproduces the layer's election: every instance's
    ("begin", i) trace entry lands on the device elect_devices chose."""
    ts = periodic_taskset(18, 4.0, seed=8)
    jobs = release_jobs(ts)
    for disc in ("round_robin", "priority_affinity"):
        chosen = elect_devices(jobs, 3, disc)
        mono = SimScheduler(jobs, Mode.FIKIT, devices=3, discipline=disc,
                            steal=False, trace="list")
        mono.run()
        for d, pol in enumerate(mono.placement.policies):
            for ev in pol.trace:
                if ev[0] == "begin":
                    assert chosen[ev[1]] == d


def test_fleet_rejects_dynamic_disciplines_and_coupling_kwargs():
    jobs = release_jobs(periodic_taskset(6, 2.0, seed=1))
    with pytest.raises(ValueError):
        simulate_fleet(jobs, Mode.FIKIT, devices=2,
                       discipline="least_loaded")
    with pytest.raises(ValueError):
        simulate_fleet(jobs, Mode.FIKIT, devices=2, jitter=0.1)
    with pytest.raises(ValueError):
        simulate_fleet(jobs, Mode.FIKIT, devices=2, steal=True)
    with pytest.raises(ValueError):
        elect_devices(jobs, 2, "no_such_discipline")
