"""Multi-process worker fleet over one durable store.

Four layers of proof:

1. **Lease protocol unit tests** — claim atomicity/ordering, the
   live-lease exclusion (a leased row is unclaimable even while its
   state transiently reads ``submitted``), renew, reap, churn
   accounting, worker rows, coordination flags.
2. **Equivalence pin** — ONE worker claiming everything in one batch is
   decision-trace-identical to the single-process
   ``SimScheduler.recover`` path, for every scenario x sharing mode.
3. **Crash reclamation** — a REAL worker subprocess hard-crashes
   (``os._exit(86)``) mid-lease; a survivor reaps the expired leases
   and completes exactly the remaining suffix: zero lost, zero
   duplicated (the PR-7 conservation assertion, fleet edition).
4. **Admission seam** — ``AdmissionPlane(backend=StoreBackend(...))``
   persists admitted groups as sharded claimable rows, resolves tickets
   from store-observed completion, and folds per-worker backpressure
   into the admission decision.
"""
import shutil
import threading
import time

import pytest

from faultutils import (SCENARIOS, SWEEP_MODES, assert_conserved, profiles,
                        seed_worker_store, spawn_worker, total_kernels)
from repro.core.faults import CRASH_EXIT
from repro.core.jobstore import DONE, SUBMITTED, JobStore
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode, SimScheduler
from repro.core.task import TaskKey, TaskSpec, TraceKernel
from repro.serving.workers import (EngineWorker, SpecService, StoreBackend,
                                   WorkerConfig, WorkerSupervisor,
                                   enqueue_specs, fleet_status)

pytestmark = pytest.mark.fast


def k(name, dur=0.01, gap=0.002):
    return TraceKernel(KernelID(name), dur, gap)


def spec(name, prio, n=4):
    return TaskSpec(TaskKey(name), prio, [k(f"{name}/{i}")
                                          for i in range(n)])


# ---------------------------------------------------------------------------
# 1. lease protocol on the store
# ---------------------------------------------------------------------------
class TestLeases:
    def test_claim_is_priority_ordered_and_exclusive(self):
        with JobStore.memory() as store:
            enqueue_specs(store, [spec("lo", 5), spec("hi", 0),
                                  spec("mid", 2)])
            a = store.claim_jobs("wA", limit=2, lease_s=5.0)
            assert [r.key.process for r in a] == ["hi", "mid"]
            assert all(r.owner == "wA" and r.state == "running"
                       for r in a)
            b = store.claim_jobs("wB", limit=5, lease_s=5.0)
            assert [r.key.process for r in b] == ["lo"]

    def test_live_lease_blocks_claim_even_in_submitted_state(self):
        """The sim's write-ahead parks claimed jobs back in
        ``submitted`` until their arrival event; only lease EXPIRY may
        hand them to a peer."""
        with JobStore.memory() as store:
            (jid,) = enqueue_specs(store, [spec("x", 0)])
            store.claim_jobs("wA", lease_s=5.0, now=100.0)
            store.record_submit(jid, TaskKey("x"), 0, n_kernels=4,
                                state=SUBMITTED)     # write-ahead replay
            assert store.job(jid).state == SUBMITTED
            assert store.claim_jobs("wB", now=101.0) == []
            assert store.pending_jobs(now=101.0) == 0
            assert store.leased_jobs() == 1
            # ... but an EXPIRED lease is claimable directly, and that
            # claim counts as a reclaim
            got = store.claim_jobs("wB", now=106.0)
            assert [r.job_id for r in got] == [jid]
            assert got[0].owner == "wB" and got[0].reclaims == 1
            assert store.lease_churn() == 1

    def test_renew_extends_and_reports_lost_leases(self):
        with JobStore.memory() as store:
            store.register_worker("wA")
            enqueue_specs(store, [spec("x", 0)])
            store.claim_jobs("wA", lease_s=1.0, now=100.0)
            assert store.renew_leases("wA", lease_s=10.0, now=100.5) == 1
            assert store.reap_expired(now=105.0) == []   # renewed past it
            reaped = store.reap_expired(now=111.0)
            assert len(reaped) == 1
            assert reaped[0].state == SUBMITTED
            assert reaped[0].owner is None
            assert store.renew_leases("wA", now=111.0) == 0   # lost

    def test_reap_preserves_watermark_and_credits_reaper(self):
        with JobStore.memory() as store:
            store.register_worker("wB")
            (jid,) = enqueue_specs(store, [spec("x", 0, n=6)])
            store.claim_jobs("wA", lease_s=0.5, now=100.0)
            store.record_completion(jid, 0)
            store.record_completion(jid, 1)
            reaped = store.reap_expired(by="wB", now=101.0)
            assert reaped[0].completed == 2       # watermark intact
            assert reaped[0].reclaims == 1
            assert store.workers()[0]["reaped"] == 1
            # the re-claim sees the suffix: 4 kernels remain
            (rec,) = store.claim_jobs("wB", now=101.0)
            assert rec.remaining == 4

    def test_terminal_state_releases_lease(self):
        with JobStore.memory() as store:
            (jid,) = enqueue_specs(store, [spec("x", 0)])
            store.claim_jobs("wA", lease_s=500.0)
            store.record_state(jid, DONE)
            rec = store.job(jid)
            assert rec.owner is None and rec.lease_expires is None
            assert store.leased_jobs() == 0

    def test_shard_filtered_claim_and_pending(self):
        with JobStore.memory() as store:
            enqueue_specs(store, [spec("g", 0), spec("b", 5)],
                          qos=lambda s: "gold" if s.priority == 0
                          else "bronze")
            assert store.shards() == ["bronze", "gold"]
            assert store.pending_jobs(["gold"]) == 1
            got = store.claim_jobs("w", shards=["bronze"])
            assert [r.qos for r in got] == ["bronze"]
            assert store.claim_jobs("w", shards=[]) == []

    def test_flags_roundtrip(self):
        with JobStore.memory() as store:
            assert store.flag("workers_go") is None
            store.set_flag("workers_go", "1")
            assert store.flag("workers_go") == "1"
            store.clear_flag("workers_go")
            assert store.flag("workers_go") is None

    def test_worker_rows_accumulate(self):
        with JobStore.memory() as store:
            store.register_worker("w0")
            store.worker_update("w0", jobs_done=2, kernels_done=10,
                                steals=1, batches=1)
            store.worker_update("w0", jobs_done=1, kernels_done=5,
                                state="stopped")
            (row,) = store.workers()
            assert (row["jobs_done"], row["kernels_done"],
                    row["steals"], row["state"]) == (3, 15, 1, "stopped")


# ---------------------------------------------------------------------------
# 2. workers=1 pinned equivalent to the single-process recover() path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("mode", SWEEP_MODES)
def test_one_worker_trace_identical_to_recover(scenario, mode, tmp_path):
    base = tmp_path / "base.db"
    seed_worker_store(base, scenario)
    a, b = tmp_path / "a.db", tmp_path / "b.db"
    shutil.copy(base, a)
    shutil.copy(base, b)

    with JobStore(str(a)) as sa:
        ref = SimScheduler.recover(sa, mode)
        ref.run()
    with JobStore(str(b)) as sb:
        w = EngineWorker(sb, WorkerConfig(worker_id="solo", mode=mode,
                                          batch=1000))
        w.run()
        assert w.last_sim is not None
        assert w.last_sim.policy.trace == ref.policy.trace
        assert_conserved(sb, SCENARIOS[scenario]())


def test_worker_claims_own_shard_first_then_steals():
    with JobStore.memory() as store:
        enqueue_specs(store, [spec("g1", 0), spec("g2", 0), spec("b1", 5),
                              spec("b2", 5)],
                      qos=lambda s: "gold" if s.priority == 0
                      else "bronze")
        w = EngineWorker(store, WorkerConfig(
            worker_id="wG", batch=2, shards=("gold",), steal=True,
            heartbeat_s=0.05, lease_s=2.0))
        summary = w.run()
    assert summary["jobs_done"] == 4
    assert summary["steals"] == 2            # the two bronze jobs
    assert summary["batches"] == 2


def test_worker_without_steal_leaves_foreign_shards(tmp_path):
    with JobStore(str(tmp_path / "s.db")) as store:
        enqueue_specs(store, [spec("g", 0), spec("b", 5)],
                      qos=lambda s: "gold" if s.priority == 0
                      else "bronze")
        w = EngineWorker(store, WorkerConfig(
            worker_id="wG", shards=("gold",), steal=False,
            drain_on_empty=True, heartbeat_s=0.05, lease_s=2.0,
            poll_s=0.01))
        t = threading.Thread(target=w.run, daemon=True)
        t.start()
        deadline = time.monotonic() + 10
        while (store.pending_jobs(["gold"]) > 0
               and time.monotonic() < deadline):
            time.sleep(0.01)
        store.set_flag("workers_stop", "1")   # it polls forever otherwise
        t.join(timeout=10)
        assert not t.is_alive()
        assert store.pending_jobs(["bronze"]) == 1
        assert store.pending_jobs(["gold"]) == 0


def test_paced_store_stamps_wall_time(tmp_path):
    """The worker's sink must overwrite the sim's virtual timestamps
    with wall time — fleet JCT stats subtract enqueue wall time."""
    with JobStore(str(tmp_path / "s.db")) as store:
        t0 = time.time()
        enqueue_specs(store, [spec("x", 0)])
        EngineWorker(store, WorkerConfig(worker_id="w",
                                         heartbeat_s=0.05)).run()
        rec = store.jobs()[0]
        assert rec.state == DONE
        # virtual completion would be ~0.05; wall epoch is ~1.7e9
        assert rec.updated_at >= t0
        assert 0.0 <= rec.updated_at - rec.submitted_at < 60.0


# ---------------------------------------------------------------------------
# 3. crash reclamation across REAL processes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario,boundary", [("pair", 3), ("tiers", 7),
                                               ("churn", 11)])
def test_worker_crash_survivor_reclaims_suffix(scenario, boundary,
                                               tmp_path):
    db = tmp_path / "fleet.db"
    specs, _ = seed_worker_store(db, scenario, qos="gold")

    victim = spawn_worker(db, "victim", lease=0.5, heartbeat=0.1,
                          crash_at=boundary)
    _, verr = victim.communicate(timeout=60)
    assert victim.returncode == CRASH_EXIT, verr[-500:]
    with JobStore(str(db)) as store:
        assert store.leased_jobs() > 0        # died holding leases
        done_before = sum(1 for r in store.jobs() if r.state == DONE)

    survivor = spawn_worker(db, "survivor", lease=0.5, heartbeat=0.1)
    sout, serr = survivor.communicate(timeout=60)
    assert survivor.returncode == 0, serr[-500:]

    with JobStore(str(db)) as store:
        assert_conserved(store, specs)        # zero lost, zero duplicated
        assert store.leased_jobs() == 0
        assert store.lease_churn() >= len(specs) - done_before
        by_id = {w["worker_id"]: w for w in store.workers()}
        assert by_id["survivor"]["reaped"] + by_id["survivor"][
            "jobs_done"] > 0


def test_two_survivors_race_for_reclaimed_work(tmp_path):
    """Both survivors reap/claim concurrently; claims are transactional,
    so the suffix still completes exactly once."""
    db = tmp_path / "fleet.db"
    specs, _ = seed_worker_store(db, "churn")
    victim = spawn_worker(db, "victim", lease=0.4, heartbeat=0.1,
                          crash_at=9)
    victim.communicate(timeout=60)
    assert victim.returncode == CRASH_EXIT

    s1 = spawn_worker(db, "s1", lease=0.5, heartbeat=0.1, batch=2)
    s2 = spawn_worker(db, "s2", lease=0.5, heartbeat=0.1, batch=2)
    for p in (s1, s2):
        _, err = p.communicate(timeout=60)
        assert p.returncode == 0, err[-500:]
    with JobStore(str(db)) as store:
        assert_conserved(store, specs)


# ---------------------------------------------------------------------------
# 4. supervisor + fleet status
# ---------------------------------------------------------------------------
def test_supervisor_drains_store_across_two_workers(tmp_path):
    db = tmp_path / "fleet.db"
    specs, _ = seed_worker_store(
        db, "churn", qos=lambda s: "gold" if s.priority <= 1 else "bulk")
    sup = WorkerSupervisor(str(db), n=2, shard=True, batch=2,
                           lease_s=2.0, heartbeat_s=0.2)
    sup.start()
    try:
        summaries = sup.wait(timeout=60)
    finally:
        sup.kill()
    assert sum(s["jobs_done"] for s in summaries) == len(specs)
    assert sum(s["kernels_done"] for s in summaries) == \
        total_kernels(specs)
    with JobStore(str(db)) as store:
        assert_conserved(store, specs)
        fs = fleet_status(store)
    assert {w["worker_id"] for w in fs["workers"]} == {"w0", "w1"}
    assert all(w["state"] == "stopped" for w in fs["workers"])
    assert fs["pending"] == 0 and fs["leased"] == 0
    assert fs["jobs_done"] == len(specs)
    assert set(fs["classes"]) <= {"gold", "bulk"}
    for c in fs["classes"].values():
        assert c["jct_p50"] <= c["jct_p99"]
        assert c["jct_p99"] < 120.0           # wall seconds, not virtual


def test_stop_flag_halts_polling_worker(tmp_path):
    """A worker running with ``--no-drain-on-empty`` polls forever; the
    graceful-drain flag (what ``serve workers stop`` sets) ends it."""
    db = tmp_path / "fleet.db"
    with JobStore(str(db)):
        pass                                   # empty store
    p = spawn_worker(db, "w0", extra=("--no-drain-on-empty",
                                      "--poll", "0.01"))
    time.sleep(0.3)
    with JobStore(str(db)) as store:
        store.set_flag("workers_stop", "1")
    out, err = p.communicate(timeout=30)
    assert p.returncode == 0, err[-500:]
    import json
    assert json.loads(out.strip().splitlines()[-1])["jobs_done"] == 0


# ---------------------------------------------------------------------------
# 5. the admission seam: StoreBackend dispatch + per-worker backpressure
# ---------------------------------------------------------------------------
def _mk_plane(store, **kw):
    from repro.serving.admission import AdmissionPlane, QoSClass
    classes = (QoSClass("gold", priority=0, queue_limit=64, deadline=None,
                        max_batch=1),
               QoSClass("bronze", priority=5, queue_limit=64,
                        deadline=None, max_batch=1))
    return AdmissionPlane(None, classes=classes, **kw)


def test_admission_dispatches_through_store_to_worker(tmp_path):
    db = str(tmp_path / "s.db")
    store = JobStore(db)
    backend = StoreBackend(store, per_worker_backlog=1000)
    plane = _mk_plane(store, backend=backend).start()
    wstore = JobStore(db)
    worker = EngineWorker(wstore, WorkerConfig(
        worker_id="w0", drain_on_empty=False, poll_s=0.01,
        heartbeat_s=0.2, lease_s=2.0))
    t = threading.Thread(target=worker.run, daemon=True)
    t.start()
    try:
        tickets = [plane.submit(SpecService(spec(f"s{i}",
                                                 0 if i % 2 else 5)),
                                "gold" if i % 2 else "bronze")
                   for i in range(6)]
        outcomes = [tk.result(timeout=60) for tk in tickets]
        assert outcomes == ["completed"] * 6
        stats = plane.stats()["classes"]
        assert stats["gold"]["completed"] == 3
        assert stats["bronze"]["completed"] == 3
        assert all(tk.jct is not None and tk.jct >= 0.0
                   for tk in tickets)
        with JobStore(db) as chk:
            assert sorted({r.qos for r in chk.jobs()}) == ["bronze",
                                                           "gold"]
    finally:
        store.set_flag("workers_stop", "1")
        t.join(timeout=15)
        plane.stop()
        backend.close()
        store.close()
        wstore.close()


def test_backend_backpressure_rejects_with_retry_hint(tmp_path):
    db = str(tmp_path / "s.db")
    with JobStore(db) as store:
        backend = StoreBackend(store, per_worker_backlog=2,
                               retry_after=0.123)
        plane = _mk_plane(store, backend=backend, dispatcher=False)
        # no live workers: budget is one worker's backlog = 2
        enqueue_specs(store, [spec("a", 0), spec("b", 0)], qos="gold")
        t = plane.submit(SpecService(spec("c", 0)), "gold")
        assert t.outcome == "rejected"
        assert t.retry_after == pytest.approx(0.123)
        st = plane.stats()["classes"]["gold"]
        assert st["offered"] == st["rejected"] == 1
        backend.close()


def test_backend_overload_budget_scales_with_live_workers(tmp_path):
    db = str(tmp_path / "s.db")
    with JobStore(db) as store:
        backend = StoreBackend(store, per_worker_backlog=2)
        enqueue_specs(store, [spec("a", 0), spec("b", 0)], qos="gold")
        assert backend.overloaded("gold") is not None
        store.register_worker("w0")
        store.register_worker("w1")            # budget now 4
        assert backend.overloaded("gold") is None
        backend.close()


def test_shard_router_by_service():
    from repro.serving.admission import SHARD_ROUTERS
    svc = SpecService(spec("llama", 0))
    assert SHARD_ROUTERS["qos"](svc, "gold") == "gold"
    assert SHARD_ROUTERS["service"](svc, "gold") == "llama"


def test_unknown_shard_router_rejected():
    with pytest.raises(ValueError, match="shard router"):
        _mk_plane(JobStore.memory(), shard_by="nope")
