"""Admission plane: QoS classes, backpressure, SLO shedding, continuous
batching, the non-blocking submit path, and the admission-OFF trace
differential (the bit-identity contract for this PR).

Deterministic tests drive ``AdmissionPlane`` in manual-pump mode
(``dispatcher=False``) against a stub system with a controllable clock;
integration tests run the real ``ServingSystem``/``WallClockEngine``
with fake (no-JAX) services.
"""
import threading
import time

import pytest

from repro.core.client import HookClient
from repro.core.executor import WallClockEngine
from repro.core.kernel_id import KernelID
from repro.core.scheduler import Mode
from repro.core.task import TaskKey
from repro.serving import (AdmissionPlane, QoSClass, ServingSystem)
from repro.serving.admission import (
    CANCELLED, COMPLETED, FAILED, REJECTED, REQUEUED, SHED,
    coerce_admission)

pytestmark = pytest.mark.fast


# ---------------------------------------------------------------------------
# fixtures: fake services, stub system, fake clock
# ---------------------------------------------------------------------------
class _FakeSvc:
    """Duck-typed InferenceService: fake payloads, no models, no JAX."""

    class _Seg:
        def __init__(self, name, fn=None):
            self.name = name
            self.fn = fn or (lambda state: state)
            self.host_work = None

        def kernel_id(self, state):
            return KernelID(self.name)

    class _Svc:
        def __init__(self, segs):
            self.segments = segs

        def make_input(self):
            return 0

    def __init__(self, name="fake", priority=0, n=3, fns=None):
        self.key = TaskKey(name)
        self.priority = priority
        fns = fns or [None] * n
        self.svc = self._Svc([self._Seg(f"{name}/s{i}", fns[i])
                              for i in range(n)])

    def client(self, engine, identify=True):
        return HookClient(engine, self.key, self.priority,
                          self.svc.segments, identify=identify)


class _FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


class _StubSystem:
    """Synchronous engine stand-in: every group completes immediately
    with a scripted JCT (or error), so plane dispatch is deterministic."""

    def __init__(self, jct=1.0, error=None, clock=None):
        self.jct = jct
        self.error = error
        self.clock = clock
        self.groups = []          # (service, rel_deadline) per admit

    def _invoke_async(self, service, on_done, deadline=None):
        self.groups.append((service, deadline))
        if self.clock is not None and self.jct is not None:
            self.clock.t += self.jct           # time passes while serving
        if self.error is not None:
            on_done(None, self.error)
        else:
            on_done(self.jct, None)
        return 0


def make_plane(system=None, classes=None, clock=None, **kw):
    classes = classes or (QoSClass("gold", 0, queue_limit=4, max_batch=2),
                          QoSClass("bronze", 5, queue_limit=4, max_batch=4))
    clock = clock or _FakeClock()
    system = system or _StubSystem(clock=clock)
    kw.setdefault("dispatcher", False)
    kw.setdefault("record_events", True)
    return AdmissionPlane(system, classes, clock=clock, **kw), system, clock


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------
def test_qos_class_validation():
    with pytest.raises(ValueError, match="Q0..Q9"):
        QoSClass("x", priority=10)
    with pytest.raises(ValueError, match="queue_limit"):
        QoSClass("x", priority=0, queue_limit=0)
    with pytest.raises(ValueError, match="max_batch"):
        QoSClass("x", priority=0, max_batch=0)
    with pytest.raises(ValueError, match="duplicate"):
        AdmissionPlane(_StubSystem(), (QoSClass("a", 0), QoSClass("a", 1)))
    with pytest.raises(ValueError, match="at least one"):
        AdmissionPlane(_StubSystem(), ())
    with pytest.raises(ValueError, match="max_inflight"):
        AdmissionPlane(_StubSystem(), (QoSClass("a", 0),), max_inflight=0)


def test_unknown_qos_name_raises():
    plane, _, _ = make_plane()
    with pytest.raises(ValueError, match="unknown QoS class"):
        plane.submit(_FakeSvc(), "platinum")


def test_coerce_admission_specs():
    assert coerce_admission(None) is None
    assert coerce_admission(True) == {}
    c = QoSClass("solo", 1)
    assert coerce_admission(c) == {"classes": (c,)}
    assert coerce_admission([c]) == {"classes": (c,)}
    assert coerce_admission({"max_inflight": 2}) == {"max_inflight": 2}
    with pytest.raises(TypeError, match="admission="):
        coerce_admission(42)


# ---------------------------------------------------------------------------
# backpressure + requeue signals
# ---------------------------------------------------------------------------
def test_bounded_queue_rejects_with_retry_after():
    plane, system, clock = make_plane()
    svc = _FakeSvc()
    plane.note_latency(svc, 2.0)              # EMA known -> hint available
    tickets = [plane.submit(svc, "gold") for _ in range(6)]
    # queue_limit=4: the 5th and 6th submit trip backpressure immediately
    assert [t.outcome for t in tickets[:4]] == [None] * 4
    for t in tickets[4:]:
        assert t.outcome == REJECTED
        assert not t.requeue                  # overload, not a drain signal
        assert t.retry_after is not None and t.retry_after > 0
    plane.pump()
    assert all(t.outcome == COMPLETED for t in tickets[:4])
    s = plane.stats()["classes"]["gold"]
    assert (s["offered"], s["admitted"], s["rejected"]) == (6, 4, 2)


def test_stop_requeues_leftover_tickets():
    plane, system, clock = make_plane()
    svc = _FakeSvc()
    tickets = [plane.submit(svc, "bronze") for _ in range(3)]
    plane.stop()                              # never pumped: still queued
    assert all(t.outcome == REQUEUED and t.requeue for t in tickets)
    late = plane.submit(svc, "bronze")        # post-stop: reject + requeue
    assert late.outcome == REJECTED and late.requeue
    s = plane.stats()["classes"]["bronze"]
    assert (s["offered"], s["requeued"], s["rejected"]) == (4, 3, 1)


# ---------------------------------------------------------------------------
# SLO-aware shedding
# ---------------------------------------------------------------------------
def test_hopeless_deadline_is_shed_cold_service_is_not():
    plane, system, clock = make_plane()
    hot, cold = _FakeSvc("hot"), _FakeSvc("cold")
    plane.note_latency(hot, 5.0)              # known service time: 5s
    t_hopeless = plane.submit(hot, "gold", deadline=1.0)   # 1s budget
    t_fine = plane.submit(hot, "gold", deadline=10.0)
    t_cold = plane.submit(cold, "gold", deadline=0.001)    # never observed
    plane.pump()
    assert t_hopeless.outcome == SHED
    assert t_fine.outcome == COMPLETED
    assert t_cold.outcome == COMPLETED        # cold is never shed
    s = plane.stats()["classes"]["gold"]
    assert (s["offered"], s["admitted"], s["shed"]) == (3, 2, 1)
    assert s["offered"] == s["admitted"] + s["shed"] + s["rejected"]


def test_goodput_counts_only_in_deadline_completions():
    clock = _FakeClock()
    system = _StubSystem(jct=2.0, clock=clock)
    plane, _, _ = make_plane(system=system, clock=clock)
    svc = _FakeSvc()
    t_miss = plane.submit(svc, "gold", deadline=1.0)   # completes at 2.0
    t_hit = plane.submit(svc, "gold", deadline=50.0)
    plane.pump()
    assert t_miss.outcome == COMPLETED and t_hit.outcome == COMPLETED
    s = plane.stats()["classes"]["gold"]
    assert s["completed"] == 2
    assert s["goodput"] == pytest.approx(0.5)   # 1 of 2 offered in-SLO


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
def test_consecutive_same_service_coalesce_into_one_stream():
    plane, system, clock = make_plane()
    a, b = _FakeSvc("a"), _FakeSvc("b")
    ts = [plane.submit(a, "bronze") for _ in range(3)]
    ts += [plane.submit(b, "bronze")]
    plane.pump()
    # 3 a-invocations coalesced into ONE engine task stream, b alone
    assert [svc.key.process for svc, _ in system.groups] == ["a", "b"]
    assert [t.batch_size for t in ts] == [3, 3, 3, 1]
    assert all(t.outcome == COMPLETED for t in ts)
    s = plane.stats()["classes"]["bronze"]
    assert s["admitted"] == 4 and s["completed"] == 4


def test_batch_respects_max_batch_and_service_boundary():
    plane, system, clock = make_plane(
        classes=(QoSClass("only", 0, queue_limit=16, max_batch=2),))
    a, b = _FakeSvc("a"), _FakeSvc("b")
    for svc in (a, a, a, b, a):
        plane.submit(svc, "only")
    plane.pump()
    # a,a | a | b | a — max_batch=2 splits the head run; b breaks the run
    assert [svc.key.process for svc, _ in system.groups] == \
        ["a", "a", "b", "a"]


def test_batch_deadline_is_earliest_member_deadline():
    plane, system, clock = make_plane()
    svc = _FakeSvc()
    plane.submit(svc, "gold", deadline=9.0)
    plane.submit(svc, "gold", deadline=3.0)
    plane.pump()
    assert len(system.groups) == 1
    _, rel = system.groups[0]
    assert rel == pytest.approx(3.0)          # min member budget governs


# ---------------------------------------------------------------------------
# strict-priority dispatch / shed ordering
# ---------------------------------------------------------------------------
def test_strict_priority_no_inversion_and_event_log_proves_it():
    plane, system, clock = make_plane(max_inflight=1)
    hi, lo = _FakeSvc("hi"), _FakeSvc("lo")
    for _ in range(3):
        plane.submit(lo, "bronze")
    for _ in range(3):
        plane.submit(hi, "gold")
    plane.pump()
    assert plane.priority_inversions == 0
    admits = [e for e in plane.events if e[1] == "admit"]
    # every admit recorded zero queued requests in any higher class
    assert all(e[4] == 0 for e in admits)
    # and gold drained before the first bronze admit
    first_bronze = next(i for i, e in enumerate(admits) if e[2] == "bronze")
    assert all(e[2] == "gold" for e in admits[:first_bronze])


def test_failed_group_resolves_failed():
    clock = _FakeClock()
    system = _StubSystem(error=RuntimeError("boom"), clock=clock)
    plane, _, _ = make_plane(system=system, clock=clock)
    t = plane.submit(_FakeSvc(), "gold")
    plane.pump()
    assert t.outcome == FAILED
    assert isinstance(t.error, RuntimeError)
    assert plane.stats()["classes"]["gold"]["failed"] == 1


# ---------------------------------------------------------------------------
# the non-blocking client path (run_async / _invoke_async)
# ---------------------------------------------------------------------------
def test_run_async_matches_blocking_run():
    svc = _FakeSvc(n=4)
    done = threading.Event()
    got = {}
    with WallClockEngine(Mode.FIKIT) as eng:
        cl = svc.client(eng)
        state, jct = cl.run(0)
        def on_done(result, ajct, error):
            got.update(result=result, jct=ajct, error=error)
            done.set()
        cl.run_async(0, on_done)
        assert done.wait(5)
    assert got["error"] is None
    assert got["result"] == state
    assert got["jct"] > 0


def test_run_async_propagates_payload_error():
    def boom(state):
        raise ValueError("payload dead")
    svc = _FakeSvc(n=3, fns=[None, boom, None])
    done = threading.Event()
    got = {}
    with WallClockEngine(Mode.FIKIT) as eng:
        def on_done(result, jct, error):
            got.update(result=result, error=error)
            done.set()
        svc.client(eng).run_async(0, on_done)
        assert done.wait(5)
    assert got["result"] is None
    assert isinstance(got["error"], ValueError)


def test_invoke_async_counts_deadline_misses():
    slow = _FakeSvc(n=2, fns=[lambda s: (time.sleep(0.02), s)[1], None])
    done = threading.Event()
    with ServingSystem(Mode.FIKIT) as sys_:
        sys_._invoke_async(slow, lambda jct, err: done.set(),
                           deadline=0.001)
        assert done.wait(5)
        assert sys_.deadlines_tagged == 1
        assert sys_.deadline_misses == 1


def test_ops_cancel_resolves_ticket_cancelled():
    """An ops-plane cancel mid-flight surfaces as a CANCELLED ticket,
    not a hang or a failure."""
    gate = threading.Event()
    release = threading.Event()

    def block(state):
        gate.set()
        release.wait(5)
        return state

    svc = _FakeSvc(n=3, fns=[block, None, None])
    with ServingSystem(Mode.FIKIT,
                       admission={"max_inflight": 1}) as sys_:
        t = sys_.submit_async(svc, "gold")
        assert gate.wait(5)               # first kernel is on the device
        # the in-flight instance is the newest one the engine tracks
        insts = list(sys_.engine.placement._device_of)
        assert len(insts) == 1
        sys_.engine.cancel(insts[0])
        release.set()
        assert t.result(timeout=5) == CANCELLED
        assert sys_.cancelled_invocations == 1


# ---------------------------------------------------------------------------
# end-to-end over the real engine
# ---------------------------------------------------------------------------
def test_end_to_end_dispatcher_thread_serves_all_classes():
    hi, lo = _FakeSvc("hi", 0), _FakeSvc("lo", 5)
    with ServingSystem(Mode.FIKIT, admission=True) as sys_:
        ts = [sys_.submit_async(hi, "gold") for _ in range(5)]
        ts += [sys_.submit_async(lo, "bronze") for _ in range(5)]
        for t in ts:
            assert t.result(timeout=10) == COMPLETED
        st = sys_.status()["admission"]
        assert st["priority_inversions"] == 0
        g, b = st["classes"]["gold"], st["classes"]["bronze"]
        assert g["completed"] == 5 and b["completed"] == 5
        for s in (g, b):
            assert s["offered"] == (s["admitted"] + s["rejected"]
                                    + s["shed"] + s["requeued"])


def test_drain_completes_inflight_then_rejects_new():
    svc = _FakeSvc()
    with ServingSystem(Mode.FIKIT, admission=True) as sys_:
        ts = [sys_.submit_async(svc, "silver") for _ in range(4)]
        assert sys_.admission.drain(timeout=5)
        late = sys_.submit_async(svc, "silver")
        assert late.outcome == REJECTED and late.requeue
        assert all(t.result(timeout=5) in (COMPLETED,) for t in ts)


# ---------------------------------------------------------------------------
# the contract: admission OFF is bit-identical to direct invoke
# ---------------------------------------------------------------------------
def _normalized(trace):
    """Policy decision trace with instance ids renumbered by first
    appearance — instance ids are global counters, so two runs of the
    same scenario differ only in that offset."""
    mapping = {}
    out = []
    for ev in trace:
        ev = tuple(ev)
        if len(ev) > 1 and isinstance(ev[1], int):
            ev = (ev[0], mapping.setdefault(ev[1], len(mapping))) + ev[2:]
        out.append(ev)
    return out


def test_admission_off_trace_identical_to_direct_invoke():
    """The wired-but-disabled differential: a ServingSystem with the
    admission plane attached but ``enabled=False`` must hand the engine
    EXACTLY the call sequence of the no-plane direct ``invoke`` path —
    the policy decision traces are bit-identical after instance-id
    normalization."""
    pattern = ["a", "b", "a", "a", "b"]

    def direct():
        svcs = {"a": _FakeSvc("a", 0), "b": _FakeSvc("b", 5)}
        with ServingSystem(Mode.FIKIT) as sys_:
            for name in pattern:
                assert sys_.invoke(svcs[name], n=1)
            return _normalized(list(sys_.engine.policy.trace))

    def through_disabled_plane():
        svcs = {"a": _FakeSvc("a", 0), "b": _FakeSvc("b", 5)}
        qos = {"a": "gold", "b": "bronze"}
        with ServingSystem(Mode.FIKIT,
                           admission={"enabled": False}) as sys_:
            for name in pattern:
                t = sys_.submit_async(svcs[name], qos[name])
                assert t.outcome == COMPLETED     # resolves synchronously
                assert t.jct is not None
            assert sys_.admission is not None     # wired, just disabled
            assert not sys_.admission.enabled
            return _normalized(list(sys_.engine.policy.trace))

    a, b = direct(), through_disabled_plane()
    assert a == b
    assert any(ev[0] == "launch" for ev in a)     # non-trivial scenario

