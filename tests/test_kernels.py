"""Pallas kernel allclose tests: shape/dtype sweeps against the pure-jnp
oracles, executed with interpret=True on CPU (kernel bodies run in Python).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref

pytestmark = pytest.mark.slow

KEY = jax.random.key(42)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


FLASH_CASES = [
    # B, H, Kh, Sq, Sk, D, kwargs
    (2, 4, 4, 256, 256, 64, {}),                      # MHA causal
    (1, 8, 2, 256, 256, 128, dict(window=96)),        # GQA + SWA
    (2, 4, 1, 384, 384, 64, dict(chunk=128)),         # MQA + chunked
    (1, 2, 2, 128, 512, 64, dict(causal=False)),      # cross-shaped
    (1, 4, 4, 512, 512, 96, dict(window=128, block_q=256, block_k=128)),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: str(c[:6]))
def test_flash_attention_allclose(case, dtype):
    B, H, Kh, Sq, Sk, D, kw = case
    ks = jax.random.split(jax.random.fold_in(KEY, Sq * D), 3)
    q = _rand(ks[0], (B, H, Sq, D), dtype)
    k = _rand(ks[1], (B, Kh, Sk, D), dtype)
    v = _rand(ks[2], (B, Kh, Sk, D), dtype)
    out = flash_attention(q, k, v, interpret=True, **kw)
    ref = flash_attention_ref(q, k, v,
                              **{k_: v_ for k_, v_ in kw.items()
                                 if k_ in ("causal", "window", "chunk")})
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert out.dtype == q.dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


DECODE_CASES = [
    (2, 8, 2, 512, 64, {}, 300),
    (1, 4, 1, 1024, 128, dict(window=256), 900),
    (2, 4, 4, 512, 64, dict(chunk=256), 400),
    (3, 8, 8, 256, 128, {}, 100),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES, ids=lambda c: str(c[:5]))
def test_decode_attention_allclose(case, dtype):
    B, H, Kh, C, D, kw, pos = case
    ks = jax.random.split(jax.random.fold_in(KEY, C + D), 3)
    q = _rand(ks[0], (B, H, D), dtype)
    k = _rand(ks[1], (B, Kh, C, D), dtype)
    v = _rand(ks[2], (B, Kh, C, D), dtype)
    kpos = jnp.arange(C, dtype=jnp.int32)
    out = decode_attention(q, k, v, kpos, pos, interpret=True, **kw)
    ref = decode_attention_ref(q, k, v, kpos, pos, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


def test_decode_attention_ring_cache_semantics():
    """Ring-buffer slot positions: empty slots (-1) and out-of-window slots
    are masked identically by kernel and oracle."""
    B, H, Kh, C, D = 1, 4, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, H, D), jnp.float32)
    k = _rand(ks[1], (B, Kh, C, D), jnp.float32)
    v = _rand(ks[2], (B, Kh, C, D), jnp.float32)
    kpos = jnp.where(jnp.arange(C) < 100, jnp.arange(C), -1).astype(jnp.int32)
    out = decode_attention(q, k, v, kpos, 99, interpret=True, window=64)
    ref = decode_attention_ref(q, k, v, kpos, 99, window=64)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


RGLRU_CASES = [(8, 256, 256), (4, 128, 512), (16, 512, 128), (8, 384, 384)]


@pytest.mark.parametrize("case", RGLRU_CASES, ids=str)
def test_rglru_scan_allclose(case):
    B, S, W = case
    ks = jax.random.split(jax.random.fold_in(KEY, S + W), 3)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.3, 0.999)
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32) * 0.1
    h0 = jax.random.normal(ks[2], (B, W), jnp.float32)
    out = rglru_scan(a, b, h0, interpret=True, block_s=128)
    ref = rglru_scan_ref(a, b, h0)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_rglru_matches_model_recurrence():
    """Kernel output equals the step-by-step recurrence used at decode."""
    import numpy as np
    B, S, W = 2, 64, 128
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, W), jnp.float32, 0.5, 0.99)
    b = jax.random.normal(ks[1], (B, S, W), jnp.float32) * 0.1
    h0 = jnp.zeros((B, W), jnp.float32)
    out = np.asarray(rglru_scan(a, b, h0, interpret=True, block_s=32))
    h = np.zeros((B, W), np.float32)
    an, bn = np.asarray(a), np.asarray(b)
    for t in range(S):
        h = an[:, t] * h + bn[:, t]
        assert np.max(np.abs(out[:, t] - h)) < 1e-4
