"""Unit tests for the O(log n) scheduling fast path introduced for the
paper's <5% overhead budget: indexed PriorityQueues, interned KernelIDs,
flattened ProfiledData lookups, pluggable trace sinks, and the
fills_in_flight clamp."""
import pickle
import random

import pytest

from repro.core.fikit import best_prio_fit, best_prio_fit_scan
from repro.core.kernel_id import KernelID, kernel_id_for
from repro.core.policy import (FikitPolicy, ListTrace, Mode, NullTrace,
                               RingTrace, make_trace_sink)
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.task import KernelRequest, TaskKey

pytestmark = pytest.mark.fast


def _pd(entries):
    """entries: [(task_name, kernel_name, duration)]"""
    pd = ProfiledData()
    by_task = {}
    for tname, kname, dur in entries:
        by_task.setdefault(tname, {})[kname] = dur
    for tname, kernels in by_task.items():
        prof = TaskProfile(key=TaskKey(tname), runs=1)
        for kname, dur in kernels.items():
            prof.SK[KernelID(kname)] = dur
        pd.load(prof)
    return pd


def _req(tname, kname, prio, instance=0, seq=0):
    return KernelRequest(task_key=TaskKey(tname), kernel_id=KernelID(kname),
                        priority=prio, task_instance=instance, seq_index=seq)


# ---------------------------------------------------------------------------
# KernelID interning
# ---------------------------------------------------------------------------
def test_kernel_id_interned_identity():
    a = KernelID("k", (4, 4), (128, "float32"))
    b = KernelID("k", (4, 4), (128, "float32"))
    assert a is b
    assert hash(a) == hash(("k", (4, 4), (128, "float32")))
    assert a == b and not (a != b)
    assert KernelID("k") is not a


def test_kernel_id_immutable_and_ordered():
    a = KernelID("a")
    with pytest.raises(AttributeError):
        a.name = "b"
    assert KernelID("a") < KernelID("b")
    assert sorted([KernelID("b"), KernelID("a")])[0] is a


def test_kernel_id_pickle_reinterns():
    a = kernel_id_for("seg", mesh_fp="m0")
    b = pickle.loads(pickle.dumps(a))
    assert b is a


def test_kernel_id_str_encode_unchanged():
    k = KernelID("f", (2, 3), (4,))
    assert str(k) == "f<<<2x3,4>>>"
    assert k.encode() == "f|(2, 3)|(4,)"


# ---------------------------------------------------------------------------
# ProfiledData flat lookups + versioning
# ---------------------------------------------------------------------------
def test_profiled_data_flat_lookup_and_version():
    pd = ProfiledData()
    assert pd.version == 0
    prof = TaskProfile(key=TaskKey("t"), runs=1)
    prof.SK[KernelID("k")] = 0.002
    prof.SG[KernelID("k")] = 0.004
    pd.load(prof)
    assert pd.version == 1
    assert pd.predict_duration(TaskKey("t"), KernelID("k")) == 0.002
    assert pd.predict_gap(TaskKey("t"), KernelID("k")) == 0.004
    assert pd.predict_duration(TaskKey("t"), KernelID("other")) == -1.0
    assert pd.predict_gap(TaskKey("nope"), KernelID("k")) == 0.0
    # reload replaces stale flat entries
    prof2 = TaskProfile(key=TaskKey("t"), runs=2)
    prof2.SK[KernelID("k2")] = 0.009
    pd.load(prof2)
    assert pd.version == 2
    assert pd.predict_duration(TaskKey("t"), KernelID("k")) == -1.0
    assert pd.predict_duration(TaskKey("t"), KernelID("k2")) == 0.009


def test_queue_index_invalidated_by_profile_reload():
    pd = _pd([("a", "ka", 0.002)])
    qs = PriorityQueues()
    qs.push(_req("a", "ka", 5))
    got, dur = best_prio_fit(qs, 0.01, pd)
    assert got is not None and dur == 0.002
    qs.push(got)
    # reload with a new duration: the index must serve the NEW prediction
    prof = TaskProfile(key=TaskKey("a"), runs=1)
    prof.SK[KernelID("ka")] = 0.008
    pd.load(prof)
    got2, dur2 = best_prio_fit(qs, 0.01, pd)
    assert dur2 == 0.008


# ---------------------------------------------------------------------------
# Indexed PriorityQueues bookkeeping
# ---------------------------------------------------------------------------
def test_queue_len_remove_pop_iter():
    qs = PriorityQueues(threadsafe=False)
    reqs = [_req(f"t{i}", f"k{i}", prio=i % 10, instance=i) for i in range(30)]
    for r in reqs:
        qs.push(r)
    assert len(qs) == 30
    # iteration: priority-major, FIFO within level
    seen = list(qs)
    assert [r.priority for r in seen] == sorted(r.priority for r in reqs)
    # remove from the middle
    qs.remove(reqs[17])
    assert len(qs) == 29
    with pytest.raises(ValueError):
        qs.remove(reqs[17])
    # pop_highest drains in (priority, FIFO) order
    order = []
    while True:
        r = qs.pop_highest()
        if r is None:
            break
        order.append(r)
    assert len(order) == 29
    assert [r.priority for r in order] == sorted(r.priority for r in order)
    assert len(qs) == 0 and qs.peek_highest() is None
    assert qs.highest_nonempty() is None


def test_queue_head_of_stream_succession():
    """Removing a stream's head promotes its successor into the index."""
    pd = _pd([("s", "k0", 0.002), ("s", "k1", 0.005)])
    qs = PriorityQueues(threadsafe=False)
    qs.push(_req("s", "k0", 5, instance=1, seq=0))
    qs.push(_req("s", "k1", 5, instance=1, seq=1))
    # only the head (k0, dur 0.002) is eligible although k1 fits better
    got, dur = best_prio_fit(qs, 0.01, pd)
    assert got.seq_index == 0 and dur == 0.002
    # now the successor is head
    got2, dur2 = best_prio_fit(qs, 0.01, pd)
    assert got2.seq_index == 1 and dur2 == 0.005
    assert len(qs) == 0


def test_indexed_matches_scan_exhaustive_drain():
    """Drain randomized queues decision-by-decision; the indexed and scan
    implementations must select the same request every single time."""
    rng = random.Random(0)
    for trial in range(40):
        entries = []
        for i in range(rng.randint(1, 40)):
            # discrete durations -> ties are common
            entries.append((f"t{i}", f"t{i}_k", rng.randint(0, 9),
                            rng.choice([0.001, 0.002, 0.004, 0.008])))
        pd = _pd([(t, k, d) for t, k, _, d in entries])
        qa, qb = PriorityQueues(), PriorityQueues()
        for i, (t, k, p, _) in enumerate(entries):
            qa.push(_req(t, k, p, instance=i))
            qb.push(_req(t, k, p, instance=i))
        while True:
            idle = rng.choice([0.0005, 0.0015, 0.003, 0.005, 0.1])
            ra, da = best_prio_fit(qa, idle, pd)
            rb, db = best_prio_fit_scan(qb, idle, pd)
            assert (ra is None) == (rb is None)
            assert da == db
            if ra is None:
                if idle == 0.1:        # nothing fits even a huge gap: empty
                    break
                continue
            assert (ra.task_key, ra.task_instance, ra.seq_index) == \
                (rb.task_key, rb.task_instance, rb.seq_index)
        assert len(qa) == len(qb) == 0


# ---------------------------------------------------------------------------
# Cold-start provisional durations vs the index binding
# ---------------------------------------------------------------------------
def test_cold_flag_flip_rebinds_index_without_version_bump():
    """``enable_cold_start()`` changes what unprofiled heads predict
    WITHOUT bumping ``version`` — the index binding keys on the cold flag
    too, so the indexed path agrees with the O(n) scan within the very
    next decision instead of serving stale -1.0 sentinels."""
    pd = _pd([("warm", "kw", 0.002)])
    qa, qb = PriorityQueues(), PriorityQueues()
    for q in (qa, qb):
        q.push(_req("cold", "kc", 5, instance=1))   # never profiled
    # before the flip: the -1.0 sentinel hides the head on BOTH paths
    assert best_prio_fit(qa, 0.01, pd)[0] is None
    assert best_prio_fit_scan(qb, 0.01, pd)[0] is None
    v = pd.version
    pd.enable_cold_start()
    assert pd.version == v                  # the flip does not bump
    ra, da = best_prio_fit(qa, 0.01, pd)    # must rebind on the flag
    rb, db = best_prio_fit_scan(qb, 0.01, pd)
    assert ra is not None and rb is not None
    assert da == db == 0.002                # provisional = global mean SK
    assert (ra.task_key, ra.seq_index) == (rb.task_key, rb.seq_index)


def test_cold_estimate_binding_fixed_until_version_bump():
    """A head indexed under a cold provisional duration keeps that exact
    binding until the profile version changes; the load that shifts the
    global mean also bumps version, so the next decision serves the
    refreshed estimate — never a half-stale mix."""
    pd = _pd([("warm", "kw", 0.002)])
    pd.enable_cold_start()
    qs = PriorityQueues(threadsafe=False)
    qs.push(_req("cold", "kc", 5, instance=1))
    got, dur = best_prio_fit(qs, 0.01, pd)
    assert dur == 0.002                     # global mean over {0.002}
    assert qs.bound_version == pd.version
    qs.push(got)
    prof = TaskProfile(key=TaskKey("warm2"), runs=1)
    prof.SK[KernelID("kw2")] = 0.006
    pd.load(prof)                           # mean shifts AND version bumps
    assert qs.bound_version != pd.version
    got2, dur2 = best_prio_fit(qs, 0.01, pd)
    assert got2 is not None
    assert dur2 == pytest.approx((0.002 + 0.006) / 2)
    assert qs.bound_version == pd.version


# ---------------------------------------------------------------------------
# fills_in_flight clamp (regression: spurious/double fill_complete)
# ---------------------------------------------------------------------------
def test_fill_complete_spurious_clamps_at_zero():
    launched = []
    pol = FikitPolicy(Mode.FIKIT, _pd([("lo", "k", 0.002)]),
                      clock=lambda: 0.0,
                      launch=lambda req, filler: launched.append(req))
    pol.task_begin(0, TaskKey("hi"), 0, arrival=0.0)
    pol.task_begin(1, TaskKey("lo"), 5, arrival=0.0)
    pol.submit(_req("lo", "k", 5, instance=1))     # parks (holder is 0)
    pol.gap_open = True
    pol.gap_remaining = 0.01
    pol.try_fill()                                 # launches the filler
    assert pol.fills_in_flight == 1
    pol.fill_complete()
    assert pol.fills_in_flight == 0
    # double/spurious completion: clamped, counted, never negative
    pol.fill_complete()
    pol.fill_complete()
    assert pol.fills_in_flight == 0
    assert pol.spurious_fill_completions == 2
    # and the pipeline-depth budget is unaffected by the spurious events
    assert pol.pipeline_depth - pol.fills_in_flight == pol.pipeline_depth


# ---------------------------------------------------------------------------
# Trace sinks
# ---------------------------------------------------------------------------
def _drive(trace_spec):
    pol = FikitPolicy(Mode.FIKIT, ProfiledData(), clock=lambda: 0.0,
                      launch=lambda req, filler: None, trace=trace_spec)
    for i in range(5):
        pol.task_begin(i, TaskKey(f"t{i}"), i % 3, arrival=float(i))
        pol.submit(_req(f"t{i}", "k", i % 3, instance=i))
    for i in range(5):
        pol.task_end(i)
    return pol


def test_trace_sink_list_default():
    pol = _drive("list")
    assert isinstance(pol.trace, ListTrace)
    assert ("begin", 0) in pol.trace


def test_trace_sink_ring_bounded():
    pol = _drive(8)
    assert isinstance(pol.trace, RingTrace)
    assert pol.trace.maxlen == 8
    assert len(pol.trace) == 8                     # only the newest kept
    full = _drive("list")
    assert list(pol.trace) == list(full.trace)[-8:]


def test_trace_sink_off_records_nothing_but_schedules_identically():
    off = _drive("off")
    assert isinstance(off.trace, NullTrace)
    assert len(off.trace) == 0 and list(off.trace) == []
    ref = _drive("list")
    # scheduling state is identical with tracing disabled
    assert off.fill_count == ref.fill_count
    assert off.queued == ref.queued
    assert off.holder() == ref.holder()


def test_trace_sink_custom_and_bad_spec():
    sink = []
    pol = _drive(sink)
    assert pol.trace is sink and ("begin", 0) in sink
    with pytest.raises(ValueError):
        make_trace_sink(3.5)
