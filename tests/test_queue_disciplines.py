"""Directed tests for the intra-device queue disciplines (fifo/sjf/edf):
spec validation, per-level configuration, pop/fill selection semantics,
the EDF undated-request FIFO fallback, and deadline-miss accounting.

The randomized trace-identity guarantees live in
``tests/test_policy_differential.py`` (indexed vs O(n) reference scans);
this module pins the directed, human-readable properties.
"""
import random

import pytest

from repro.core.fikit import best_prio_fit, best_prio_fit_scan
from repro.core.kernel_id import KernelID
from repro.core.policy import FikitPolicy, Mode
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.queues import (PriorityQueues, QUEUE_DISCIPLINES,
                               normalize_disciplines)
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import KernelRequest, TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast


def _pd(entries):
    """entries: [(task_name, kernel_name, duration)]"""
    pd = ProfiledData()
    by_task = {}
    for tname, kname, dur in entries:
        by_task.setdefault(tname, {})[kname] = dur
    for tname, kernels in by_task.items():
        prof = TaskProfile(key=TaskKey(tname), runs=1)
        for kname, dur in kernels.items():
            prof.SK[KernelID(kname)] = dur
        pd.load(prof)
    return pd


def _req(tname, kname, prio, instance=0, seq=0, deadline=None):
    return KernelRequest(task_key=TaskKey(tname), kernel_id=KernelID(kname),
                         priority=prio, task_instance=instance,
                         seq_index=seq, deadline=deadline)


# ---------------------------------------------------------------------------
# Spec validation (mirrors the placement.DISCIPLINES unknown-name test)
# ---------------------------------------------------------------------------
def test_unknown_discipline_raises_with_sorted_known_names():
    with pytest.raises(ValueError) as ei:
        PriorityQueues(discipline_by_level="lifo")
    assert str(sorted(QUEUE_DISCIPLINES)) in str(ei.value)
    assert "'lifo'" in str(ei.value)


def test_unknown_discipline_raises_through_policy_and_engines():
    with pytest.raises(ValueError) as ei:
        FikitPolicy(Mode.FIKIT, clock=lambda: 0.0,
                    launch=lambda r, f: None, discipline="srtf")
    assert str(sorted(QUEUE_DISCIPLINES)) in str(ei.value)
    with pytest.raises(ValueError):
        SimScheduler([], Mode.FIKIT, queue_discipline="bogus")


def test_discipline_spec_forms():
    assert normalize_disciplines(None, 10) == ("fifo",) * 10
    assert normalize_disciplines("sjf", 10) == ("sjf",) * 10
    by_map = normalize_disciplines({0: "edf", 5: "sjf"}, 10)
    assert by_map[0] == "edf" and by_map[5] == "sjf"
    assert all(d == "fifo" for i, d in enumerate(by_map) if i not in (0, 5))
    seq = ("fifo",) * 9 + ("edf",)
    assert normalize_disciplines(list(seq), 10) == seq
    with pytest.raises(ValueError):       # out-of-range mapped level
        normalize_disciplines({10: "sjf"}, 10)
    with pytest.raises(ValueError):       # wrong-length sequence
        normalize_disciplines(["fifo"] * 3, 10)
    qs = PriorityQueues(discipline_by_level={2: "edf"})
    assert qs.discipline_of(2) == "edf" and qs.discipline_of(3) == "fifo"


# ---------------------------------------------------------------------------
# Pop selection semantics
# ---------------------------------------------------------------------------
def test_sjf_pops_shortest_head_ties_to_earliest():
    pd = _pd([("a", "ka", 0.004), ("b", "kb", 0.002), ("c", "kc", 0.002)])
    qs = PriorityQueues(profiled=pd, discipline_by_level="sjf")
    qs.push(_req("a", "ka", 5, instance=0))
    qs.push(_req("b", "kb", 5, instance=1))      # 2 ms, parked before c
    qs.push(_req("c", "kc", 5, instance=2))      # 2 ms tie
    assert qs.peek_highest().task_instance == 1  # shortest, earliest-parked
    assert [qs.pop_highest().task_instance for _ in range(3)] == [1, 2, 0]


def test_sjf_pop_respects_priority_levels_first():
    """Discipline orders WITHIN a level; cross-level priority still wins."""
    pd = _pd([("hi", "kh", 0.009), ("lo", "kl", 0.001)])
    qs = PriorityQueues(profiled=pd, discipline_by_level="sjf")
    qs.push(_req("lo", "kl", 7, instance=1))     # shorter but lower prio
    qs.push(_req("hi", "kh", 2, instance=0))
    assert qs.pop_highest().task_instance == 0


def test_edf_pops_earliest_deadline_undated_last():
    qs = PriorityQueues(discipline_by_level="edf")
    qs.push(_req("a", "k", 5, instance=0, deadline=None))
    qs.push(_req("b", "k", 5, instance=1, deadline=0.30))
    qs.push(_req("c", "k", 5, instance=2, deadline=0.10))
    qs.push(_req("d", "k", 5, instance=3, deadline=None))
    # dated by deadline first; undated fall back to FIFO park order
    assert [qs.pop_highest().task_instance for _ in range(4)] == [2, 1, 0, 3]


def test_pops_only_release_stream_heads():
    """A stream's later kernel must never pop before its earlier one, even
    when it is shorter / more urgent."""
    pd = _pd([("s", "k0", 0.008), ("s", "k1", 0.001)])
    qs = PriorityQueues(profiled=pd, discipline_by_level="sjf")
    qs.push(_req("s", "k0", 5, instance=0, seq=0))
    qs.push(_req("s", "k1", 5, instance=0, seq=1))   # shorter, same stream
    assert qs.pop_highest().seq_index == 0
    assert qs.pop_highest().seq_index == 1
    qe = PriorityQueues(discipline_by_level="edf")
    qe.push(_req("s", "k0", 5, instance=0, seq=0, deadline=0.9))
    qe.push(_req("s", "k1", 5, instance=0, seq=1, deadline=0.1))
    assert qe.pop_highest().seq_index == 0


# ---------------------------------------------------------------------------
# Gap-fill selection semantics
# ---------------------------------------------------------------------------
def test_sjf_fill_selects_shortest_fitting():
    pd = _pd([("a", "ka", 0.004), ("b", "kb", 0.001), ("c", "kc", 0.009)])
    for maker in (best_prio_fit, best_prio_fit_scan):
        qs = PriorityQueues(profiled=pd, discipline_by_level="sjf")
        qs.push(_req("a", "ka", 5, instance=0))
        qs.push(_req("b", "kb", 5, instance=1))
        qs.push(_req("c", "kc", 5, instance=2))  # does not fit 6 ms
        got, dur = maker(qs, 0.006, pd)
        assert got.task_instance == 1 and dur == 0.001, maker.__name__


def test_edf_fill_keeps_longest_fit_breaks_ties_by_deadline():
    # primary criterion unchanged: 4 ms beats 1 ms inside a 6 ms gap even
    # when the 1 ms head is more urgent
    pd = _pd([("a", "ka", 0.004), ("b", "kb", 0.001)])
    qs = PriorityQueues(profiled=pd, discipline_by_level="edf")
    qs.push(_req("b", "kb", 5, instance=1, deadline=0.01))
    qs.push(_req("a", "ka", 5, instance=0, deadline=9.0))
    got, dur = best_prio_fit(qs, 0.006, pd)
    assert got.task_instance == 0 and dur == 0.004
    # equal predicted durations: earliest deadline wins over park order
    pd2 = _pd([("x", "kx", 0.002), ("y", "ky", 0.002), ("z", "kz", 0.002)])
    for maker in (best_prio_fit, best_prio_fit_scan):
        qs2 = PriorityQueues(profiled=pd2, discipline_by_level="edf")
        qs2.push(_req("x", "kx", 5, instance=0, deadline=None))
        qs2.push(_req("y", "ky", 5, instance=1, deadline=0.5))
        qs2.push(_req("z", "kz", 5, instance=2, deadline=0.2))
        got, dur = maker(qs2, 0.006, pd2)
        assert got.task_instance == 2 and dur == 0.002, maker.__name__


# ---------------------------------------------------------------------------
# EDF undated fallback == FIFO, end to end
# ---------------------------------------------------------------------------
def _mix(deadlines=False):
    def k(name, dur, gap=0.0):
        return TraceKernel(KernelID(name), dur, gap)
    return [
        TaskSpec(TaskKey("hi"), 0, [k("hi/a", 0.002, 0.006)] * 8),
        TaskSpec(TaskKey("loA"), 5, [k("loA/a", 0.003, 0.0004)] * 9,
                 arrival=0.001,
                 deadline=0.08 if deadlines else None),
        TaskSpec(TaskKey("loB"), 5, [k("loB/a", 0.003, 0.0004)] * 9,
                 arrival=0.002,
                 deadline=0.03 if deadlines else None),
    ]


def test_edf_without_deadlines_is_trace_identical_to_fifo():
    """Every request undated -> edf degrades to FIFO ordering
    deterministically: bit-identical decision traces and timelines."""
    tasks = _mix(deadlines=False)
    pd = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    fifo = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                        queue_discipline="fifo")
    fifo.run()
    edf = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                       queue_discipline="edf")
    edf.run()
    assert list(edf.policy.trace) == list(fifo.policy.trace)


def test_edf_with_deadlines_reorders_equal_duration_ties():
    """With equal predicted durations, the urgent (later-arriving!) lo task
    overtakes the relaxed one under edf but not under fifo."""
    tasks = _mix(deadlines=True)
    pd = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    fifo = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                        queue_discipline="fifo").run()
    edf = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                       queue_discipline="edf").run()
    # loB (tight 30 ms deadline, parked second) finishes earlier under edf
    assert edf.results[2].completion < fifo.results[2].completion
    assert edf.deadline_misses <= fifo.deadline_misses


# ---------------------------------------------------------------------------
# Deadline-miss accounting
# ---------------------------------------------------------------------------
def test_sim_report_counts_deadline_misses():
    def k(name, dur, gap=0.0):
        return TraceKernel(KernelID(name), dur, gap)
    tasks = [
        TaskSpec(TaskKey("a"), 0, [k("a/x", 0.002)] * 5, deadline=1e-6),
        TaskSpec(TaskKey("b"), 1, [k("b/x", 0.002)] * 5, deadline=10.0),
        TaskSpec(TaskKey("c"), 2, [k("c/x", 0.002)] * 5),  # undated
    ]
    rep = SimScheduler(tasks, Mode.FIKIT).run()
    assert rep.deadlines_tagged == 2
    assert rep.deadline_misses == 1           # only the impossible one
    assert rep.deadline_miss_rate == 0.5
    undated = SimScheduler([tasks[2]], Mode.FIKIT).run()
    assert undated.deadlines_tagged == 0
    assert undated.deadline_miss_rate == 0.0


# ---------------------------------------------------------------------------
# Wall-clock deadline plumbing: HookClient budget -> absolute request tags
# ---------------------------------------------------------------------------
def test_wallclock_client_tags_absolute_deadlines():
    from repro.core.client import HookClient, Segment
    from repro.core.executor import WallClockEngine

    segs = [Segment(f"seg{i}", lambda s: s) for i in range(3)]
    with WallClockEngine(Mode.FIKIT, queue_discipline="edf") as eng:
        cl = HookClient(eng, TaskKey("svc"), 0, segs, identify=False)
        import time
        t0 = time.perf_counter()
        _, jct = cl.run(0, deadline=0.5)
        recs = eng.records()
    assert len(recs) == 3
    for r in recs:
        # absolute perf_counter deadline = call start + relative budget
        assert r.req.deadline is not None
        assert t0 < r.req.deadline < t0 + 0.5 + 1.0
    # undated runs stay undated
    with WallClockEngine(Mode.FIKIT, queue_discipline="edf") as eng2:
        cl2 = HookClient(eng2, TaskKey("svc2"), 0, segs, identify=False)
        cl2.run(0)
        assert all(r.req.deadline is None for r in eng2.records())


# ---------------------------------------------------------------------------
# Randomized pop mini-differential (indexed vs reference scan), local-run
# mirror of the hypothesis invariants in tests/test_property_fikit.py
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("discipline", sorted(QUEUE_DISCIPLINES))
def test_pop_indexed_matches_scan_randomized(discipline):
    # stable seed (str hash is salted per process -> unreproducible cases)
    rng = random.Random(sorted(QUEUE_DISCIPLINES).index(discipline))
    for _ in range(30):
        n = rng.randint(1, 25)
        entries = [(f"t{i}", f"t{i}k", rng.choice([0.001, 0.002, 0.004]))
                   for i in range(n)]
        pd = _pd(entries)
        qi = PriorityQueues(profiled=pd, discipline_by_level=discipline)
        qr = PriorityQueues(profiled=pd, discipline_by_level=discipline,
                            reference=True)
        for i, (t, kn, _) in enumerate(entries):
            dl = rng.choice([None, 0.1, 0.2, 0.2, 0.4])
            prio = rng.randint(0, 9)
            qi.push(_req(t, kn, prio, instance=i, deadline=dl))
            qr.push(_req(t, kn, prio, instance=i, deadline=dl))
        while len(qi):
            a, b = qi.pop_highest(), qr.pop_highest()
            assert (a.task_instance, a.seq_index) == \
                (b.task_instance, b.seq_index)
        assert qr.pop_highest() is None
