"""Online measurement loop (repro.core.online): EMA epoch commits,
cold-start estimation, drift counters, profile_store round-trips, and the
queue-index invalidation that epoch commits ride.

The OFF-is-bit-identical contract lives in the randomized differential
suite (tests/test_policy_differential.py); this module covers the ON
semantics directly.
"""
import math

import pytest

from repro.core.kernel_id import KernelID
from repro.core.online import OnlineConfig, OnlineMeasurement
from repro.core.profile_store import load_profiles, save_profiles
from repro.core.profiler import ProfiledData, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.scheduler import Mode, SimScheduler, profile_tasks
from repro.core.task import KernelRequest, TaskKey, TaskSpec, TraceKernel

pytestmark = pytest.mark.fast

HI = TaskKey("hi")
LO = TaskKey("lo")
K_HI = KernelID("hi/a")
K_LO = KernelID("lo/a")


def k(name, dur, gap=0.0):
    return TraceKernel(KernelID(name), dur, gap)


def gap_fill_tasks(n_hi=10, n_lo=12):
    return [
        TaskSpec(HI, 0, [k("hi/a", 0.002, 0.006)] * n_hi),
        TaskSpec(LO, 5, [k("lo/a", 0.003, 0.0005)] * n_lo, arrival=0.001),
    ]


def make_profile(key, sk, sg=None):
    prof = TaskProfile(key=key, runs=1)
    prof.SK = dict(sk)
    prof.SG = dict(sg or {})
    return prof


# ---------------------------------------------------------------------------
# OnlineConfig coercion
# ---------------------------------------------------------------------------
def test_online_config_coerce():
    assert OnlineConfig.coerce(None) is None
    assert OnlineConfig.coerce(False) is None
    assert isinstance(OnlineConfig.coerce(True), OnlineConfig)
    cfg = OnlineConfig(ema_alpha=0.5)
    assert OnlineConfig.coerce(cfg) is cfg
    with pytest.raises(TypeError):
        OnlineConfig.coerce("yes")


# ---------------------------------------------------------------------------
# EMA + epoch semantics (unit level)
# ---------------------------------------------------------------------------
def test_first_commit_sets_batch_mean_then_ema():
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(ema_alpha=0.25,
                                            epoch_observations=10**9,
                                            epoch_seconds=10**9))
    om.observe(0, 1, HI, K_HI, 0.0, 0.004)
    om.observe(0, 1, HI, K_HI, 0.010, 0.012)       # durations 4ms, 2ms
    assert pd.version == 0                          # nothing committed yet
    assert om.commit() == 1
    assert pd.version == 1
    assert math.isclose(pd.predict_duration(HI, K_HI), 0.003)  # batch mean
    # second epoch: EMA folds the new batch into the standing value
    om.observe(0, 1, HI, K_HI, 1.0, 1.007)          # 7ms
    om.commit()
    assert math.isclose(pd.predict_duration(HI, K_HI),
                        0.75 * 0.003 + 0.25 * 0.007)
    prof = pd.get(HI)
    assert prof.obs_count[K_HI] == 3
    assert prof.ema_alpha == 0.25


def test_epoch_commits_by_observation_count():
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=5,
                                            epoch_seconds=10**9))
    for i in range(4):
        assert not om.observe(0, 1, HI, K_HI, i * 1.0, i * 1.0 + 0.002)
    assert pd.version == 0 and om.commits == 0
    assert om.observe(0, 1, HI, K_HI, 9.0, 9.002)   # 5th obs: epoch closes
    assert om.commits == 1
    assert pd.version == 1
    assert om.pending_observations == 0


def test_epoch_commits_by_elapsed_time():
    now = [0.0]
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=10**9,
                                            epoch_seconds=0.5),
                           clock=lambda: now[0])
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    assert om.commits == 0
    now[0] = 0.6                                    # past epoch_seconds
    assert om.observe(0, 1, HI, K_HI, 0.55, 0.552)
    assert om.commits == 1


def test_gap_attribution_same_device_stream_only():
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=10**9,
                                            epoch_seconds=10**9))
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    om.observe(0, 1, HI, K_HI, 0.005, 0.007)       # gap 3ms after K_HI
    om.observe(1, 2, LO, K_LO, 0.0, 0.001)         # other device/instance
    om.commit()
    assert math.isclose(pd.predict_gap(HI, K_HI), 0.003)
    assert pd.predict_gap(LO, K_LO) == 0.0          # single obs: no pair
    assert om.gap_observations == 1
    # a migrated task (task_gone) loses its anchor: no cross-device gap
    om.task_gone(2)
    om.observe(0, 2, LO, K_LO, 0.010, 0.011)
    assert om.gap_observations == 1


def _om(pd=None):
    pd = pd if pd is not None else ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=10**9,
                                            epoch_seconds=10**9))
    return pd, om


def test_cross_device_completion_never_yields_gap_sample():
    """Same instance observed on another device (migration without a
    task_gone — defensive path): the device check alone must refuse the
    cross-timeline launch-to-launch delta."""
    pd, om = _om()
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    om.observe(1, 1, HI, K_HI, 0.010, 0.012)    # other device: no pair
    assert om.gap_observations == 0
    om.commit()
    assert pd.predict_gap(HI, K_HI) == 0.0      # nothing fabricated
    # the anchor re-bound to device 1: the next completion THERE pairs
    om.observe(1, 1, HI, K_HI, 0.015, 0.016)
    assert om.gap_observations == 1


def test_steal_then_observe_drops_gap_anchor():
    """The placement layer calls task_gone BEFORE a steal detaches a
    task; the first completion on the destination device must produce no
    gap sample, and the stream re-anchors on the new timeline."""
    pd, om = _om()
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    om.task_gone(1)                             # steal: anchor dropped
    om.observe(1, 1, HI, K_HI, 0.004, 0.006)    # first launch on dest
    assert om.gap_observations == 0
    om.observe(1, 1, HI, K_HI, 0.009, 0.011)    # same-device pair: clean
    assert om.gap_observations == 1
    om.commit()
    assert math.isclose(pd.predict_gap(HI, K_HI), 0.003)


def test_negative_raw_gap_skipped_not_clamped():
    """Overlapping wall-clock brackets (callback jitter) give a negative
    launch-to-launch gap: the sample is DROPPED, not clamped — recording
    a fabricated 0.0 would drag the SG estimate toward zero."""
    pd, om = _om()
    om.observe(0, 1, HI, K_HI, 0.0, 0.005)
    om.observe(0, 1, HI, K_HI, 0.004, 0.006)    # starts before prev end
    assert om.gap_observations == 0
    om.commit()
    assert pd.predict_gap(HI, K_HI) == 0.0
    assert pd.get(HI).gap_obs_count == {}
    # skipping is per-sample: the next clean pair still records
    om.observe(0, 1, HI, K_HI, 0.009, 0.010)    # gap 3ms after prev end
    assert om.gap_observations == 1
    om.commit()
    assert math.isclose(pd.predict_gap(HI, K_HI), 0.003)


def test_directed_steal_gap_attribution_stays_same_device(monkeypatch):
    """Force a real 2-device steal mid-run and replay every observation:
    a gap sample may only pair two same-device completions of one stream
    with no steal/retirement in between, and the stolen task's first
    completion on the destination device contributes none."""
    events = []
    orig_observe = OnlineMeasurement.observe
    orig_gone = OnlineMeasurement.task_gone

    def spy_observe(self, device, instance, key, kid, start, end, *,
                    last=False):
        before = self.gap_observations
        ret = orig_observe(self, device, instance, key, kid, start, end,
                           last=last)
        events.append(("obs", device, instance, key,
                       self.gap_observations - before, last))
        return ret

    def spy_gone(self, instance):
        events.append(("gone", instance))
        return orig_gone(self, instance)

    monkeypatch.setattr(OnlineMeasurement, "observe", spy_observe)
    monkeypatch.setattr(OnlineMeasurement, "task_gone", spy_gone)

    tasks = [
        TaskSpec(HI, 0, [k("hi/a", 0.002, 0.006)] * 20),
        TaskSpec(LO, 5, [k("lo/a", 0.003, 0.0005)] * 8, arrival=0.001),
        TaskSpec(TaskKey("tiny"), 9, [k("tiny/a", 0.001, 0.0001)] * 2,
                 arrival=0.0005),
    ]

    def pin(layer, instance, key, priority, arrival):
        # hi+lo co-located on device 0; tiny holds device 1 then retires,
        # leaving it idle to steal the parked lo task
        return 1 if key.process == "tiny" else 0

    pd = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    sim = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0, devices=2,
                       discipline=pin,
                       online=OnlineConfig(epoch_observations=4))
    sim.run()
    assert sim.placement.steal_count >= 1

    # the lo stream really ran on both devices (the steal moved it)
    lo_devices = {e[1] for e in events if e[0] == "obs" and e[3] == LO}
    assert lo_devices == {0, 1}

    anchor = {}
    crossings = 0
    for e in events:
        if e[0] == "gone":
            anchor.pop(e[1], None)
            continue
        _, device, inst, key, gained, last = e
        if anchor.get(inst) is not None and anchor[inst] != device:
            crossings += 1
        if gained:
            assert anchor.get(inst) == device, e
        if last:
            anchor.pop(inst, None)
        else:
            anchor[inst] = device
    # with task_gone called before the steal, the destination-device
    # completion never even sees a stale foreign anchor
    assert crossings == 0


def test_disabled_config_never_observes_or_commits():
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(enabled=False))
    assert not om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    om.observe_gap_error(0.001, 0.002)
    assert om.commit() == 0
    assert pd.version == 0
    assert om.observations == 0 and om.gap_drift_obs == 0
    assert not pd.cold_start                        # not flipped either


def test_commit_merges_device_buffers_with_one_load_per_key():
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=10**9,
                                            epoch_seconds=10**9))
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)          # device 0
    om.observe(1, 2, HI, K_HI, 0.0, 0.004)          # device 1, same key
    om.observe(1, 3, LO, K_LO, 0.0, 0.001)
    assert om.commit() == 2                         # two dirty TaskKeys
    assert pd.version == 2                          # one load per key
    assert math.isclose(pd.predict_duration(HI, K_HI), 0.003)  # merged mean
    assert om.committed_keys == 2


# ---------------------------------------------------------------------------
# Drift counters
# ---------------------------------------------------------------------------
def test_drift_counters_vs_strict_prediction():
    pd = ProfiledData()
    pd.load(make_profile(HI, {K_HI: 0.004}))        # wrong: true is 2ms
    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=10**9,
                                            epoch_seconds=10**9))
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    om.observe(0, 2, LO, K_LO, 0.0, 0.001)          # unprofiled: cold
    s = om.stats()
    assert s["drift_obs"] == 1
    assert math.isclose(s["drift_mean_abs_err"], 0.002)
    assert math.isclose(s["drift_mean_rel_err"], 0.5)
    assert s["cold_observations"] == 1


def test_gap_drift_recorded_by_policy_feedback_path():
    tasks = gap_fill_tasks()
    pd = profile_tasks(tasks, T=3, jitter=0.0, measurement_overhead=0.0)
    rep = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                       online=True).run()
    assert rep.online_stats["gap_drift_obs"] > 0


# ---------------------------------------------------------------------------
# Cold-start estimation (ProfiledData)
# ---------------------------------------------------------------------------
def test_cold_start_off_keeps_sentinel():
    pd = ProfiledData()
    pd.load(make_profile(HI, {K_HI: 0.002}))
    assert pd.predict_duration(HI, KernelID("hi/unseen")) == -1.0
    assert pd.predict_duration(LO, K_LO) == -1.0
    assert pd.cold_predictions == 0


def test_cold_start_key_mean_then_global_then_sentinel():
    pd = ProfiledData(cold_start=True)
    assert pd.predict_duration(HI, K_HI) == -1.0    # nothing loaded at all
    pd.load(make_profile(HI, {K_HI: 0.002, KernelID("hi/b"): 0.004}))
    # unseen kernel of a KNOWN key: that key's mean SK
    assert math.isclose(pd.predict_duration(HI, KernelID("hi/unseen")),
                        0.003)
    # unknown key: global mean over all loaded SK entries
    assert math.isclose(pd.predict_duration(LO, K_LO), 0.003)
    pd.load(make_profile(LO, {K_LO: 0.009}))
    assert math.isclose(pd.predict_duration(LO, KernelID("lo/unseen")),
                        0.009)
    assert math.isclose(pd.predict_duration(TaskKey("new"), K_LO),
                        (0.002 + 0.004 + 0.009) / 3)
    assert pd.cold_predictions > 0
    # profiled kernels are never affected by the estimator
    assert pd.predict_duration(HI, K_HI) == 0.002
    assert pd.predict_duration_raw(HI, KernelID("hi/unseen")) == -1.0


def test_cold_start_reload_replaces_key_contribution():
    pd = ProfiledData(cold_start=True)
    pd.load(make_profile(HI, {K_HI: 0.002}))
    pd.load(make_profile(HI, {K_HI: 0.006}))        # reload same key
    assert math.isclose(pd.predict_duration(HI, KernelID("hi/unseen")),
                        0.006)
    assert math.isclose(pd.predict_duration(LO, K_LO), 0.006)  # not 0.004


def test_cold_start_makes_unprofiled_task_fillable():
    """The motivating scenario: a never-profiled lo task is invisible to
    gap filling offline (-1.0 sentinel) but fillable under cold start."""
    tasks = gap_fill_tasks()
    # profile ONLY the hi task: lo is cold
    pd_off = profile_tasks(tasks[:1], T=3, jitter=0.0,
                           measurement_overhead=0.0)
    rep_off = SimScheduler(tasks, Mode.FIKIT, pd_off, jitter=0.0).run()
    assert rep_off.fills == 0                       # cold task: invisible

    pd_on = profile_tasks(tasks[:1], T=3, jitter=0.0,
                          measurement_overhead=0.0)
    rep_on = SimScheduler(tasks, Mode.FIKIT, pd_on, jitter=0.0,
                          online=True).run()
    assert rep_on.fills > 0                         # cold-start fills
    # the fills are the point: the cold lo task finishes earlier because
    # its kernels ride the hi task's gaps instead of waiting it out
    assert rep_on.jct(1) < rep_off.jct(1)


# ---------------------------------------------------------------------------
# Convergence on a stationary workload
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("jitter", [0.0, 0.05])
def test_predictions_converge_to_true_durations(jitter):
    """Starting from an EMPTY profile, the online loop's committed SK
    converges to the true kernel durations of a stationary workload."""
    tasks = [
        TaskSpec(HI, 0, [k("hi/a", 0.002, 0.006)] * 80),
        TaskSpec(LO, 5, [k("lo/a", 0.003, 0.0005)] * 90, arrival=0.001),
    ]
    pd = ProfiledData()
    rep = SimScheduler(tasks, Mode.FIKIT, pd, jitter=jitter, seed=3,
                       online=OnlineConfig(epoch_observations=16)).run()
    assert rep.online_stats["commits"] > 1
    for key, kid, true_dur in ((HI, K_HI, 0.002), (LO, K_LO, 0.003)):
        got = pd.predict_duration(key, kid)
        assert abs(got - true_dur) / true_dur < (0.02 if jitter == 0
                                                 else 0.15), (key, got)
    # drift error vs the learned profile is small by the end
    assert rep.online_stats["drift_mean_rel_err"] < 0.5


def test_stale_profile_is_corrected_online():
    """A profile that has drifted (2x the true durations) is pulled back
    toward truth by EMA epochs; drift counters expose the initial error."""
    tasks = gap_fill_tasks(n_hi=60, n_lo=70)
    pd = ProfiledData()
    pd.load(make_profile(HI, {K_HI: 0.004}, {K_HI: 0.012}))   # all 2x
    pd.load(make_profile(LO, {K_LO: 0.006}))
    rep = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0,
                       online=OnlineConfig(epoch_observations=16,
                                           ema_alpha=0.5)).run()
    assert rep.online_stats["drift_mean_rel_err"] > 0.1       # drift seen
    assert abs(pd.predict_duration(HI, K_HI) - 0.002) < 0.0005
    assert abs(pd.predict_duration(LO, K_LO) - 0.003) < 0.0008


# ---------------------------------------------------------------------------
# Epoch commits respect scheduling invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("epoch_n", [1, 4, 32])
def test_online_run_keeps_fill_below_holder_and_stream_order(epoch_n):
    tasks = [
        TaskSpec(TaskKey("a"), 0, [k("a/x", 0.002, 0.005)] * 12),
        TaskSpec(TaskKey("b"), 3, [k("b/x", 0.0015, 0.001)] * 10,
                 arrival=0.0005),
        TaskSpec(TaskKey("c"), 8, [k("c/x", 0.003, 0.0001)] * 14,
                 arrival=0.001, max_inflight=6),
    ]
    pd = profile_tasks(tasks[:2], T=3, jitter=0.0, measurement_overhead=0.0)
    sim = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.02, seed=5,
                       online=OnlineConfig(epoch_observations=epoch_n))
    rep = sim.run()
    holder = None
    for e in sim.policy.trace:
        if e[0] == "holder":
            holder = e[1]
        elif e[0] == "fill":
            assert holder is not None
            assert tasks[e[1]].priority > tasks[holder].priority
    per_task = {}
    for e in rep.timeline:
        per_task.setdefault(e.task, []).append(e.seq)
    for ti, seqs in per_task.items():
        assert seqs == sorted(seqs)
        assert seqs == list(range(len(tasks[ti].kernels)))


def test_online_multi_device_merges_and_conserves():
    tasks = [
        TaskSpec(TaskKey(f"t{i}"), i % 7,
                 [k(f"t{i}/x", 0.001 + 0.0005 * (i % 3), 0.001)] * 8,
                 arrival=0.0003 * i)
        for i in range(8)
    ]
    pd = ProfiledData()
    rep = SimScheduler(tasks, Mode.FIKIT, pd, jitter=0.0, devices=3,
                       online=OnlineConfig(epoch_observations=8)).run()
    assert rep.online_stats["observations"] == sum(len(t.kernels)
                                                  for t in tasks)
    for ti, spec in enumerate(tasks):
        execs = [e for e in rep.timeline if e.task == ti]
        assert len(execs) == len(spec.kernels)
    for i, spec in enumerate(tasks):
        got = pd.predict_duration(spec.key, spec.kernels[0].kid)
        true = spec.kernels[0].duration
        assert abs(got - true) < 1e-9, (i, got, true)


def test_online_determinism():
    tasks = gap_fill_tasks()
    pd1 = ProfiledData()
    pd2 = ProfiledData()
    cfg = OnlineConfig(epoch_observations=4)
    r1 = SimScheduler(tasks, Mode.FIKIT, pd1, jitter=0.03, seed=11,
                      online=cfg).run()
    r2 = SimScheduler(tasks, Mode.FIKIT, pd2, jitter=0.03, seed=11,
                      online=cfg).run()
    assert [e.__dict__ for e in r1.timeline] == \
        [e.__dict__ for e in r2.timeline]
    assert r1.online_stats == r2.online_stats


# ---------------------------------------------------------------------------
# Queue-index invalidation on mid-serving version bumps
# ---------------------------------------------------------------------------
def test_epoch_commit_invalidates_queue_duration_index():
    """A mid-serving commit bumps ProfiledData.version; the next indexed
    decision rebuilds the duration index instead of serving stale SK."""
    pd = ProfiledData()
    pd.load(make_profile(LO, {K_LO: 0.005}))        # too long for the gap
    qs = PriorityQueues(profiled=pd, threadsafe=False)
    req = KernelRequest(task_key=LO, kernel_id=K_LO, priority=5,
                        task_instance=1, seq_index=0)
    qs.push(req)
    qs.ensure_index(pd)
    assert qs.bound_version == pd.version
    assert qs.best_fit_under(0.004)[0] is None      # 5ms doesn't fit 4ms

    om = OnlineMeasurement(pd, OnlineConfig(epoch_observations=10**9,
                                            epoch_seconds=10**9))
    om.observe(0, 2, LO, K_LO, 0.0, 0.002)          # the kernel is 2ms now
    om.commit()
    assert qs.bound_version != pd.version           # index is stale
    qs.ensure_index(pd)
    assert qs.bound_version == pd.version
    # EMA pulled SK to 0.75*5ms + 0.25*2ms = 4.25ms: fits a 4.5ms gap
    got, dur = qs.best_fit_under(0.0045)
    assert got is req                               # refreshed SK fits
    assert math.isclose(dur, 0.75 * 0.005 + 0.25 * 0.002)


# ---------------------------------------------------------------------------
# profile_store round-trips online state
# ---------------------------------------------------------------------------
def test_profile_store_roundtrips_online_state(tmp_path):
    pd = ProfiledData()
    om = OnlineMeasurement(pd, OnlineConfig(ema_alpha=0.4,
                                            epoch_observations=10**9,
                                            epoch_seconds=10**9))
    om.observe(0, 1, HI, K_HI, 0.0, 0.002)
    om.observe(0, 1, HI, K_HI, 0.004, 0.006)        # + a gap sample
    om.observe(0, 2, LO, K_LO, 0.0, 0.003)
    om.commit()
    path = str(tmp_path / "profiles.json")
    save_profiles(path, pd)
    back = load_profiles(path, cold_start=True)
    assert back.cold_start
    for key, kid in ((HI, K_HI), (LO, K_LO)):
        orig, got = pd.get(key), back.get(key)
        assert got.SK == orig.SK
        assert got.SG == orig.SG
        assert got.obs_count == orig.obs_count
        assert got.gap_obs_count == orig.gap_obs_count
        assert got.ema_alpha == orig.ema_alpha == 0.4
        assert got.online_observations == orig.online_observations
    # resumed smoothing continues from the restored EMA state
    om2 = OnlineMeasurement(back, OnlineConfig(ema_alpha=0.4,
                                               epoch_observations=10**9,
                                               epoch_seconds=10**9))
    om2.observe(0, 5, HI, K_HI, 0.0, 0.004)
    om2.commit()
    assert math.isclose(back.predict_duration(HI, K_HI),
                        0.6 * 0.002 + 0.4 * 0.004)
    assert back.get(HI).obs_count[K_HI] == 3


def test_profile_store_offline_format_unchanged_and_loadable(tmp_path):
    """Purely offline profiles write the original compact format (no
    online keys) and old-format files load with empty online state."""
    import json
    pd = ProfiledData()
    pd.load(make_profile(HI, {K_HI: 0.002}, {K_HI: 0.006}))
    path = str(tmp_path / "offline.json")
    save_profiles(path, pd)
    with open(path) as f:
        raw = json.load(f)
    assert set(raw[0]) == {"process", "args", "runs", "SK", "SG"}
    back = load_profiles(path)
    assert not back.cold_start
    prof = back.get(HI)
    assert prof.obs_count == {} and prof.gap_obs_count == {}
    assert prof.ema_alpha is None
    assert prof.SK == {K_HI: 0.002}


# ---------------------------------------------------------------------------
# Wall-clock engine integration (fake payloads, no JAX)
# ---------------------------------------------------------------------------
def test_wallclock_engine_online_observes_and_flushes():
    from repro.core.executor import WallClockEngine

    eng = WallClockEngine(Mode.FIKIT, ProfiledData(),
                          online=OnlineConfig(epoch_observations=10**9,
                                              epoch_seconds=10**9))
    with eng:
        eng.task_begin(1, HI, 0)
        for i in range(3):
            req = KernelRequest(task_key=HI, kernel_id=K_HI, priority=0,
                                task_instance=1, seq_index=i,
                                payload=lambda: None)
            eng.submit(req).result(timeout=5)
        eng.task_end(1)
        assert eng.online_stats()["observations"] == 3
        assert eng.online_stats()["commits"] == 0
    # stop() flushed the partial epoch into the profile
    assert eng.online.commits == 1
    assert eng.profiled.predict_duration(HI, K_HI) >= 0.0
    assert eng.profiled.get(HI).obs_count[K_HI] == 3


def test_wallclock_engine_online_off_is_none():
    from repro.core.executor import WallClockEngine

    eng = WallClockEngine(Mode.FIKIT, ProfiledData())
    assert eng.online is None
    assert eng.online_stats() is None
