"""Cluster-scale fleet benchmark: throughput of the fast event core and
FIKIT's hi-priority protection at fleet scale.

Four measurements, all driven by ``repro.sim`` (workload generator +
sharded fleet runner + analytics):

1. **scale** — the headline scenario: a Poisson-merged three-class
   tenant mix over a large fleet (full: 1000 devices, 10^6 kernel
   requests; smoke: 50 devices, 5*10^4), simulated with traces and
   timelines off. Reports events/sec (gated floor) and wall seconds
   (gated budget — the nightly CI wall-clock assertion).
2. **fast_vs_reference** — the same monolithic scenario through the
   fast event core and the per-event reference core
   (``SimScheduler(reference_core=True)``): decision traces must be
   bit-identical (gated) and the speedup is tracked.
3. **protection** — an overloaded smaller fleet run under FIKIT vs
   default SHARING: the hi-class p99 JCT ratio (FIKIT / SHARING) must
   stay under the gated ceiling < 1 — priority protection must not
   evaporate at fleet scale.
4. **load_curve** — deadline-miss-rate-vs-load points from UUNIFAST
   periodic task sets swept over total utilization, per tenant class,
   plus the per-device utilization histogram of the scale run. Curve
   points are reported (not gated) except the FIKIT ordering property
   that the hi class's miss rate stays <= the lo class's at every load
   point (gated) — zero hi misses is NOT attainable with implicit
   (deadline = period) task sets under co-location, but priority
   ordering of misses is exactly what the scheduler sells.

Sharded-vs-monolithic equivalence also re-checks here on a small fleet
(gated) so the bench itself cannot drift off the contract pinned by
``tests/test_sim_fastcore.py``.

Set BENCH_SMOKE=1 (CI) for the reduced sizes; the full run (nightly)
executes the 1000-device / 10^6-request scenario.
"""
from __future__ import annotations

import os
import random
import time

from benchmarks.common import Csv
from repro.core.policy import Mode
from repro.core.scheduler import SimScheduler
from repro.core.task import TaskKey, TaskSpec
from repro.serving.loadgen import merge_schedules, poisson_arrivals
from repro.sim.analytics import (fleet_summary, per_class_jct, percentile,
                                 utilization_histogram)
from repro.sim.fleet import simulate_fleet
from repro.sim.workload import (KernelShape, periodic_taskset, release_jobs,
                                specs_from_arrivals)

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))
SEED = 11

DEVICES = 50 if SMOKE else 1000
REQUESTS = 5_000 if SMOKE else 100_000
KERNELS_PER_REQ = 10
SCALE_UTIL = 0.6          # per-device offered load of the scale scenario
WALL_BUDGET_S = 120.0 if SMOKE else 600.0

PROTECT_DEVICES = 8 if SMOKE else 32
PROTECT_REQUESTS = 2_000 if SMOKE else 16_000
PROTECT_UTIL = 1.3        # overloaded: where protection matters

CURVE_UTILS = (0.5, 1.2) if SMOKE else (0.4, 0.7, 1.0, 1.3)
CURVE_DEVICES = 4
CURVE_TASKS_PER_DEVICE = 6

#: three tenant classes; shares mirror the serving bench's gold/silver/
#: bronze mix. 10-kernel shapes => REQUESTS * 10 kernel requests total.
CLASSES = (
    ("hi", 0, 0.10, KernelShape("hi", n_kernels=KERNELS_PER_REQ,
                                gap_fraction=0.15, spread=0.4,
                                max_inflight=1,
                                kclass_cycle=("compute",))),
    ("mid", 4, 0.30, KernelShape("mid", n_kernels=KERNELS_PER_REQ,
                                 gap_fraction=0.10, spread=0.5,
                                 max_inflight=2,
                                 kclass_cycle=("compute", "memory"))),
    ("lo", 8, 0.60, KernelShape("lo", n_kernels=KERNELS_PER_REQ,
                                gap_fraction=0.05, spread=0.6,
                                max_inflight=4,
                                kclass_cycle=("memory", "compute"))),
)

KERNEL_MS = 1.0           # mean kernel duration of every class


def _templates():
    """One TaskSpec template per tenant class (kernels shared across all
    of its requests)."""
    rng = random.Random(SEED)
    out = {}
    for name, prio, share, shape in CLASSES:
        wcet = KERNEL_MS * 1e-3 * shape.n_kernels
        out[name] = (share, TaskSpec(
            key=TaskKey(f"fleet_{name}"), priority=prio,
            kernels=shape.synthesize(wcet, rng),
            max_inflight=shape.max_inflight))
    return out


def _class_mix(requests: int, devices: int, util: float, seed: int):
    """Merged Poisson trace of ``requests`` jobs across the tenant
    classes, rate-tuned so fleet offered load ~= ``util`` per device."""
    tpls = _templates()
    mean_solo = sum(share * t.solo_jct for share, t in tpls.values())
    total_rate = util * devices / mean_solo
    duration = requests / total_rate
    rng = random.Random(seed)
    scheds = [poisson_arrivals(total_rate * share, duration, tpl, name, rng)
              for name, (share, tpl) in tpls.items()]
    return specs_from_arrivals(merge_schedules(*scheds))


def _class_of(spec: TaskSpec):
    return spec.key.process.rsplit("_", 1)[-1]


def _run_scale():
    jobs = _class_mix(REQUESTS, DEVICES, SCALE_UTIL, SEED)
    t0 = time.perf_counter()
    fl = simulate_fleet(jobs, Mode.FIKIT, devices=DEVICES,
                        discipline="round_robin")
    wall = time.perf_counter() - t0
    summary = fleet_summary(jobs, fl.report, class_of=_class_of)
    return jobs, fl, wall, summary


def _run_fast_vs_reference():
    """Monolithic single-device head-to-head, trace identity + speedup."""
    n = 500 if SMOKE else 5_000
    jobs = _class_mix(n, 1, SCALE_UTIL, SEED + 1)
    walls = {}
    traces = {}
    for label, kw in (("fast", {}), ("reference", {"reference_core": True})):
        t0 = time.perf_counter()
        sim = SimScheduler(jobs, Mode.FIKIT, trace="list",
                           record_timeline=False, **kw)
        sim.run()
        walls[label] = time.perf_counter() - t0
        traces[label] = list(sim.placement.policies[0].trace)
    identical = traces["fast"] == traces["reference"]
    speedup = walls["reference"] / max(walls["fast"], 1e-9)
    return identical, speedup, walls


def _run_protection():
    """FIKIT vs SHARING on an overloaded fleet: hi-class p99 ratio."""
    jobs = _class_mix(PROTECT_REQUESTS, PROTECT_DEVICES, PROTECT_UTIL,
                      SEED + 2)
    p99 = {}
    for mode in (Mode.FIKIT, Mode.SHARING):
        fl = simulate_fleet(jobs, mode, devices=PROTECT_DEVICES,
                            discipline="round_robin")
        stats = per_class_jct(jobs, fl.report, class_of=_class_of)
        p99[mode.name] = {c: s["p99"] for c, s in stats.items()}
    ratio = p99["FIKIT"]["hi"] / p99["SHARING"]["hi"]
    return ratio, p99


def _run_load_curve():
    """Deadline-miss-rate-vs-load from UUNIFAST periodic task sets."""
    curve = []
    for u in CURVE_UTILS:
        ts = periodic_taskset(CURVE_DEVICES * CURVE_TASKS_PER_DEVICE,
                              u * CURVE_DEVICES, seed=SEED + 3,
                              phase_jitter=1.0)
        jobs = release_jobs(ts, cycles=1)
        fl = simulate_fleet(jobs, Mode.FIKIT, devices=CURVE_DEVICES,
                            discipline="round_robin")
        summary = fleet_summary(jobs, fl.report,
                                class_of=lambda s: s.priority)
        curve.append({"util_per_device": u, "jobs": len(jobs),
                      "miss_rate": fl.report.deadline_miss_rate,
                      "miss_by_class": summary["miss_by_class"]})
    return curve


def _run_fleet_mono_check():
    """Small sharded-vs-monolithic re-check of the equivalence contract."""
    jobs = _class_mix(300, 4, 0.9, SEED + 4)
    mono = SimScheduler(jobs, Mode.FIKIT, devices=4,
                        discipline="round_robin", steal=False, trace="list")
    mono.run()
    fl = simulate_fleet(jobs, Mode.FIKIT, devices=4,
                        discipline="round_robin", trace="list")
    return fl.traces == [list(p.trace) for p in mono.placement.policies]


def main():
    jobs, fl, wall, scale_summary = _run_scale()
    events_per_sec = fl.report.events / max(wall, 1e-9)
    fast_ref_ok, speedup, walls = _run_fast_vs_reference()
    protect_ratio, protect_p99 = _run_protection()
    curve = _run_load_curve()
    fleet_mono_ok = _run_fleet_mono_check()
    miss_ordering_ok = True
    for pt in curve:
        by = pt["miss_by_class"]
        if by:
            hi_c = min(by, key=int)
            lo_c = max(by, key=int)
            if by[hi_c]["miss_rate"] > by[lo_c]["miss_rate"]:
                miss_ordering_ok = False

    csv = Csv(("name", "value", "derived"))
    csv.add("devices", DEVICES, f"{len(jobs)} jobs x {KERNELS_PER_REQ} "
            f"kernels (smoke {SMOKE})")
    csv.add("scale_wall_s", round(wall, 2),
            f"budget {WALL_BUDGET_S:g}s")
    csv.add("events_per_sec", round(events_per_sec),
            f"{fl.report.events} events")
    csv.add("fast_ref_trace_identical", fast_ref_ok,
            f"speedup {speedup:.2f}x "
            f"(ref {walls['reference']:.2f}s fast {walls['fast']:.2f}s)")
    csv.add("fleet_mono_trace_identical", fleet_mono_ok)
    csv.add("hi_p99_protect_ratio", round(protect_ratio, 3),
            f"FIKIT {1e3 * protect_p99['FIKIT']['hi']:.2f}ms vs SHARING "
            f"{1e3 * protect_p99['SHARING']['hi']:.2f}ms at "
            f"{PROTECT_UTIL}x load")
    for pt in curve:
        csv.add(f"miss_rate@{pt['util_per_device']:g}",
                round(pt["miss_rate"], 4), f"{pt['jobs']} jobs")
    csv.add("miss_ordering_ok", miss_ordering_ok,
            "hi miss rate <= lo miss rate at every load point")
    csv.emit("fleet (cluster-scale sharded simulation)")

    csv.json_payload = {
        "smoke": SMOKE,
        "devices": DEVICES,
        "requests": len(jobs),
        "kernels_per_request": KERNELS_PER_REQ,
        "scale": {"wall_s": wall, "budget_s": WALL_BUDGET_S,
                  "events": fl.report.events,
                  "events_per_sec": events_per_sec,
                  "summary": scale_summary},
        "fast_vs_reference": {"trace_identical": fast_ref_ok,
                              "speedup": speedup, "walls_s": walls},
        "fleet_mono_trace_identical": fleet_mono_ok,
        "protection": {"hi_p99_protect_ratio": protect_ratio,
                       "p99_by_mode": protect_p99,
                       "devices": PROTECT_DEVICES,
                       "util_per_device": PROTECT_UTIL},
        "load_curve": curve,
        "miss_ordering_ok": miss_ordering_ok,
        "util_histogram": utilization_histogram(fl.report),
    }
    return csv


if __name__ == "__main__":
    main()
