"""Paper Fig 19 + Fig 20: preemption scenario. Low-priority service runs
continuously; a high-priority task is inserted every second (100 total).

Claims: high-priority JCT under FIKIT is up to ~15.8x faster than default
sharing (most combos), and the continuously-running low-priority service's
JCT under FIKIT stays 0.86-1x of its sharing-mode value.

The PREEMPT columns are the paper's *preemptive sharing* baseline: at
every kernel boundary the device is reserved for the highest-priority
tier — lower-priority launches park in the priority queues until no
strictly-higher-priority task is active (no gap filling). High-priority
JCT matches FIKIT's (both isolate the holder); the low-priority service
retains 0.86-1.0x of its sharing-mode performance (JCT_share/JCT_preempt,
the paper's band) because its kernels run whenever the intermittent
high-priority task is absent — but unlike FIKIT it never advances
*during* a high-priority task's gaps.
"""
from __future__ import annotations

import statistics as st

from benchmarks.common import PAIRS, Csv, arch_trace, repeat_task
from repro.core.scheduler import Mode, SimScheduler, profile_tasks

N_HIGH = 40          # paper: 100 x 1s; scaled for bench runtime
DUTY = 0.25          # fraction of wall time the inserted hi task occupies
MODES = (Mode.SHARING, Mode.FIKIT, Mode.PREEMPT)


def run_pair(high: str, low: str, seed: int = 0):
    hi_proto = arch_trace(high, priority=0, interactive=True, seq_tokens=48)
    # seq_tokens=64 keeps the low service's per-layer kernels a few ms —
    # small enough that BestPrioFit can place them inside the interactive
    # service's ~4-6 ms host gaps (with 512 they are ~25 ms and nothing
    # ever fits, which would make FIKIT degenerate to PREEMPT).
    lo_proto = arch_trace(low, priority=5, interactive=False, seq_tokens=64)
    profiled = profile_tasks([hi_proto, lo_proto], T=10, jitter=0.05,
                             seed=seed)
    # paper setup: the inserted task is short relative to its period (1 s
    # inter-arrival); keep the duty cycle fixed across pairs so the
    # low-priority service retains idle time to reclaim.
    interval = hi_proto.solo_jct / DUTY
    # enough back-to-back low tasks to span the whole horizon
    horizon = N_HIGH * interval
    n_lo = max(3, int(horizon / max(lo_proto.solo_jct, 1e-9)) + 2)
    lo_tasks = repeat_task(lo_proto, n_lo, interval=0.0)
    hi_tasks = repeat_task(hi_proto, N_HIGH, interval=interval, start=0.05)
    tasks = lo_tasks + hi_tasks
    out = {}
    for mode in MODES:
        rep = SimScheduler(tasks, mode, profiled, jitter=0.05,
                           seed=seed).run()
        hi_j = [rep.jct(len(lo_tasks) + i) for i in range(N_HIGH)]
        lo_j = [rep.jct(i) for i in range(len(lo_tasks))
                if rep.results[i].completion > 0]
        out[mode] = (st.mean(hi_j), st.mean(lo_j))
    return out


def main(csvout=None):
    # lo_perf_retained_* = JCT_share / JCT_mode for the low-priority
    # service: the fraction of its sharing-mode performance it keeps under
    # the priority scheduler (paper Fig 20's 0.86-1.0x band; smaller JCT =
    # better performance, so 0.93 means "7% slower than under sharing").
    csvout = csvout or Csv(("pair", "hi_speedup_fikit_vs_share",
                            "lo_perf_retained_fikit",
                            "hi_speedup_preempt_vs_share",
                            "lo_perf_retained_preempt"))
    lo_preempt_ratios = []
    for label, high, low in PAIRS:
        res = run_pair(high, low)
        hi_share, lo_share = res[Mode.SHARING]
        hi_fikit, lo_fikit = res[Mode.FIKIT]
        hi_pre, lo_pre = res[Mode.PREEMPT]
        lo_preempt_ratios.append(lo_share / lo_pre)
        csvout.add(f"{label} H:{high} L:{low}",
                   round(hi_share / hi_fikit, 2),
                   round(lo_share / lo_fikit, 3),
                   round(hi_share / hi_pre, 2),
                   round(lo_share / lo_pre, 3))
    csvout.add("lo_perf_retained_preempt_min", round(min(lo_preempt_ratios), 3))
    csvout.add("lo_perf_retained_preempt_max", round(max(lo_preempt_ratios), 3))
    csvout.emit("Fig19/20: Preemption scenario (low runs continuously, "
                "high inserted periodically; PREEMPT = kernel-boundary "
                "preemptive sharing baseline)")
    return csvout


if __name__ == "__main__":
    main()
