"""Paper Fig 19 + Fig 20: preemption scenario. Low-priority service runs
continuously; a high-priority task is inserted every second (100 total).

Claims: high-priority JCT under FIKIT is up to ~15.8x faster than default
sharing (most combos), and the continuously-running low-priority service's
JCT under FIKIT stays 0.86-1x of its sharing-mode value.
"""
from __future__ import annotations

import statistics as st

from benchmarks.common import PAIRS, Csv, arch_trace, repeat_task
from repro.core.scheduler import Mode, SimScheduler, profile_tasks

N_HIGH = 40          # paper: 100 x 1s; scaled for bench runtime
INTERVAL = 0.25


def run_pair(high: str, low: str, seed: int = 0):
    hi_proto = arch_trace(high, priority=0, interactive=True, seq_tokens=48)
    lo_proto = arch_trace(low, priority=5, interactive=False, seq_tokens=512)
    profiled = profile_tasks([hi_proto, lo_proto], T=10, jitter=0.05,
                             seed=seed)
    # enough back-to-back low tasks to span the whole horizon
    horizon = N_HIGH * INTERVAL
    n_lo = max(3, int(horizon / max(lo_proto.solo_jct, 1e-9)) + 2)
    lo_tasks = repeat_task(lo_proto, n_lo, interval=0.0)
    hi_tasks = repeat_task(hi_proto, N_HIGH, interval=INTERVAL, start=0.05)
    tasks = lo_tasks + hi_tasks
    out = {}
    for mode in (Mode.SHARING, Mode.FIKIT):
        rep = SimScheduler(tasks, mode, profiled, jitter=0.05,
                           seed=seed).run()
        hi_j = [rep.jct(len(lo_tasks) + i) for i in range(N_HIGH)]
        lo_j = [rep.jct(i) for i in range(len(lo_tasks))
                if rep.results[i].completion > 0]
        out[mode] = (st.mean(hi_j), st.mean(lo_j))
    return out


def main(csvout=None):
    csvout = csvout or Csv(("pair", "hi_speedup_fikit_vs_share",
                            "lo_fikit_over_share"))
    for label, high, low in PAIRS:
        res = run_pair(high, low)
        hi_share, lo_share = res[Mode.SHARING]
        hi_fikit, lo_fikit = res[Mode.FIKIT]
        csvout.add(f"{label} H:{high} L:{low}",
                   round(hi_share / hi_fikit, 2),
                   round(lo_share / lo_fikit, 3))
    csvout.emit("Fig19/20: Preemption scenario (low runs continuously, "
                "high inserted periodically)")
    return csvout


if __name__ == "__main__":
    main()
