"""Paper Fig 16 + Fig 17 + Table 2: multiple inference services sharing one
device. High-priority JCT speedup of FIKIT over default sharing mode, and
the low-priority cost ratio, for the 10 A..J arch pairings.

Paper claims: speedup 1.32-16.41x, >3.4x for half the cases; low-priority
tasks run at <~30% of their sharing-mode rate under FIKIT (the price paid).
"""
from __future__ import annotations

import statistics as st

from benchmarks.common import PAIRS, Csv, arch_trace, repeat_task
from repro.core.scheduler import Mode, SimScheduler, profile_tasks


def run_pair(high: str, low: str, n: int = 12, seed: int = 0):
    # high: interactive request (small batch); low: batch job (async
    # client) — the paper's cloud-serving combination. seq_tokens=64 keeps
    # the low service's per-layer kernels a few ms, small enough for
    # BestPrioFit to place them inside the interactive service's ~4-6 ms
    # host gaps (at 512 they are ~25 ms, nothing ever fits, and FIKIT's
    # fill advantage is invisible — it degenerates to pure preemption).
    hi_proto = arch_trace(high, priority=0, interactive=True, seq_tokens=48)
    lo_proto = arch_trace(low, priority=5, interactive=False,
                          seq_tokens=64)
    profiled = profile_tasks([hi_proto, lo_proto], T=10, jitter=0.05,
                             seed=seed)
    # both services issue n tasks; high-priority tasks arrive paced by the
    # interactive client, low-priority back-to-back (batch job)
    hi_tasks = repeat_task(hi_proto, n, interval=hi_proto.solo_jct * 1.15)
    lo_tasks = repeat_task(lo_proto, n, interval=0.0)
    tasks = hi_tasks + lo_tasks
    out = {}
    for mode in (Mode.SHARING, Mode.FIKIT):
        rep = SimScheduler(tasks, mode, profiled, jitter=0.05,
                           seed=seed).run()
        hi_j = [rep.jct(i) for i in range(n)]
        lo_j = [rep.jct(n + i) for i in range(n)]
        out[mode] = (st.mean(hi_j), st.mean(lo_j), rep)
    return out


def main(csvout=None):
    csvout = csvout or Csv(("pair", "hi_speedup_fikit_vs_share",
                            "lo_ratio_fikit_vs_share"))
    speedups = []
    for label, high, low in PAIRS:
        res = run_pair(high, low)
        hi_share, lo_share, _ = res[Mode.SHARING]
        hi_fikit, lo_fikit, _ = res[Mode.FIKIT]
        speedup = hi_share / hi_fikit
        lo_ratio = lo_share / lo_fikit       # <1: low prio slower under FIKIT
        speedups.append(speedup)
        csvout.add(f"{label} H:{high} L:{low}", round(speedup, 2),
                   round(lo_ratio, 3))
    csvout.add("min_speedup", round(min(speedups), 2), "")
    csvout.add("max_speedup", round(max(speedups), 2), "")
    csvout.add("frac_above_3.4x",
               round(sum(s > 3.4 for s in speedups) / len(speedups), 2), "")
    csvout.emit("Fig16/17: High-priority JCT speedup FIKIT vs default "
                "sharing (and low-priority cost)")
    return csvout


if __name__ == "__main__":
    main()
