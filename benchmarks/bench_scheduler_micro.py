"""Scheduler-path microbenchmarks: the paper's <5% overhead budget requires
each scheduling decision to cost << one kernel launch (0.1-2 ms).

Measures: KernelID construction, BestPrioFit over loaded queues, a full
FIKIT fill decision, and profiler statistics reduction.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv
from repro.core.fikit import best_prio_fit, fikit_procedure
from repro.core.kernel_id import KernelID, kernel_id_for
from repro.core.profiler import ProfiledData, Profiler, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.task import KernelRequest, TaskKey


def _timeit(fn, n=2000):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6   # us


def main(csvout=None):
    csvout = csvout or Csv()
    x = np.zeros((8, 128, 256), np.float32)
    csvout.add("kernel_id_for(3d aval)",
               round(_timeit(lambda: kernel_id_for("seg", [x, x])), 2),
               "per dispatch (sharing stage)")

    # queues with 64 waiting requests across priorities
    pd = ProfiledData()
    qs = PriorityQueues()
    for i in range(64):
        key = TaskKey(f"t{i}")
        kid = KernelID(f"k{i}")
        prof = TaskProfile(key=key, runs=1)
        prof.SK[kid] = 0.001 * (1 + i % 7)
        pd.load(prof)
        qs.push(KernelRequest(task_key=key, kernel_id=kid, priority=i % 10))

    def bpf():
        r, d = best_prio_fit(qs, 0.0000001, pd)   # never fits: no dequeue
        assert r is None
    csvout.add("best_prio_fit(64 waiting, scan all)",
               round(_timeit(bpf), 2), "per gap-fill decision")

    def fill():
        fikit_procedure(qs, TaskKey("t0"), KernelID("k0"), 0.0000001, pd,
                        launch=lambda r: None)
    csvout.add("fikit_procedure(no fit)", round(_timeit(fill), 2), "")

    prof = Profiler(TaskKey("svc"))
    kid = KernelID("k")
    for _ in range(100):
        prof.start_run()
        for _ in range(50):
            prof.record(kid, 0.001)
            prof.record_gap(0.001)
        prof.end_run()
    csvout.add("profiler.statistics(100 runs x 50 kernels)",
               round(_timeit(lambda: prof.statistics(), n=50), 2),
               "offline, once per service")
    csvout.emit("Scheduler-path microbenchmarks (decision cost must be "
                "<< 0.1-2ms kernel launch)")
    return csvout


if __name__ == "__main__":
    main()
