"""Scheduler-path microbenchmarks: the paper's <5% overhead budget requires
each scheduling decision to cost << one kernel launch (0.1-2 ms).

Measures: KernelID construction, BestPrioFit decision latency as a function
of queue depth (the indexed O(log n) path vs the O(n) reference scan — the
asymptotic win this subsystem exists for), sustained fill-decision
throughput, a full FIKIT fill decision, and profiler statistics reduction.

Set BENCH_SMOKE=1 (CI) to cap the sweep at 4k waiting requests and shrink
repetition counts.

``main`` returns the Csv with a ``json_payload`` attribute —
``benchmarks.run`` persists it as BENCH_scheduler_micro.json so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import Csv
from repro.core.fikit import best_prio_fit, best_prio_fit_scan, \
    fikit_procedure
from repro.core.kernel_id import KernelID, kernel_id_for
from repro.core.profiler import ProfiledData, Profiler, TaskProfile
from repro.core.queues import PriorityQueues
from repro.core.task import KernelRequest, TaskKey

SMOKE = bool(int(os.environ.get("BENCH_SMOKE", "0")))
# queue-depth scaling sweep: 64 -> 64k waiting requests
DEPTHS = (64, 512, 4096) if SMOKE else (64, 512, 4096, 32768, 65536)
SCAN_MAX_DEPTH = 4096          # the O(n) oracle gets too slow beyond this


def _timeit(fn, n=2000):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6   # us


def _loaded_queues(depth: int, discipline: str = "fifo"):
    """depth waiting requests, each its own stream, spread over Q0..Q9,
    with profiled durations on a small grid (ties included). Non-FIFO
    disciplines get deadline tags on half the requests (the EDF index has
    both dated and undated entries to keep sorted)."""
    pd = ProfiledData()
    qs = PriorityQueues(discipline_by_level=discipline)
    for i in range(depth):
        key = TaskKey(f"t{i}")
        kid = KernelID(f"k{i}")
        prof = TaskProfile(key=key, runs=1)
        prof.SK[kid] = 0.001 * (1 + i % 7)
        pd.load(prof)
        deadline = None if discipline == "fifo" or i % 2 else \
            0.01 * (1 + i % 11)
        qs.push(KernelRequest(task_key=key, kernel_id=kid, priority=i % 10,
                              task_instance=i, deadline=deadline))
    return pd, qs


def _sweep(csvout):
    """Per-decision best_prio_fit latency vs queue depth."""
    sweep = {"depths": list(DEPTHS), "indexed_us": {}, "scan_us": {},
             "indexed_decisions_per_sec": {}}
    for depth in DEPTHS:
        pd, qs = _loaded_queues(depth)
        reps = 200 if SMOKE else 2000

        def probe_nofit():
            r, d = best_prio_fit(qs, 1e-7, pd)    # never fits: no dequeue
            assert r is None
        us = _timeit(probe_nofit, n=reps)
        sweep["indexed_us"][depth] = round(us, 3)
        csvout.add(f"best_prio_fit(indexed, {depth} waiting)",
                   round(us, 2), "per gap-fill decision")

        def probe_hit():
            r, d = best_prio_fit(qs, 0.0025, pd)  # fits 0.001/0.002 heads
            qs.push(r)                            # restore depth
        us_hit = _timeit(probe_hit, n=reps)
        sweep["indexed_decisions_per_sec"][depth] = round(1e6 / us_hit)
        csvout.add(f"best_prio_fit(indexed, {depth} waiting, fit+dequeue)",
                   round(us_hit, 2),
                   f"{round(1e6 / us_hit):,} decisions/s")

        if depth <= SCAN_MAX_DEPTH:
            scan_reps = max(5, min(reps, 200_000 // depth))

            def probe_scan():
                r, d = best_prio_fit_scan(qs, 1e-7, pd)
                assert r is None
            us_scan = _timeit(probe_scan, n=scan_reps)
            sweep["scan_us"][depth] = round(us_scan, 3)
            csvout.add(f"best_prio_fit(reference scan, {depth} waiting)",
                       round(us_scan, 2), "O(n) oracle")
    lo, hi = DEPTHS[0], DEPTHS[-1]
    growth = sweep["indexed_us"][hi] / max(sweep["indexed_us"][lo], 1e-9)
    depth_ratio = hi / lo
    sweep["latency_growth_64_to_max"] = round(growth, 2)
    sweep["depth_ratio"] = depth_ratio
    sweep["sublinear"] = growth < depth_ratio
    csvout.add("indexed latency growth (depth x"
               f"{depth_ratio:g})", round(growth, 2),
               "sub-linear" if growth < depth_ratio else "LINEAR-OR-WORSE")
    return sweep


def _discipline_sweep(csvout):
    """Per-decision fill latency (fit + dequeue + requeue) under each queue
    discipline at a fixed deep queue — the sjf/edf paths are extra bisects
    over the same indexes and must stay within 2x of the fifo fast path."""
    depth = 4096
    reps = 200 if SMOKE else 2000
    out = {"depth": depth, "per_decision_us": {}}
    for disc in ("fifo", "sjf", "edf"):
        pd, qs = _loaded_queues(depth, discipline=disc)

        def probe_hit():
            r, d = best_prio_fit(qs, 0.0025, pd)  # fits 0.001/0.002 heads
            qs.push(r)                            # restore depth
        us = _timeit(probe_hit, n=reps)
        out["per_decision_us"][disc] = round(us, 3)
        csvout.add(f"best_prio_fit({disc}, {depth} waiting, fit+dequeue)",
                   round(us, 2), "queue-discipline overhead")
    fifo_us = out["per_decision_us"]["fifo"]
    ratio = max(out["per_decision_us"][d] / fifo_us
                for d in ("sjf", "edf"))
    out["max_overhead_vs_fifo"] = round(ratio, 2)
    out["within_2x_of_fifo"] = ratio <= 2.0
    csvout.add("discipline overhead vs fifo", round(ratio, 2),
               "OK (<= 2x)" if ratio <= 2.0 else "ABOVE 2x FIFO")
    return out


def main(csvout=None):
    csvout = csvout or Csv()
    x = np.zeros((8, 128, 256), np.float32)
    kid_us = _timeit(lambda: kernel_id_for("seg", [x, x]))
    csvout.add("kernel_id_for(3d aval)", round(kid_us, 2),
               "per dispatch (sharing stage)")

    sweep = _sweep(csvout)
    disciplines = _discipline_sweep(csvout)

    pd, qs = _loaded_queues(64)

    def fill():
        fikit_procedure(qs, TaskKey("t0"), KernelID("k0"), 1e-7, pd,
                        launch=lambda r: None)
    fill_us = _timeit(fill)
    csvout.add("fikit_procedure(no fit)", round(fill_us, 2), "")

    prof = Profiler(TaskKey("svc"))
    kid = KernelID("k")
    for _ in range(100):
        prof.start_run()
        for _ in range(50):
            prof.record(kid, 0.001)
            prof.record_gap(0.001)
        prof.end_run()
    stats_us = _timeit(lambda: prof.statistics(), n=50)
    csvout.add("profiler.statistics(100 runs x 50 kernels)",
               round(stats_us, 2), "offline, once per service")
    csvout.emit("Scheduler-path microbenchmarks (decision cost must be "
                "<< 0.1-2ms kernel launch)")
    csvout.json_payload = {
        "smoke": SMOKE,
        "kernel_id_for_us": round(kid_us, 3),
        "best_prio_fit_sweep": sweep,
        "queue_discipline_sweep": disciplines,
        "fikit_procedure_nofit_us": round(fill_us, 3),
        "profiler_statistics_us": round(stats_us, 3),
    }
    return csvout


if __name__ == "__main__":
    main()
