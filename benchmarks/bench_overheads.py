"""Paper Figs 13, 14, 15: the three overhead experiments, on REAL wall-clock
execution of reduced-scale JAX services (not simulated).

- Fig 13 analog ("-rdynamic" vs base): JCT with kernel-ID construction ON
  vs OFF at dispatch time. Paper: -2.38%..+1.55% (noise). Our kernel ID is
  an aval hash — also expected to be noise-level.
- Fig 14 (FIKIT sharing stage vs base): single profiled service under the
  FIKIT engine vs direct execution. Paper: +0.09%..+4.93% (<5%).
- Fig 14-online (this repo's extension): the same sharing-stage run with
  the ONLINE measurement loop enabled (EMA epoch commits + cold start).
  The loop must fit inside the paper's <5% sharing-stage budget — its
  observation path is a dict upsert per kernel_end and commits are
  batched per epoch. The GATE therefore isolates the loop's marginal
  cost: per-arch ``(JCT_fikit+online - JCT_fikit) / JCT_fikit``, gated on
  the MEDIAN across archs staying inside the +/-5% band
  (``fig14_online_gate_ok`` in BENCH_overheads.json; enforced by
  ``scripts/check_bench_gates.py`` in the nightly workflow). The
  engine-vs-direct-base percentages are still reported per arch for
  paper comparability, but on CPU containers they carry large per-arch
  SYSTEMATIC effects in both directions (segment-dispatch overhead vs
  pipelining), identical with the loop on or off — gating the
  on-vs-off delta measures exactly what the online subsystem adds.
- Fig 15 (measuring stage vs base): per-kernel timed exclusive runs vs
  direct execution. Paper: +34.5%..+71.8% (measurement is the expensive
  phase — which is WHY the two-phase design exists, and why the online
  loop refines profiles from sharing-mode execution instead).
"""
from __future__ import annotations

import json
import os
import statistics as st
import time

import jax

from benchmarks.common import WALLCLOCK_ARCHS, Csv
from repro.config import get_config
from repro.core.client import HookClient
from repro.core.executor import WallClockEngine
from repro.core.online import OnlineConfig
from repro.core.profiler import ProfiledData, Profiler
from repro.core.scheduler import Mode
from repro.core.task import TaskKey
from repro.models import api
from repro.models.segmentation import SegmentedService

RUNS = 24
WARM = 6
ARCHS = WALLCLOCK_ARCHS[:5]

#: paper Fig 14 band: the online loop's marginal cost must stay inside
#: this band. Read from the committed tolerance file so the payload's
#: gate_ok and scripts/check_bench_gates.py can never disagree.
with open(os.path.join(os.path.dirname(__file__),
                       "bench_gates.json")) as _f:
    GATE_PCT = json.load(_f)["overheads"]["max_fig14_online_delta_pct"]


def _service(arch: str, host_gap=0.0008):
    cfg = get_config(arch).reduced()
    params = api.build_params(cfg, jax.random.key(0))
    # batch 8 x seq 64: per-segment kernels in the 1-5 ms range so python
    # dispatch noise is small relative to device time
    svc = SegmentedService(cfg, params, batch=8, seq=64, host_gap=host_gap)
    svc.warmup()
    svc.warmup()
    return cfg, svc


def _direct_jct(svc, runs=RUNS):
    """Base environment: run segments directly, no engine, no hooks."""
    jcts = []
    for _ in range(runs):
        state = svc.make_input()
        t0 = time.perf_counter()
        for seg in svc.segments:
            state = seg.fn(state)
            if seg.host_work is not None:
                state = seg.host_work(state)
        jcts.append(time.perf_counter() - t0)
    return st.median(jcts[WARM:])


def _engine_jct(svc, key, mode, profiled=None, identify=True, runs=RUNS,
                measured=False, online=None):
    with WallClockEngine(mode, profiled, online=online) as eng:
        cl = HookClient(eng, key, 0, svc.segments, identify=identify)
        jcts = []
        prof = Profiler(key)
        for _ in range(runs):
            state = svc.make_input()
            if measured:
                _, jct = cl.measure_run(state, prof)
            else:
                _, jct = cl.run(state)
            jcts.append(jct)
    return st.median(jcts[WARM:]), prof


def main(csvout=None):
    csvout = csvout or Csv(("name", "base_ms", "overhead_pct"))
    payload = {"gate_pct": GATE_PCT, "archs": {}}
    for arch in ARCHS:
        cfg, svc = _service(arch)
        key = TaskKey(cfg.name)
        base = _direct_jct(svc)

        # Fig 13: identification on vs off (sharing engine either way)
        with_id, _ = _engine_jct(svc, key, Mode.SHARING, identify=True)
        no_id, _ = _engine_jct(svc, key, Mode.SHARING, identify=False)
        fig13 = round(100 * (with_id - no_id) / no_id, 2)
        csvout.add(f"fig13 ident_on_vs_off {arch}",
                   round(no_id * 1e3, 2), fig13)

        # Fig 15: measuring stage vs base (also produces the profile)
        meas, prof = _engine_jct(svc, key, Mode.EXCLUSIVE, measured=True)
        fig15 = round(100 * (meas - base) / base, 2)
        csvout.add(f"fig15 measuring_vs_base {arch}", round(base * 1e3, 2),
                   fig15)

        # Fig 14: FIKIT sharing stage (profiled) vs base
        pd = ProfiledData()
        pd.load(prof.statistics())
        fikit, _ = _engine_jct(svc, key, Mode.FIKIT, profiled=pd)
        fig14 = round(100 * (fikit - base) / base, 2)
        csvout.add(f"fig14 sharing_stage_vs_base {arch}",
                   round(base * 1e3, 2), fig14)

        # Fig 14-online: same sharing stage with live SK/SG refinement.
        # Fresh ProfiledData from the same measured stats so the online
        # run does not inherit the previous engine's state.
        pd_on = ProfiledData()
        pd_on.load(prof.statistics())
        fikit_on, _ = _engine_jct(svc, key, Mode.FIKIT, profiled=pd_on,
                                  online=OnlineConfig(epoch_observations=64,
                                                      epoch_seconds=0.25))
        fig14_on = round(100 * (fikit_on - base) / base, 2)
        online_delta = round(100 * (fikit_on - fikit) / fikit, 2)
        csvout.add(f"fig14-online sharing+online_vs_base {arch}",
                   round(base * 1e3, 2), fig14_on)
        csvout.add(f"fig14-online loop_cost_vs_fikit {arch}",
                   round(fikit * 1e3, 2), online_delta)

        payload["archs"][arch] = {
            "base_ms": round(base * 1e3, 3),
            "fig13_ident_pct": fig13,
            "fig14_sharing_pct": fig14,
            "fig14_online_pct": fig14_on,
            "fig14_online_delta_pct": online_delta,
            "fig15_measuring_pct": fig15,
        }

    deltas = sorted(a["fig14_online_delta_pct"]
                    for a in payload["archs"].values())
    med_delta = st.median(deltas)
    payload["fig14_online_delta_med_pct"] = round(med_delta, 2)
    payload["fig14_online_delta_max_abs_pct"] = round(
        max(abs(d) for d in deltas), 2)
    payload["fig14_online_gate_ok"] = abs(med_delta) < GATE_PCT
    # reported (not gated): the paper-shaped engine-vs-base percentages
    payload["fig14_max_pct"] = max(a["fig14_sharing_pct"]
                                   for a in payload["archs"].values())
    payload["fig14_online_max_pct"] = max(a["fig14_online_pct"]
                                          for a in payload["archs"].values())
    csvout.add("fig14-online gate (median loop cost vs fikit)",
               round(med_delta, 2),
               f"OK (|median| < {GATE_PCT}%)"
               if payload["fig14_online_gate_ok"]
               else f"OUTSIDE +/-{GATE_PCT}%")
    csvout.emit("Fig13/14/15: interception, sharing-stage (offline AND "
                "online-measure) and measuring-stage overheads (wall clock)")
    csvout.json_payload = payload
    return csvout


if __name__ == "__main__":
    main()
